"""Time-series forecasting with window features — the paper's third use
case: per-key AR(1) drift series, window-aggregate features (RANGE
windows), ridge forecaster trained offline and served online.

    PYTHONPATH=src python examples/forecast.py
"""
import numpy as np

from repro.core import Engine
from repro.data.synthetic import EventStreamConfig, generate_events
from repro.featurestore.table import TableSchema

# ---- stream with an AR(1) drift column ------------------------------------
cfg = EventStreamConfig(n_events=12_000, n_keys=64, n_features=6,
                        ar_rho=0.9, seed=5)
keys, ts, rows = generate_events(cfg)
DRIFT = 4  # column index of the AR(1) series

engine = Engine()
engine.create_table(
    TableSchema("series", key_col="k", ts_col="ts",
                value_cols=("amount", "lat", "lon", "cat", "drift",
                            "drift2")),
    max_keys=64, capacity=512, bucket_size=64)
engine.insert("series", keys.tolist(), ts.tolist(), rows)

# RANGE windows: last 30 and 120 SECONDS (not rows) of signal
engine.deploy("forecast_features", """
    SELECT AVG(drift)  OVER recent AS avg_30s,
           STD(drift)  OVER recent AS std_30s,
           LAST(drift) OVER recent AS last_val,
           AVG(drift)  OVER longw  AS avg_120s,
           COUNT(drift) OVER longw AS n_120s
    FROM series
    WINDOW recent AS (PARTITION BY k ORDER BY ts
                      RANGE BETWEEN 30 PRECEDING AND CURRENT ROW),
           longw  AS (PARTITION BY k ORDER BY ts
                      RANGE BETWEEN 120 PRECEDING AND CURRENT ROW)
""")

# ---- offline: features at each event predict the NEXT drift value ---------
off = engine.query_offline("forecast_features")
names = sorted(n for n in off if not n.startswith("__"))
X = np.stack([off[n] for n in names], -1)
okey, ots = np.asarray(off["__key"]), np.asarray(off["__ts"])

# target: the key's next drift observation
idx = np.searchsorted(ts, ots)
y = np.full(len(idx), np.nan, np.float32)
for j, (kk, i0) in enumerate(zip(okey, idx)):
    later = np.where((keys[i0 + 1:] == keys[i0]))[0]
    if len(later):
        y[j] = rows[i0 + 1 + later[0], DRIFT]
m = np.isfinite(y)
X, y = X[m], y[m]

# ridge regression (closed form)
mu, sd = X.mean(0), X.std(0) + 1e-6
Xn = np.c_[(X - mu) / sd, np.ones(len(X))]
w = np.linalg.solve(Xn.T @ Xn + 1e-3 * np.eye(Xn.shape[1]), Xn.T @ y)
pred = Xn @ w
ss_res = np.sum((y - pred) ** 2)
ss_tot = np.sum((y - y.mean()) ** 2)
print(f"forecaster trained on {len(y)} rows, R^2 = {1 - ss_res / ss_tot:.3f} "
      f"(AR(1) rho={cfg.ar_rho} -> persistence is learnable)")

# ---- online: forecast for fresh requests ----------------------------------
req_keys = list(range(8))
out = engine.request("forecast_features", req_keys,
                     [float(ts.max()) + 1.0] * 8)
F = np.stack([out[n] for n in names], -1)
fc = np.c_[(F - mu) / sd, np.ones(len(F))] @ w
for k, f in zip(req_keys, fc):
    print(f"  key {k}: next-drift forecast {f:+.3f} "
          f"(last observed {out['last_val'][k]:+.3f})")
