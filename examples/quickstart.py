"""Quickstart: deploy a SQL+ML feature query and serve it in real time.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Engine
from repro.featurestore.table import TableSchema

# 1. create a streaming event table (per-key ring buffers + pre-aggregates)
engine = Engine()
engine.create_table(
    TableSchema("events", key_col="user", ts_col="ts",
                value_cols=("amount", "lat", "lon")),
    max_keys=256, capacity=512, bucket_size=64)

# 2. ingest a synthetic transaction stream
rng = np.random.default_rng(0)
n = 5000
keys = rng.integers(0, 100, n)
ts = np.sort(rng.uniform(0, 3600, n)).astype(np.float32)
rows = np.stack([rng.lognormal(3, 1, n), rng.normal(0, 5, n),
                 rng.normal(0, 5, n)], axis=1).astype(np.float32)
engine.insert("events", keys.tolist(), ts.tolist(), rows)

# 3. deploy a feature query ONCE — it serves online and offline
dep = engine.deploy("user_features", """
    SELECT SUM(amount)  OVER w AS spend_50,
           AVG(amount)  OVER w AS avg_50,
           STD(amount)  OVER w AS std_50,
           COUNT(amount) OVER w AS txn_50,
           MAX(amount)  OVER w AS max_50
    FROM events
    WINDOW w AS (PARTITION BY user ORDER BY ts
                 ROWS BETWEEN 50 PRECEDING AND CURRENT ROW)
""")
print("optimizer decisions:")
print(engine.explain("user_features"))

# 4. online: serve a batch of real-time requests (sub-ms after warmup)
out = engine.request("user_features", [1, 2, 3, 4], [4000.0] * 4)
print("\nonline features:")
for name, vals in sorted(out.items()):
    print(f"  {name:10s} {np.round(vals, 3)}")

# 5. offline: materialise point-in-time features for every stored event
#    (training set) — same definition, no training-serving skew
table = engine.query_offline("user_features")
print(f"\noffline materialisation: {len(table['spend_50'])} rows, "
      f"columns={sorted(k for k in table if not k.startswith('__'))}")

print("\nlatency decomposition (paper Eq. 3):")
for k, v in engine.latency_decomposition().items():
    print(f"  {k:15s} {v:.5f}")
