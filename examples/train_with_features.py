"""End-to-end training driver: train a (reduced) assigned architecture for
a few hundred steps on CPU with the full production stack — sharded
train_step, host pipeline, async checkpoints, NaN supervisor.

    PYTHONPATH=src python examples/train_with_features.py \
        [--arch qwen1.5-0.5b] [--steps 200]
"""
import argparse
import tempfile

from repro.configs.base import reduced
from repro.configs.registry import get_config, list_archs
from repro.launch.train import TrainLoop, make_batches
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"training reduced {args.arch}: {cfg.n_layers}L d={cfg.d_model} "
          f"family={cfg.family}")
    with tempfile.TemporaryDirectory() as ckdir:
        loop = TrainLoop(
            cfg,
            opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20,
                                total_steps=args.steps),
            ckpt_dir=ckdir, retain=2)
        batches = make_batches(cfg, batch=args.batch, seq=args.seq, seed=0)
        out = loop.run(batches, steps=args.steps, ckpt_every=50,
                       log_every=20)
        first = out["history"][0]["loss"]
        print(f"\nloss: {first:.3f} -> {out['final_loss']:.3f} "
              f"({args.steps} steps)")
        print(f"checkpoints kept: {loop.ckpt.all_steps()}")


if __name__ == "__main__":
    main()
