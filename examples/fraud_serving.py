"""Real-time fraud detection — the paper's flagship use case, end to end,
now MULTI-TABLE (DESIGN.md §8):

transactions stream  ──┐
                       ├─ LAST JOIN merchants (point-in-time risk profile)
merchant profiles  ────┘
        -> window features + joined features -> offline training set
        -> logistic scorer -> PREDICT() deployed in-query
        -> dynamic-batched serving with latency SLO

The merchant risk profile is re-published mid-stream: offline training
sees each transaction joined against the profile that was live AT THAT
TRANSACTION'S TIME (no leakage), while online serving joins the latest
profile — the same plan, two execution modes.

    PYTHONPATH=src python examples/fraud_serving.py
"""
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.data.synthetic import (EventStreamConfig, generate_events,
                                  make_labels)
from repro.featurestore.table import TableSchema
from repro.serving.batcher import BatcherConfig
from repro.serving.server import FeatureServer, ServerConfig

N_EVENTS, N_KEYS, N_MERCHANTS = 20_000, 256, 12

FEATURE_SQL = """
SELECT
  SUM(amount)   OVER w1 AS amt_sum_10,
  AVG(amount)   OVER w1 AS amt_avg_10,
  STD(amount)   OVER w1 AS amt_std_10,
  COUNT(amount) OVER w2 AS txn_cnt_100,
  MAX(amount)   OVER w2 AS amt_max_100,
  merchants.risk   AS m_risk,
  merchants.volume AS m_volume
FROM events
LAST JOIN merchants ORDER BY mts ON merchant
WINDOW w1 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 10 PRECEDING AND CURRENT ROW),
       w2 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 100 PRECEDING AND CURRENT ROW)
"""

# ---- two tables: transactions + merchant profiles -------------------------
engine = Engine(OptFlags())
engine.create_table(
    TableSchema("events", key_col="user", ts_col="ts",
                value_cols=("amount", "lat", "lon", "merchant")),
    max_keys=N_KEYS, capacity=1024, bucket_size=64)
engine.create_table(
    TableSchema("merchants", key_col="merchant", ts_col="mts",
                value_cols=("risk", "volume")),
    max_keys=N_MERCHANTS, capacity=64, bucket_size=8)

keys, ts, rows = generate_events(
    EventStreamConfig(n_events=N_EVENTS, n_keys=N_KEYS, n_features=4))
engine.insert("events", keys.tolist(), ts.tolist(), rows)

# merchant risk profiles, re-published mid-stream (risk regime change)
rng = np.random.default_rng(7)
risk_epochs = rng.uniform(0, 1, (2, N_MERCHANTS)).astype(np.float32)
t_mid = float(ts[N_EVENTS // 2])
for epoch, t0 in enumerate((float(ts[0]), t_mid)):
    engine.insert(
        "merchants", list(range(N_MERCHANTS)), [t0] * N_MERCHANTS,
        np.stack([risk_epochs[epoch],
                  rng.uniform(10, 500, N_MERCHANTS)], -1)
        .astype(np.float32))

# labels: planted per-user rule + risky-merchant rule (epoch-aware, so the
# JOINED feature is genuinely predictive and point-in-time matters)
mid = rows[:, 3].astype(np.int64)
risk_at_event = np.where(ts >= t_mid, risk_epochs[1][mid],
                         risk_epochs[0][mid])
y_all = make_labels(keys, ts, rows, amount_thresh=60.0, dist_thresh=4.0)
y_all = np.maximum(y_all, ((risk_at_event > 0.8)
                           & (rows[:, 0] > 25.0)).astype(np.float32))

# ---- offline: point-in-time features (windows + join) -> train ------------
engine.deploy("fraud_features", FEATURE_SQL)
off = engine.query_offline("fraud_features")
names = sorted(n for n in off if not n.startswith("__"))
X = np.stack([off[n] for n in names], -1)
y = y_all[np.searchsorted(ts, np.asarray(off["__ts"]))]
mu, sd = X.mean(0), X.std(0) + 1e-6
Xn = (X - mu) / sd
w = np.zeros(X.shape[1], np.float32)
b = 0.0
for _ in range(300):
    p = 1 / (1 + np.exp(-(Xn @ w + b)))
    w -= 1.0 * (Xn.T @ (p - y) / len(y)).astype(np.float32)
    b -= 1.0 * float(np.mean(p - y))
print(f"trained scorer on {len(y)} point-in-time rows "
      f"({len(names)} features incl. joined {', '.join(n for n in names if n.startswith('m_'))}); "
      f"base rate {y.mean():.3f}, mean score on positives "
      f"{p[y == 1].mean():.3f} vs negatives {p[y == 0].mean():.3f}")

# ---- deploy PREDICT() over the SAME two-table definition ------------------
def scorer(params, feats):
    wj, bj = params
    return 1 / (1 + jnp.exp(-(((feats - mu) / sd) @ wj + bj)))

engine.register_model("fraud", scorer, (jnp.asarray(w), jnp.asarray(b)))
head, window = FEATURE_SQL.strip().split("FROM events")
handle = engine.deploy("fraud_scored",
                       head + ", PREDICT(fraud, " + ", ".join(names)
                       + ") AS score FROM events" + window,
                       warm_buckets=(1, 2, 4, 8, 16, 32, 64))
print(f"deployed {handle.tag} [{handle.state}], "
      f"{len(handle._fns)} executables pre-warmed")
print(engine.explain("fraud_scored"))

# ---- online: dynamic-batched serving with deadline SLO --------------------
lat = []
scores = {}

with FeatureServer(engine, "fraud_scored",
                   ServerConfig(BatcherConfig(max_batch=64,
                                              max_delay_s=0.002))) as server:

    def client(i):
        t0 = time.perf_counter()
        try:
            # the request row carries the in-flight transaction, incl.
            # the merchant id the LAST JOIN probes
            r = server.request(int(keys[i]), float(ts.max()) + 1 + i,
                               row=rows[i], timeout=60.0)
        except Exception as e:        # pragma: no cover - report & continue
            print("request failed:", e)
            return
        lat.append(time.perf_counter() - t0)
        assert r.version == handle.version and r.all_ok
        scores[i] = float(r["score"])

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(256)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

lat_ms = np.asarray(lat) * 1e3
print(f"\nserved {len(scores)} concurrent requests in {wall:.3f}s "
      f"({len(scores) / wall:,.0f} QPS), each LAST JOINed against the "
      f"live merchant profile")
print(f"client latency p50={np.percentile(lat_ms, 50):.2f}ms "
      f"p99={np.percentile(lat_ms, 99):.2f}ms "
      f"(mean batch {server.batcher.mean_batch:.1f})")
vals = np.asarray(list(scores.values()))
thresh = np.percentile(vals, 95)      # review the top-5% riskiest
flagged = int((vals > thresh).sum())
print(f"flagged {flagged}/{len(scores)} requests for review "
      f"(score > p95 = {thresh:.4f})")

# ---- data-plane observability: freshness, drift, SLO burn (DESIGN §14) ----
from repro.obs.export import registry_from_engine
from repro.obs.slo import SLOEngine, SLOSpec

fexp = engine.freshness_export()
print(f"\nfeature freshness (events): age p50={fexp['events/age_p50']:.1f} "
      f"p99={fexp['events/age_p99']:.1f} event-time units over "
      f"{fexp['events/serve_rows']} served rows "
      f"(table v{fexp['events/table_version']})")

# pin the launch cohort's serving distribution as the drift reference,
# then replay the same transactions with amounts jumped 4x — the kind of
# upstream regime change the PSI detector exists to catch
engine.pin_drift_reference()
with FeatureServer(engine, "fraud_scored",
                   ServerConfig(BatcherConfig(max_batch=64,
                                              max_delay_s=0.002))) as srv2:
    shifted = rows.copy()
    shifted[:, 0] *= 4.0
    for i in range(128):
        srv2.request(int(keys[i]), float(ts.max()) + 300 + i,
                     row=shifted[i], timeout=60.0)
drift = engine.drift_report()
drifted = sorted(c for c, r in drift.items() if r["drifted"])
print("drift vs pinned reference: " + ", ".join(
    f"{c} psi={r['psi']:.2f}{'*' if r['drifted'] else ''}"
    for c, r in sorted(drift.items())) + f"  -> drifted: {drifted}")

# declarative SLOs: latency and freshness may steer the knob controller
# ("tune"); drift is observe-only — a skewed feature distribution is a
# modeling problem, not a capacity problem
slo = SLOEngine([
    SLOSpec("latency", "latency_p99_s", bound=1.0, budget=0.05,
            fast_window_s=10.0, slow_window_s=60.0),
    SLOSpec("freshness", "feature_age_p99", bound=5_000.0, budget=0.10,
            fast_window_s=10.0, slow_window_s=60.0),
    SLOSpec("drift", "drift_psi_max", bound=0.25, budget=0.0001,
            fast_window_s=10.0, slow_window_s=60.0, action="report"),
])
metrics = {"latency_p99_s": float(np.percentile(lat_ms, 99)) / 1e3,
           "feature_age_p99": fexp["events/age_p99"],
           "drift_psi_max": max(r["psi"] for r in drift.values())}
t0 = time.monotonic()
for k in range(12):                    # a minute of synthetic scrapes
    slo.evaluate(metrics, now=t0 + 5.0 * k)
for name, st in sorted(slo.snapshot(now=t0 + 60.0).items()):
    print(f"SLO {name:9s} [{st['state']:8s}] metric={st['metric']} "
          f"burn fast={st['fast_burn']:.2f} slow={st['slow_burn']:.2f} "
          f"over {st['slow_samples']} samples")
print(f"flight recorder: {engine.flight.stats()} "
      f"(ring dumps to JSONL on SLO breach or worker crash)")

# everything above is one Prometheus scrape away
prom = registry_from_engine(engine, slo=slo).render_prometheus()
wanted = ("repro_freshness_age_p", "repro_drift_psi{",
          "repro_slo_alerting", "repro_slo_fast_burn")
print("\nscrape excerpt:")
for line in prom.splitlines():
    if line.startswith(wanted):
        print("  " + line)
engine.close()
