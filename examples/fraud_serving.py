"""Real-time fraud detection — the paper's flagship use case, end to end:

synthetic transaction stream -> feature store -> offline training features
-> logistic scorer -> PREDICT() deployed in-query -> dynamic-batched
serving with latency SLO.

    PYTHONPATH=src python examples/fraud_serving.py
"""
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (EventStreamConfig, generate_events,
                                  make_labels)
from repro.launch.serve import FEATURE_SQL, build_engine
from repro.serving.batcher import BatcherConfig
from repro.serving.server import FeatureServer, ServerConfig

N_EVENTS, N_KEYS = 20_000, 256

# ---- offline: features + labels -> train the scorer ----------------------
engine = build_engine(N_EVENTS, N_KEYS)
keys, ts, rows = generate_events(
    EventStreamConfig(n_events=N_EVENTS, n_keys=N_KEYS, n_features=6))
y_all = make_labels(keys, ts, rows, amount_thresh=35.0, dist_thresh=2.5)

off = engine.query_offline("fraud_features")
names = sorted(n for n in off if not n.startswith("__"))
X = np.stack([off[n] for n in names], -1)
y = y_all[np.searchsorted(ts, np.asarray(off["__ts"]))]
mu, sd = X.mean(0), X.std(0) + 1e-6
Xn = (X - mu) / sd
w = np.zeros(X.shape[1], np.float32)
b = 0.0
for _ in range(300):
    p = 1 / (1 + np.exp(-(Xn @ w + b)))
    w -= 1.0 * (Xn.T @ (p - y) / len(y)).astype(np.float32)
    b -= 1.0 * float(np.mean(p - y))
print(f"trained scorer on {len(y)} point-in-time rows; "
      f"base rate {y.mean():.3f}, mean score on positives "
      f"{p[y == 1].mean():.3f} vs negatives {p[y == 0].mean():.3f}")

# ---- deploy PREDICT() over the SAME feature definition --------------------
def scorer(params, feats):
    wj, bj = params
    return 1 / (1 + jnp.exp(-(((feats - mu) / sd) @ wj + bj)))

engine.register_model("fraud", scorer, (jnp.asarray(w), jnp.asarray(b)))
head, window = FEATURE_SQL.strip().split("FROM events")
# deploy returns a versioned DeploymentHandle; warm_buckets pre-compiles
# every power-of-2 shape bucket BEFORE the version goes live, so no
# serving request ever pays a JIT compile (DESIGN.md §6)
handle = engine.deploy("fraud_scored",
                       head + ", PREDICT(fraud, " + ", ".join(names)
                       + ") AS score FROM events" + window,
                       warm_buckets=(1, 2, 4, 8, 16, 32, 64))
print(f"deployed {handle.tag} [{handle.state}], "
      f"{len(handle._fns)} executables pre-warmed")

# ---- online: dynamic-batched serving with deadline SLO --------------------
lat = []
scores = {}

with FeatureServer(engine, "fraud_scored",
                   ServerConfig(BatcherConfig(max_batch=64,
                                              max_delay_s=0.002))) as server:

    def client(i):
        t0 = time.perf_counter()
        try:
            r = server.request(int(keys[i]), float(ts.max()) + 1 + i,
                               timeout=60.0)
        except Exception as e:        # pragma: no cover - report & continue
            print("request failed:", e)
            return
        lat.append(time.perf_counter() - t0)
        assert r.version == handle.version and r.all_ok
        scores[i] = float(r["score"])

    threads = [threading.Thread(target=client, args=(i,))
               for i in range(256)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0

lat_ms = np.asarray(lat) * 1e3
print(f"\nserved {len(scores)} concurrent requests in {wall:.3f}s "
      f"({len(scores) / wall:,.0f} QPS)")
print(f"client latency p50={np.percentile(lat_ms, 50):.2f}ms "
      f"p99={np.percentile(lat_ms, 99):.2f}ms "
      f"(mean batch {server.batcher.mean_batch:.1f})")
vals = np.asarray(list(scores.values()))
thresh = np.percentile(vals, 95)      # review the top-5% riskiest
flagged = int((vals > thresh).sum())
print(f"flagged {flagged}/{len(scores)} requests for review "
      f"(score > p95 = {thresh:.4f})")
engine.close()
