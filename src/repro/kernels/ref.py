"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

Positional model (see featurestore.table): event ``p`` of a key lives at ring
slot ``p % C``; retained events are ``p ∈ [max(0, total−C), total)``. For a
request at time ``t`` the window is a position interval ``[p0, p1)`` with
``p1 = P_t = #{events with ts ≤ t}`` and

* ROWS  W : ``p0 = P_t − W``
* RANGE R : ``p0 = first p with ts[p] ≥ t − R``

Both clamped to retention. All aggregates reduce over that interval.

``window_agg_ref``   — naive fused multi-aggregate scan, O(C) per request.
``fused_window_ref``  — single-scan MULTI-WINDOW form: all of a deployment's
                        plain window specs answered from ONE gather of the
                        ring block (shared positions/p1, batched einsum
                        reductions over a (B, S, C) mask tensor).
``preagg_window_ref`` — bucketed pre-aggregation path (paper Eq. 2), reading
                        O(NB + 2·bucket) instead of O(C·V).
``last_join_ref``     — point-in-time LAST JOIN row lookup: latest right-
                        table row with ts ≤ req_ts, as a masked argmax over
                        positions + one-hot gather of the joined columns.
``decode_attention_ref`` / ``flash_attention_ref`` — model-side oracles.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = jnp.float32(-3.0e38)
POS_INF = jnp.float32(3.0e38)
_BIG_I32 = jnp.int32(2**30)

__all__ = ["window_agg_ref", "fused_window_ref", "preagg_window_ref",
            "last_join_ref", "derive_features", "window_bounds",
            "flash_attention_ref", "flash_attention_xla",
            "decode_attention_ref"]

FUSED_FIELDS = ("sum", "sumsq", "count", "min", "max", "first", "last")


def _positions(ts: jax.Array, total: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-slot global positions + validity for gathered rings.

    ts (B, C); total (B,). Returns p (B, C) i32, valid (B, C) bool.
    """
    B, C = ts.shape
    slots = jnp.arange(C, dtype=jnp.int32)[None, :]
    head = (total % C)[:, None].astype(jnp.int32)
    rel = (slots - head) % C
    p = total[:, None].astype(jnp.int32) - C + rel
    valid = (p >= 0) & (p < total[:, None])
    return p, valid


def _upper_bound(ts_rows: jax.Array, total_rows: jax.Array,
                 valid: jax.Array, req_ts: jax.Array,
                 assume_latest: bool) -> jax.Array:
    """``p1 = P_t`` — #events with ts ≤ req_ts. Depends only on the
    request time, never on the frame, so fused multi-window execution
    computes it ONCE and shares it across every spec."""
    if assume_latest:
        return total_rows
    after = valid & (ts_rows > req_ts[:, None])
    return total_rows - jnp.sum(after, axis=1).astype(jnp.int32)


def _lower_bound(p1: jax.Array, ts_rows: jax.Array, total_rows: jax.Array,
                 valid: jax.Array, req_ts: jax.Array, *,
                 rows_preceding: Optional[int],
                 range_preceding: Optional[float]) -> jax.Array:
    """Per-frame ``p0`` (ROWS count back from p1, or RANGE time predicate),
    clamped to [0, retention)."""
    C = ts_rows.shape[1]
    if rows_preceding is not None:
        p0 = p1 - jnp.int32(rows_preceding)
    else:
        in_range = (valid & (ts_rows >= (req_ts - range_preceding)[:, None])
                    & (ts_rows <= req_ts[:, None]))
        p0 = p1 - jnp.sum(in_range, axis=1).astype(jnp.int32)
    return jnp.maximum(jnp.maximum(p0, 0), total_rows - C)


def window_bounds(ts_rows: jax.Array, total_rows: jax.Array,
                  req_ts: jax.Array, *, rows_preceding: Optional[int],
                  range_preceding: Optional[float],
                  assume_latest: bool = False
                  ) -> Tuple[jax.Array, jax.Array]:
    """Window position interval [p0, p1) per request.

    ts_rows (B, C) gathered ring timestamps; total_rows (B,); req_ts (B,).
    ``assume_latest``: online fast path — req_ts ≥ every ingested ts of the
    key, so ``P_t = total`` without scanning timestamps (beyond-paper opt).
    """
    total_rows = total_rows.astype(jnp.int32)
    C = ts_rows.shape[1]
    if assume_latest and rows_preceding is not None:
        p1 = total_rows
        p0 = jnp.maximum(p1 - jnp.int32(rows_preceding), 0)
        p0 = jnp.maximum(p0, total_rows - C)
        return p0, p1
    _, valid = _positions(ts_rows, total_rows)
    p1 = _upper_bound(ts_rows, total_rows, valid, req_ts, assume_latest)
    p0 = _lower_bound(p1, ts_rows, total_rows, valid, req_ts,
                      rows_preceding=rows_preceding,
                      range_preceding=range_preceding)
    return p0, p1


def window_agg_ref(values: jax.Array, ts: jax.Array, total: jax.Array,
                   req_key: jax.Array, req_ts: jax.Array, *,
                   rows_preceding: Optional[int] = None,
                   range_preceding: Optional[float] = None,
                   evt_mask: Optional[jax.Array] = None,
                   assume_latest: bool = False,
                   fields: Optional[Tuple[str, ...]] = None
                   ) -> Dict[str, jax.Array]:
    """Naive fused multi-aggregate sliding window.

    values (K, C, V), ts (K, C), total (K,), req_key (B,), req_ts (B,),
    evt_mask optional (K, C) event-level WHERE mask. ``fields`` restricts
    which aggregates are materialised (None = all).

    Returns dict: sum/sumsq/min/max/first/last (B, V), count (B,).
    """
    fields = fields or ("sum", "sumsq", "count", "min", "max", "first",
                        "last")
    v = values[req_key]            # (B, C, V)
    t = ts[req_key]                # (B, C)
    tot = total[req_key]           # (B,)
    p, valid = _positions(t, tot)
    p0, p1 = window_bounds(t, tot, req_ts,
                           rows_preceding=rows_preceding,
                           range_preceding=range_preceding,
                           assume_latest=assume_latest)
    win = valid & (p >= p0[:, None]) & (p < p1[:, None])
    if evt_mask is not None:
        win = win & evt_mask[req_key]
    winf = win[:, :, None].astype(jnp.float32)

    out: Dict[str, jax.Array] = {}
    if "sum" in fields:
        out["sum"] = jnp.sum(v * winf, axis=1)
    if "sumsq" in fields:
        out["sumsq"] = jnp.sum(v * v * winf, axis=1)
    if "count" in fields:
        out["count"] = jnp.sum(win, axis=1).astype(jnp.float32)
    if "min" in fields:
        out["min"] = jnp.min(jnp.where(win[:, :, None], v, POS_INF), axis=1)
    if "max" in fields:
        out["max"] = jnp.max(jnp.where(win[:, :, None], v, NEG_INF), axis=1)
    if "first" in fields or "last" in fields:
        # first/last: events at min/max position inside the window.
        # Empty window -> 0.0 (SQL NULL has no tensor representation).
        nonempty = jnp.any(win, axis=1)[:, None].astype(jnp.float32)
        p_first = jnp.where(win, p, _BIG_I32)
        p_last = jnp.where(win, p, -1)
        idx_first = jnp.argmin(p_first, axis=1)
        idx_last = jnp.argmax(p_last, axis=1)
        if "first" in fields:
            out["first"] = jnp.take_along_axis(
                v, idx_first[:, None, None], axis=1)[:, 0, :] * nonempty
        if "last" in fields:
            out["last"] = jnp.take_along_axis(
                v, idx_last[:, None, None], axis=1)[:, 0, :] * nonempty
    return out


def last_join_ref(values: jax.Array, ts: jax.Array, total: jax.Array,
                  req_key: jax.Array, req_ts: jax.Array, *,
                  col_idx: Tuple[int, ...],
                  assume_latest: bool = False,
                  with_ts: bool = False
                  ) -> Tuple[jax.Array, ...]:
    """Point-in-time LAST JOIN row lookup (the relational tier's kernel).

    For each request ``i`` over the RIGHT table's ring buffer: select the
    **latest** retained row of key ``req_key[i]`` with
    ``ts <= req_ts[i]`` — a masked argmax over global positions — and
    gather its ``col_idx`` value columns. Per-key timestamps are
    non-decreasing (the ingest contract), so the qualifying positions are
    exactly ``[max(0, total-C), p1)`` with ``p1`` the shared upper bound
    the window kernels use; the join and the windows can therefore never
    disagree about what "as of t" means.

    ``assume_latest`` is the online fast path (req_ts ≥ every ingested
    right-table ts): the newest retained row wins without a ts scan.

    values (K, C, V), ts (K, C), total (K,), req_key (B,), req_ts (B,).
    Returns ``(row (B, len(col_idx)) f32, matched (B,) bool)``; unmatched
    requests (empty ring, or every row newer than req_ts) get zero rows.
    ``with_ts`` appends the selected row's timestamp ``(B,) f32`` (zero
    when unmatched) — the staleness-metrics input (right-row age is
    ``req_ts − sel_ts``).
    """
    if not col_idx:
        raise ValueError("last_join needs at least one value column")
    cols = jnp.asarray(col_idx, jnp.int32)
    v = values[req_key][:, :, cols].astype(jnp.float32)   # (B, C, Vc)
    t = ts[req_key]                                       # (B, C)
    tot = total[req_key].astype(jnp.int32)                # (B,)
    p, valid = _positions(t, tot)
    p1 = _upper_bound(t, tot, valid, req_ts, assume_latest)
    win = valid & (p < p1[:, None])
    p_last = jnp.max(jnp.where(win, p, -1), axis=1)       # (B,)
    matched = p_last >= 0
    # unique positions -> exact one-hot select (matches the LAST aggregate)
    sel = ((p == p_last[:, None]) & win).astype(jnp.float32)
    row = jnp.einsum("bc,bcv->bv", sel, v)
    if not with_ts:
        return row, matched
    sel_ts = jnp.sum(sel * t.astype(jnp.float32), axis=1)
    return row, matched, sel_ts


def check_fused_specs(spec_rows, spec_ranges, spec_fields) -> None:
    """Validate a fused-window spec table (shared by all backends)."""
    S = len(spec_rows)
    if not (len(spec_ranges) == S == len(spec_fields)) or S == 0:
        raise ValueError(
            f"spec table lengths must match and be non-empty: "
            f"rows={len(spec_rows)} ranges={len(spec_ranges)} "
            f"fields={len(spec_fields)}")
    for s in range(S):
        if (spec_rows[s] is None) == (spec_ranges[s] is None):
            raise ValueError(
                f"spec {s}: exactly one of rows/range must be given "
                f"(rows={spec_rows[s]}, range={spec_ranges[s]})")
        bad = [f for f in spec_fields[s] if f not in FUSED_FIELDS]
        if bad:
            raise ValueError(f"spec {s}: unknown fields {bad}")


def fused_window_ref(values: jax.Array, ts: jax.Array, total: jax.Array,
                     req_key: jax.Array, req_ts: jax.Array, *,
                     spec_rows: Tuple[Optional[int], ...],
                     spec_ranges: Tuple[Optional[float], ...],
                     spec_fields: Tuple[Tuple[str, ...], ...],
                     evt_mask: Optional[jax.Array] = None,
                     assume_latest: bool = False
                     ) -> Dict[str, jax.Array]:
    """Single-scan fused MULTI-WINDOW aggregation (the OpenMLDB
    multi-window parallel-execution optimization, TPU/XLA form).

    One deployment usually carries several distinct window frames over the
    same partition; executing them per group re-gathers and re-scans the
    same ring block once per frame. This op gathers the block ONCE, derives
    the shared upper bound ``p1`` (it depends only on req_ts) once, builds a
    ``(B, S, C)`` window-mask tensor, and reduces every spec with batched
    matmul-shaped contractions instead of S separate scan chains.

    values (K, C, V) — the UNION of the specs' columns; ``spec_rows`` /
    ``spec_ranges`` / ``spec_fields`` are length-S static tuples (exactly
    one of rows/range per spec; per-spec field masks). Semantics per spec
    are identical to :func:`window_agg_ref`; fields a spec did not request
    are ZERO in its output rows.

    Returns dict: sum/sumsq/min/max/first/last (B, S, V), count (B, S).
    """
    check_fused_specs(spec_rows, spec_ranges, spec_fields)
    S = len(spec_rows)
    fields = tuple(f for f in FUSED_FIELDS
                   if any(f in sf for sf in spec_fields))
    v = values[req_key].astype(jnp.float32)     # (B, C, V) — ONE gather
    t = ts[req_key]                             # (B, C)
    tot = total[req_key].astype(jnp.int32)      # (B,)
    Bq, C, V = v.shape
    p, valid = _positions(t, tot)
    # shared upper bound, per-spec lower bounds — the same helpers
    # window_bounds lowers through, so single- and multi-window semantics
    # cannot drift apart
    p1 = _upper_bound(t, tot, valid, req_ts, assume_latest)
    p0s = jnp.stack(
        [_lower_bound(p1, t, tot, valid, req_ts,
                      rows_preceding=spec_rows[s],
                      range_preceding=spec_ranges[s])
         for s in range(S)], axis=1)            # (B, S)

    base = valid
    if evt_mask is not None:
        base = base & evt_mask[req_key]
    win = (base[:, None, :] & (p[:, None, :] >= p0s[:, :, None])
           & (p[:, None, :] < p1[:, None, None]))          # (B, S, C)
    winf = win.astype(jnp.float32)

    # static per-field spec selector: un-requested fields are zeroed
    def need(f):
        return jnp.asarray(np.asarray(
            [f in sf for sf in spec_fields], np.bool_))

    out: Dict[str, jax.Array] = {}
    if "sum" in fields:
        r = jnp.einsum("bsc,bcv->bsv", winf, v)
        out["sum"] = jnp.where(need("sum")[None, :, None], r, 0.0)
    if "sumsq" in fields:
        r = jnp.einsum("bsc,bcv->bsv", winf, v * v)
        out["sumsq"] = jnp.where(need("sumsq")[None, :, None], r, 0.0)
    if "count" in fields:
        r = jnp.sum(winf, axis=2)
        out["count"] = jnp.where(need("count")[None, :], r, 0.0)
    # min/max loop the static spec axis so the peak temporary stays
    # (B, C, V) like the per-group path — a broadcast over S would
    # materialise (B, S, C, V)
    if "min" in fields:
        r = jnp.stack(
            [jnp.min(jnp.where(win[:, s, :, None], v, POS_INF), axis=1)
             for s in range(S)], axis=1)
        out["min"] = jnp.where(need("min")[None, :, None], r, 0.0)
    if "max" in fields:
        r = jnp.stack(
            [jnp.max(jnp.where(win[:, s, :, None], v, NEG_INF), axis=1)
             for s in range(S)], axis=1)
        out["max"] = jnp.where(need("max")[None, :, None], r, 0.0)
    if "first" in fields or "last" in fields:
        # positions are unique per key -> exact one-hot select (an empty
        # window selects nothing and yields 0, matching window_agg_ref)
        if "first" in fields:
            p_first = jnp.min(jnp.where(win, p[:, None, :], _BIG_I32),
                              axis=2)
            sel = ((p[:, None, :] == p_first[:, :, None]) & win)
            r = jnp.einsum("bsc,bcv->bsv", sel.astype(jnp.float32), v)
            out["first"] = jnp.where(need("first")[None, :, None], r, 0.0)
        if "last" in fields:
            p_last = jnp.max(jnp.where(win, p[:, None, :], -1), axis=2)
            sel = ((p[:, None, :] == p_last[:, :, None]) & win)
            r = jnp.einsum("bsc,bcv->bsv", sel.astype(jnp.float32), v)
            out["last"] = jnp.where(need("last")[None, :, None], r, 0.0)
    return out


def preagg_window_ref(values: jax.Array, ts: jax.Array, total: jax.Array,
                      pa_sum: jax.Array, pa_sumsq: jax.Array,
                      pa_min: jax.Array, pa_max: jax.Array,
                      pa_count: jax.Array,
                      req_key: jax.Array, req_ts: jax.Array, *,
                      bucket_size: int,
                      rows_preceding: Optional[int] = None,
                      range_preceding: Optional[float] = None,
                      assume_latest: bool = False,
                      fields: Optional[Tuple[str, ...]] = None
                      ) -> Dict[str, jax.Array]:
    """Bucketed pre-aggregation window (paper Eq. 2, TPU form).

    window [p0,p1) = head partial [p0, b0·B) + full buckets [b0, b1)
    + tail partial [b1·B, p1), with b0 = ceil(p0/B), b1 = floor(p1/B).
    Exactness requires window span ≤ capacity − bucket_size (DESIGN.md §2).

    Returns dict: sum/sumsq/min/max (B, V), count (B,).
    """
    fields = fields or ("sum", "sumsq", "count", "min", "max")
    B_, C = ts.shape[0], ts.shape[1]
    Bsz = bucket_size
    nb = pa_count.shape[1]
    t = ts[req_key]
    tot = total[req_key].astype(jnp.int32)
    p0, p1 = window_bounds(t, tot, req_ts,
                           rows_preceding=rows_preceding,
                           range_preceding=range_preceding,
                           assume_latest=assume_latest)
    b0 = (p0 + Bsz - 1) // Bsz
    b1 = p1 // Bsz
    has_buckets = b0 <= b1

    # -- full buckets: slot s holds bucket index b(s) = b_head − ((b_head−s) mod NB)
    b_head = jnp.maximum(tot - 1, 0) // Bsz              # (B,)
    s = jnp.arange(nb, dtype=jnp.int32)[None, :]          # (1, NB)
    b_of_s = b_head[:, None] - ((b_head[:, None] - s) % nb)
    bmask = (has_buckets[:, None] & (b_of_s >= b0[:, None])
             & (b_of_s < b1[:, None]))                    # (B, NB)
    bmf = bmask[:, :, None].astype(jnp.float32)
    g = lambda a: a[req_key]                              # (B, NB, ...) gather

    # -- raw partials: head [p0, min(b0·B, p1)) and tail [b1·B, p1) (only
    #    when buckets exist; otherwise the head interval covers everything).
    head_end = jnp.where(has_buckets, b0 * Bsz, p1)
    tail_start = jnp.where(has_buckets, b1 * Bsz, p1)   # empty when no buckets

    def partial(start, end):
        i = jnp.arange(Bsz, dtype=jnp.int32)[None, :]     # span ≤ bucket
        pp = start[:, None] + i                           # (B, Bsz)
        m = pp < end[:, None]
        slot = pp % C
        vv = jnp.take_along_axis(values[req_key], slot[:, :, None], axis=1)
        mf = m[:, :, None].astype(jnp.float32)
        res = {}
        if "sum" in fields:
            res["sum"] = jnp.sum(vv * mf, axis=1)
        if "sumsq" in fields:
            res["sumsq"] = jnp.sum(vv * vv * mf, axis=1)
        if "count" in fields:
            res["count"] = jnp.sum(m, axis=1).astype(jnp.float32)
        if "min" in fields:
            res["min"] = jnp.min(jnp.where(m[:, :, None], vv, POS_INF),
                                 axis=1)
        if "max" in fields:
            res["max"] = jnp.max(jnp.where(m[:, :, None], vv, NEG_INF),
                                 axis=1)
        return res

    h = partial(p0, head_end)
    tl = partial(tail_start, p1)

    out: Dict[str, jax.Array] = {}
    if "sum" in fields:
        out["sum"] = jnp.sum(g(pa_sum) * bmf, axis=1) + h["sum"] + tl["sum"]
    if "sumsq" in fields:
        out["sumsq"] = (jnp.sum(g(pa_sumsq) * bmf, axis=1)
                        + h["sumsq"] + tl["sumsq"])
    if "count" in fields:
        out["count"] = (jnp.sum(g(pa_count) * bmask, axis=1)
                        + h["count"] + tl["count"])
    if "min" in fields:
        min_b = jnp.min(jnp.where(bmask[:, :, None], g(pa_min), POS_INF),
                        axis=1)
        out["min"] = jnp.minimum(min_b, jnp.minimum(h["min"], tl["min"]))
    if "max" in fields:
        max_b = jnp.max(jnp.where(bmask[:, :, None], g(pa_max), NEG_INF),
                        axis=1)
        out["max"] = jnp.maximum(max_b, jnp.maximum(h["max"], tl["max"]))
    return out


def derive_features(raw: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Derive AVG/STD/VAR from moment aggregates; zero-fill empty windows."""
    cnt = raw["count"][:, None] if raw["count"].ndim == 1 else raw["count"]
    safe = jnp.maximum(cnt, 1.0)
    nonempty = cnt > 0
    out = dict(raw)
    if "sum" in raw:
        mean = raw["sum"] / safe
        out["avg"] = jnp.where(nonempty, mean, 0.0)
        if "sumsq" in raw:
            var = jnp.maximum(raw["sumsq"] / safe - mean * mean, 0.0)
            out["var"] = jnp.where(nonempty, var, 0.0)
            out["std"] = jnp.sqrt(out["var"])
    if "min" in raw:
        out["min"] = jnp.where(nonempty, raw["min"], 0.0)
    if "max" in raw:
        out["max"] = jnp.where(nonempty, raw["max"], 0.0)
    return out


# ---------------------------------------------------------------------------
# Model-side attention oracles
# ---------------------------------------------------------------------------

def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """Reference GQA attention. q (B, Sq, Hq, D), k/v (B, Sk, Hkv, D).

    ``window``: sliding-window attention span (Mistral-style), None = full.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    # grouped GQA form (no jnp.repeat) — see decode_attention_ref: the
    # repeat hides the head grouping from GSPMD and triggers KV gathers.
    qg = q.reshape(B, Sq, Hkv, rep, D)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, k) * scale
    Sk = k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned query block
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(q.dtype), v)
    return out.reshape(B, Sq, Hq, D)


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: Optional[int] = None,
                        scale: Optional[float] = None,
                        block_k: int = 1024,
                        unroll: bool = False) -> jax.Array:
    """Streaming online-softmax attention in pure XLA ops — the SAME
    algorithm as the Pallas flash kernel, expressed as a scan over KV
    blocks so the lowered HLO never materialises the (Sq, Sk) score
    matrix. This is what the production TPU build runs through the Pallas
    kernel; on the dry-run meshes it is the lowering that makes the
    memory/collective roofline terms reflect the kernel, not a naive S²
    einsum (EXPERIMENTS.md §Perf).

    ``unroll=True`` emits straight-line code (no while loop) so XLA cost
    analysis counts every block — used by the dry-run measurement.
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, Sk)
    if Sk % bk:
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)
    nb = Sk // bk
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, rep, D)
    kb = k.reshape(B, nb, bk, Hkv, D)
    vb = v.reshape(B, nb, bk, Hkv, D)
    qpos = jnp.arange(Sq, dtype=jnp.int32) + (Sk - Sq)  # right-aligned

    def body(carry, inp):
        acc, m, l = carry            # (B,Sq,Hkv,rep,D), (B,Sq,Hkv,rep), l
        kblk, vblk, k_lo = inp       # (B,bk,Hkv,D) ×2, scalar
        s = jnp.einsum("bqhrd,bkhd->bqhrk", qg,
                       kblk.astype(jnp.float32))        # (B,Sq,Hkv,rep,bk)
        kpos = k_lo + jnp.arange(bk, dtype=jnp.int32)
        mask = jnp.ones((Sq, bk), jnp.bool_)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= kpos[None, :] > qpos[:, None] - window
        maskb = mask[None, :, None, None, :]
        s = jnp.where(maskb, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(maskb, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = (acc * alpha[..., None]
                   + jnp.einsum("bqhrk,bkhd->bqhrd", p,
                                vblk.astype(jnp.float32)))
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, Hkv, rep, D), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, rep), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, rep), jnp.float32)
    xs = (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
          jnp.arange(nb, dtype=jnp.int32) * bk)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), xs,
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None,
                         ring: bool = False) -> jax.Array:
    """Single-token decode attention with KV cache.

    q (B, Hq, D); k_cache/v_cache (B, S, Hkv, D).

    ``ring=False``: prefix layout — ``lengths`` (B,) = number of valid
    cache entries (the query attends to positions < length; ``window``
    restricts to the trailing ``window`` of them).

    ``ring=True``: rolling-ring layout (sliding-window serving) —
    ``lengths`` carries the current absolute POSITION (B,). The entry at
    ring slot ``s`` holds absolute position ``pos - ((pos - s) mod S)``;
    it is attended iff that position is ≥ 0 and within the window. Softmax
    is permutation-invariant, so no reordering of the ring is needed.
    """
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    # grouped GQA einsum — NO jnp.repeat: repeating kv heads hides the
    # kv-head<->q-head relation from the SPMD partitioner, which then
    # all-gathers the sequence-sharded cache (268 MB/device/layer measured
    # on qwen2 decode) instead of keeping S local. The grouped form keeps
    # every contraction either local or a (B,H,D)-sized reduce.
    qg = q.reshape(B, Hkv, rep, D)
    logits = jnp.einsum("bhrd,bkhd->bhrk", qg, k_cache) * scale
    kpos = jnp.arange(S, dtype=jnp.int32)[None, :]
    if ring:
        pos = lengths[:, None]
        ap = pos - jax.lax.rem(pos - kpos + S * ((pos // S) + 1), S)
        mask = ap >= 0
        if window is not None:
            mask = mask & (ap > pos - window)
    else:
        mask = kpos < lengths[:, None]
        if window is not None:
            mask = mask & (kpos >= lengths[:, None] - window)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhrk,bkhd->bhrd", probs.astype(q.dtype), v_cache)
    return out.reshape(B, Hq, D)
