"""Pallas TPU kernel: fused multi-aggregate sliding-window scan.

One grid step per request. The request's ring buffer (one key's ``(C, V)``
value block + ``(C,)`` timestamp block) is staged into VMEM via BlockSpec
index maps driven by scalar-prefetched request keys; the kernel derives the
window interval and reduces every requested aggregate in a single pass —
the TPU analogue of OpenMLDB's fused window iterator (one storage scan for
N aggregates, paper §4 "query optimization").

Block layout:
    values (K, C, V)  ->  (1, C, V) VMEM block at row ``req_key[i]``
    ts     (K, C)     ->  (1, C)    VMEM block at row ``req_key[i]``
    outputs           ->  (1, V) / (1, 1) blocks at row ``i``

VMEM working set per step = C·(V+1)·4 bytes (+C for the mask) — e.g.
C=4096, V=16: ~280 KB, comfortably inside the ~16 MB VMEM budget; C and V
are config knobs validated at call time.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# python scalars on purpose: jnp constants would be captured as traced
# consts by the kernel body, which pallas_call rejects
NEG_INF = -3.0e38
POS_INF = 3.0e38
_BIG_I32 = 2**30

_ALL_FIELDS = ("sum", "sumsq", "count", "min", "max", "first", "last")

__all__ = ["window_agg_pallas"]


def _kernel(req_key_ref, tot_ref, rts_ref,    # scalar prefetch (SMEM)
            v_ref, ts_ref, mask_ref,          # VMEM blocks (mask optional)
            *out_refs,
            fields: Tuple[str, ...], C: int, V: int,
            rows_preceding: Optional[int],
            range_preceding: Optional[float],
            assume_latest: bool, has_mask: bool):
    i = pl.program_id(0)
    tot = tot_ref[i]
    t_req = rts_ref[i]
    v = v_ref[0]                                     # (C, V)
    tsb = ts_ref[0][:, None]                         # (C, 1)

    slots = jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    head = tot % C
    rel = jax.lax.rem(slots - head + C, C)
    p = tot - C + rel                                # (C, 1) global positions
    valid = (p >= 0) & (p < tot)

    if assume_latest and rows_preceding is not None:
        p1 = tot
    elif assume_latest:
        p1 = tot
    else:
        after = valid & (tsb > t_req)
        p1 = tot - jnp.sum(after.astype(jnp.int32))
    if rows_preceding is not None:
        p0 = p1 - jnp.int32(rows_preceding)
    else:
        in_range = valid & (tsb >= t_req - range_preceding) & (tsb <= t_req)
        p0 = p1 - jnp.sum(in_range.astype(jnp.int32))
    p0 = jnp.maximum(jnp.maximum(p0, 0), tot - C)

    win = valid & (p >= p0) & (p < p1)               # (C, 1)
    if has_mask:
        win = win & mask_ref[0][:, None]
    winf = win.astype(jnp.float32)

    o = 0
    if "sum" in fields:
        out_refs[o][0, :] = jnp.sum(v * winf, axis=0)
        o += 1
    if "sumsq" in fields:
        out_refs[o][0, :] = jnp.sum(v * v * winf, axis=0)
        o += 1
    if "count" in fields:
        out_refs[o][0, 0] = jnp.sum(winf)
        o += 1
    if "min" in fields:
        out_refs[o][0, :] = jnp.min(jnp.where(win, v, POS_INF), axis=0)
        o += 1
    if "max" in fields:
        out_refs[o][0, :] = jnp.max(jnp.where(win, v, NEG_INF), axis=0)
        o += 1
    if "first" in fields or "last" in fields:
        # positions are unique -> exact one-hot select, no gather needed
        if "first" in fields:
            p_first = jnp.min(jnp.where(win, p, _BIG_I32))
            sel = (p == p_first) & win
            out_refs[o][0, :] = jnp.sum(v * sel.astype(jnp.float32), axis=0)
            o += 1
        if "last" in fields:
            p_last = jnp.max(jnp.where(win, p, -1))
            sel = (p == p_last) & win
            out_refs[o][0, :] = jnp.sum(v * sel.astype(jnp.float32), axis=0)
            o += 1


def window_agg_pallas(values: jax.Array, ts: jax.Array, total: jax.Array,
                      req_key: jax.Array, req_ts: jax.Array, *,
                      rows_preceding: Optional[int] = None,
                      range_preceding: Optional[float] = None,
                      evt_mask: Optional[jax.Array] = None,
                      assume_latest: bool = False,
                      fields: Optional[Tuple[str, ...]] = None,
                      interpret: bool = False) -> Dict[str, jax.Array]:
    """Pallas implementation of :func:`repro.kernels.ref.window_agg_ref`."""
    fields = tuple(fields) if fields else _ALL_FIELDS
    fields = tuple(f for f in _ALL_FIELDS if f in fields)  # canonical order
    K, C, V = values.shape
    B = req_key.shape[0]
    tot_req = total[req_key].astype(jnp.int32)
    req_ts = req_ts.astype(jnp.float32)
    has_mask = evt_mask is not None

    def key_block3(i, keys, tots, rtss):
        return (keys[i], 0, 0)

    def key_block2(i, keys, tots, rtss):
        return (keys[i], 0)

    def req_block(i, keys, tots, rtss):
        return (i, 0)

    in_specs = [
        pl.BlockSpec((1, C, V), key_block3),
        pl.BlockSpec((1, C), key_block2),
    ]
    inputs = [values.astype(jnp.float32), ts.astype(jnp.float32)]
    if has_mask:
        in_specs.append(pl.BlockSpec((1, C), key_block2))
        inputs.append(evt_mask.astype(jnp.bool_))
    else:
        # dummy (1,1) block the kernel ignores
        in_specs.append(pl.BlockSpec((1, 1), lambda i, k, t, r: (0, 0)))
        inputs.append(jnp.zeros((1, 1), jnp.bool_))

    out_specs = []
    out_shapes = []
    for f in fields:
        w = 1 if f == "count" else V
        out_specs.append(pl.BlockSpec((1, w), req_block))
        out_shapes.append(jax.ShapeDtypeStruct((B, w), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    kern = functools.partial(
        _kernel, fields=fields, C=C, V=V,
        rows_preceding=rows_preceding, range_preceding=range_preceding,
        assume_latest=assume_latest, has_mask=has_mask)
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(req_key.astype(jnp.int32), tot_req, req_ts, *inputs)

    res: Dict[str, jax.Array] = {}
    for f, a in zip(fields, outs):
        res[f] = a[:, 0] if f == "count" else a
    return res
