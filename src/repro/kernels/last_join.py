"""Pallas TPU kernel: point-in-time LAST JOIN row lookup.

The relational tier's device-side join (DESIGN.md §8): for each request,
stage the RIGHT table's ring block for the join-resolved key into VMEM,
derive the slot→position map, and select the **latest** retained row with
``ts <= req_ts`` as a masked argmax over global positions — OpenMLDB's
LAST JOIN on ring buffers. One launch joins a whole request batch against
one right table; a deployment with J joined tables costs exactly J extra
launches (asserted by ``bench_lastjoin`` and the engine's
``n_kernel_launches`` accounting).

One grid step per request. Block layout mirrors ``window_agg``:

    values (K, C, V)  ->  (1, C, V) VMEM block at row ``req_key[i]``
    ts     (K, C)     ->  (1, C)    VMEM block at row ``req_key[i]``
    row out           ->  (1, Vc)   block at row ``i``  (selected columns)
    matched out       ->  (1, 1)    block at row ``i``  (1.0 / 0.0)

The joined columns are selected *statically* (``col_idx`` is part of the
compiled spec), so column pruning at the plan layer directly shrinks the
output block; the full ``(1, C, V)`` block still streams through VMEM —
the ring read is the dominant cost either way and keeping the input spec
identical to the window kernels lets XLA reuse the same staging pattern.

Empty/unmatched requests (empty ring, or every retained row newer than
``req_ts``) write a ZERO row and ``matched = 0`` — the engine masks
joined columns with the match flag, matching the empty-window policy.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["last_join_pallas"]


def _kernel(req_key_ref, tot_ref, rts_ref,    # scalar prefetch (SMEM)
            v_ref, ts_ref,                    # VMEM blocks
            row_ref, m_ref, *maybe_ts_ref,
            col_idx: Tuple[int, ...], C: int, V: int,
            assume_latest: bool, with_ts: bool):
    i = pl.program_id(0)
    tot = tot_ref[i]
    t_req = rts_ref[i]
    v = v_ref[0]                                     # (C, V)
    tsb = ts_ref[0][:, None]                         # (C, 1)

    slots = jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    head = tot % C
    rel = jax.lax.rem(slots - head + C, C)
    p = tot - C + rel                                # (C, 1) global positions
    valid = (p >= 0) & (p < tot)
    if assume_latest:
        # online fast path: req_ts >= every ingested right-table ts, so
        # the newest retained row is the join partner — no ts scan
        win = valid
    else:
        # per-key ts is non-decreasing, so {p : ts_p <= t} is the prefix
        # [0, p1) — the same set the window kernels' upper bound selects
        win = valid & (tsb <= t_req)
    p_last = jnp.max(jnp.where(win, p, -1))
    sel = ((p == p_last) & win).astype(jnp.float32)  # exact one-hot (C, 1)
    row = jnp.sum(v * sel, axis=0)                   # (V,)
    for oi, ci in enumerate(col_idx):
        row_ref[0, oi] = row[ci]
    m_ref[0, 0] = (p_last >= 0).astype(jnp.float32)
    if with_ts:
        # selected row's timestamp (staleness metrics); zero if unmatched
        maybe_ts_ref[0][0, 0] = jnp.sum(tsb[:, 0] * sel[:, 0])


def last_join_pallas(values: jax.Array, ts: jax.Array, total: jax.Array,
                     req_key: jax.Array, req_ts: jax.Array, *,
                     col_idx: Tuple[int, ...],
                     assume_latest: bool = False,
                     with_ts: bool = False,
                     interpret: bool = False
                     ) -> Tuple[jax.Array, ...]:
    """Pallas implementation of :func:`repro.kernels.ref.last_join_ref`."""
    if not col_idx:
        raise ValueError("last_join needs at least one value column")
    K, C, V = values.shape
    B = req_key.shape[0]
    Vc = len(col_idx)
    tot_req = total[req_key].astype(jnp.int32)
    req_ts = req_ts.astype(jnp.float32)

    def key_block3(i, keys, tots, rtss):
        return (keys[i], 0, 0)

    def key_block2(i, keys, tots, rtss):
        return (keys[i], 0)

    def req_block(i, keys, tots, rtss):
        return (i, 0)

    out_specs = [
        pl.BlockSpec((1, Vc), req_block),
        pl.BlockSpec((1, 1), req_block),
    ]
    out_shape = [jax.ShapeDtypeStruct((B, Vc), jnp.float32),
                 jax.ShapeDtypeStruct((B, 1), jnp.float32)]
    if with_ts:
        out_specs.append(pl.BlockSpec((1, 1), req_block))
        out_shape.append(jax.ShapeDtypeStruct((B, 1), jnp.float32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, C, V), key_block3),
            pl.BlockSpec((1, C), key_block2),
        ],
        out_specs=out_specs,
    )
    kern = functools.partial(_kernel, col_idx=tuple(col_idx), C=C, V=V,
                             assume_latest=assume_latest, with_ts=with_ts)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=tuple(out_shape),
        interpret=interpret,
    )(req_key.astype(jnp.int32), tot_req, req_ts,
      values.astype(jnp.float32), ts.astype(jnp.float32))
    if with_ts:
        row, m, sel_ts = out
        return row, m[:, 0] > 0.5, sel_ts[:, 0]
    row, m = out
    return row, m[:, 0] > 0.5
