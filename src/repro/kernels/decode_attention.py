"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Decode is memory-bound: each new token must stream the whole (length-long)
KV cache once. The kernel groups the ``rep = Hq/Hkv`` query heads that
share one KV head into a single (rep, D) block so every KV byte fetched
from HBM feeds ``rep`` query heads (GQA's arithmetic-intensity win), and
iterates KV blocks with an online-softmax accumulator.

Grid: (B, Hkv, S/bk). Cache blocks past ``lengths[b]`` (and before the
sliding window) are skipped with ``pl.when``.

Blocks: q (1, 1, rep, D) — q reshaped (B, Hkv, rep, D); k/v (1, bk, 1, D).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30

__all__ = ["decode_attention_pallas"]


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, window: Optional[int], bk: int, n_kb: int,
            rep: int, ring: bool, S: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    length = len_ref[b]      # prefix mode: #valid; ring mode: abs position

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_lo = j * bk
    if ring:
        run = jnp.bool_(True)      # every ring block may hold live entries
    else:
        run = k_lo < length
        if window is not None:
            run = jnp.logical_and(run, k_lo + bk - 1 >= length - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (rep, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (rep, bk)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (rep, bk), 1)
        if ring:
            # ring slot s holds absolute position pos - ((pos - s) mod S)
            pos = length
            ap = pos - jax.lax.rem(pos - kpos + S * (pos // S + 1), S)
            mask = ap >= 0
            if window is not None:
                mask &= ap > pos - window
        else:
            mask = kpos < length
            if window is not None:
                mask &= kpos >= length - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kb - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array, *,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            block_k: int = 256,
                            ring: bool = False,
                            interpret: bool = False) -> jax.Array:
    """q (B, Hq, D); caches (B, S, Hkv, D); lengths (B,) -> (B, Hq, D).

    ``ring=True``: rolling-ring cache (SWA serving); ``lengths`` carries
    the absolute position, masking follows the ring layout (see ref).
    """
    B, Hq, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bk = min(block_k, S)
    if S % bk:
        raise ValueError(f"cache length {S} must divide block_k {bk}")
    n_kb = S // bk
    qg = q.reshape(B, Hkv, rep, D)

    grid = (B, Hkv, n_kb)
    kern = functools.partial(_kernel, scale=scale, window=window, bk=bk,
                             n_kb=n_kb, rep=rep, ring=ring, S=S)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rep, D), lambda b, h, j, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, D), lambda b, h, j, lens: (b, j, h, 0)),
                pl.BlockSpec((1, bk, 1, D), lambda b, h, j, lens: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rep, D),
                                   lambda b, h, j, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep, D), jnp.float32),
                pltpu.VMEM((rep, 1), jnp.float32),
                pltpu.VMEM((rep, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rep, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, Hq, D)
