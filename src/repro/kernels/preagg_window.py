"""Pallas TPU kernel: pre-aggregated window lookup (paper Eq. 2).

The bandwidth story is the whole point: the naive kernel streams the
request's entire ``(C, V)`` ring block from HBM; this kernel reads only

* the bucketed partial-aggregate tiers ``(NB, V)`` (NB = C/bucket ≪ C),
* two ``(bucket, V)`` raw slabs for the head/tail partial corrections,
* optionally the ``(C,)`` timestamp column (RANGE windows / point-in-time).

Raw values therefore stay in HBM (``pl.ANY`` memory space); the kernel
issues two dynamic-start ``make_async_copy`` DMAs for exactly the two
bucket-aligned slabs the window's partial edges touch (ring wraparound
cannot split a slab because capacity % bucket == 0 — see featurestore).

Positions: window [p0, p1) = head partial [p0, b0·B) + full buckets
[b0, b1) + tail partial [b1·B, p1); head slab is bucket b0−1, tail slab is
bucket b1 (b0 ≤ b1+1 always holds).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -3.0e38
POS_INF = 3.0e38

_ALL_FIELDS = ("sum", "sumsq", "count", "min", "max")

__all__ = ["preagg_window_pallas"]


def _kernel(req_key_ref, tot_ref, rts_ref,             # scalar prefetch
            values_hbm, ts_ref, pa_sum_ref, pa_sumsq_ref, pa_min_ref,
            pa_max_ref, pa_cnt_ref,
            *rest,
            fields: Tuple[str, ...], C: int, V: int, NB: int, BSZ: int,
            rows_preceding: Optional[int],
            range_preceding: Optional[float],
            assume_latest: bool, needs_ts: bool):
    n_out = len(fields)
    out_refs = rest[:n_out]
    slab, sem = rest[n_out], rest[n_out + 1]

    i = pl.program_id(0)
    key = req_key_ref[i]
    tot = tot_ref[i]
    t_req = rts_ref[i]

    # ---- window interval [p0, p1) -------------------------------------
    if needs_ts:
        tsb = ts_ref[0][:, None]                          # (C, 1)
        slots = jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
        head = tot % C
        rel = jax.lax.rem(slots - head + C, C)
        p = tot - C + rel
        valid = (p >= 0) & (p < tot)
        if assume_latest:
            p1 = tot
        else:
            p1 = tot - jnp.sum((valid & (tsb > t_req)).astype(jnp.int32))
        if rows_preceding is not None:
            p0 = p1 - jnp.int32(rows_preceding)
        else:
            in_rng = valid & (tsb >= t_req - range_preceding) & (tsb <= t_req)
            p0 = p1 - jnp.sum(in_rng.astype(jnp.int32))
    else:
        p1 = tot
        p0 = p1 - jnp.int32(rows_preceding)
    p0 = jnp.maximum(jnp.maximum(p0, 0), tot - C)

    b0 = (p0 + BSZ - 1) // BSZ
    b1 = p1 // BSZ
    has_buckets = b0 <= b1

    # ---- DMA the two bucket-aligned raw slabs from HBM ------------------
    hb = jnp.maximum(b0 - 1, 0)               # head slab bucket index
    h_slot = (hb * BSZ) % C
    t_slot = (b1 * BSZ) % C
    cp_h = pltpu.make_async_copy(
        values_hbm.at[key, pl.ds(h_slot, BSZ), :], slab.at[0], sem.at[0])
    cp_t = pltpu.make_async_copy(
        values_hbm.at[key, pl.ds(t_slot, BSZ), :], slab.at[1], sem.at[1])
    cp_h.start()
    cp_t.start()

    # ---- full buckets (overlap with the DMAs) ---------------------------
    b_head = jnp.maximum(tot - 1, 0) // BSZ
    s = jax.lax.broadcasted_iota(jnp.int32, (NB, 1), 0)
    b_of_s = b_head - jax.lax.rem(b_head - s + NB * (1 + C // BSZ), NB)
    bmask = has_buckets & (b_of_s >= b0) & (b_of_s < b1)   # (NB, 1)
    bmf = bmask.astype(jnp.float32)

    acc: Dict[str, jax.Array] = {}
    if "sum" in fields:
        acc["sum"] = jnp.sum(pa_sum_ref[0] * bmf, axis=0)
    if "sumsq" in fields:
        acc["sumsq"] = jnp.sum(pa_sumsq_ref[0] * bmf, axis=0)
    if "count" in fields:
        acc["count"] = jnp.sum(pa_cnt_ref[0][:, None] * bmf)
    if "min" in fields:
        acc["min"] = jnp.min(jnp.where(bmask, pa_min_ref[0], POS_INF), axis=0)
    if "max" in fields:
        acc["max"] = jnp.max(jnp.where(bmask, pa_max_ref[0], NEG_INF), axis=0)

    cp_h.wait()
    cp_t.wait()

    # ---- partial corrections from the slabs ------------------------------
    ii = jax.lax.broadcasted_iota(jnp.int32, (BSZ, 1), 0)
    # head slab rows are positions hb·BSZ + ii, in-window [p0, head_end)
    head_end = jnp.where(has_buckets, b0 * BSZ, p1)
    hp = hb * BSZ + ii
    hm = (hp >= p0) & (hp < head_end)
    # tail slab rows are positions b1·BSZ + ii, in-window [tail_start, p1)
    tail_start = jnp.maximum(b1 * BSZ, p0)
    tp = b1 * BSZ + ii
    tm = has_buckets & (tp >= tail_start) & (tp < p1)

    hv, tv = slab[0], slab[1]                    # (BSZ, V)
    hmf, tmf = hm.astype(jnp.float32), tm.astype(jnp.float32)
    o = 0
    for f in fields:
        if f == "sum":
            val = acc["sum"] + jnp.sum(hv * hmf, axis=0) \
                + jnp.sum(tv * tmf, axis=0)
            out_refs[o][0, :] = val
        elif f == "sumsq":
            val = acc["sumsq"] + jnp.sum(hv * hv * hmf, axis=0) \
                + jnp.sum(tv * tv * tmf, axis=0)
            out_refs[o][0, :] = val
        elif f == "count":
            out_refs[o][0, 0] = acc["count"] + jnp.sum(hmf) + jnp.sum(tmf)
        elif f == "min":
            val = jnp.minimum(jnp.min(jnp.where(hm, hv, POS_INF), axis=0),
                              jnp.min(jnp.where(tm, tv, POS_INF), axis=0))
            out_refs[o][0, :] = jnp.minimum(acc["min"], val)
        elif f == "max":
            val = jnp.maximum(jnp.max(jnp.where(hm, hv, NEG_INF), axis=0),
                              jnp.max(jnp.where(tm, tv, NEG_INF), axis=0))
            out_refs[o][0, :] = jnp.maximum(acc["max"], val)
        o += 1


def preagg_window_pallas(values: jax.Array, ts: jax.Array, total: jax.Array,
                         pa_sum: jax.Array, pa_sumsq: jax.Array,
                         pa_min: jax.Array, pa_max: jax.Array,
                         pa_count: jax.Array,
                         req_key: jax.Array, req_ts: jax.Array, *,
                         bucket_size: int,
                         rows_preceding: Optional[int] = None,
                         range_preceding: Optional[float] = None,
                         assume_latest: bool = False,
                         fields: Optional[Tuple[str, ...]] = None,
                         interpret: bool = False) -> Dict[str, jax.Array]:
    """Pallas implementation of :func:`repro.kernels.ref.preagg_window_ref`."""
    fields = tuple(fields) if fields else _ALL_FIELDS
    fields = tuple(f for f in _ALL_FIELDS if f in fields)
    K, C, V = values.shape
    NB = pa_count.shape[1]
    BSZ = bucket_size
    B = req_key.shape[0]
    if C % BSZ != 0 or NB != C // BSZ:
        raise ValueError(f"capacity {C} / bucket {BSZ} / NB {NB} mismatch")
    tot_req = total[req_key].astype(jnp.int32)
    req_ts = req_ts.astype(jnp.float32)
    needs_ts = (rows_preceding is None) or (not assume_latest)

    def key3(i, k, t, r):
        return (k[i], 0, 0)

    def key2(i, k, t, r):
        return (k[i], 0)

    in_specs = [
        pl.BlockSpec(memory_space=pl.ANY),                 # values in HBM
        (pl.BlockSpec((1, C), key2) if needs_ts
         else pl.BlockSpec((1, 1), lambda i, k, t, r: (0, 0))),
        pl.BlockSpec((1, NB, V), key3),                    # pa_sum
        pl.BlockSpec((1, NB, V), key3),                    # pa_sumsq
        pl.BlockSpec((1, NB, V), key3),                    # pa_min
        pl.BlockSpec((1, NB, V), key3),                    # pa_max
        pl.BlockSpec((1, NB), key2),                       # pa_count
    ]
    ts_in = (ts.astype(jnp.float32) if needs_ts
             else jnp.zeros((1, 1), jnp.float32))

    out_specs, out_shapes = [], []
    for f in fields:
        w = 1 if f == "count" else V
        out_specs.append(pl.BlockSpec((1, w), lambda i, k, t, r: (i, 0)))
        out_shapes.append(jax.ShapeDtypeStruct((B, w), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((2, BSZ, V), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kern = functools.partial(
        _kernel, fields=fields, C=C, V=V, NB=NB, BSZ=BSZ,
        rows_preceding=rows_preceding, range_preceding=range_preceding,
        assume_latest=assume_latest, needs_ts=needs_ts)
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(req_key.astype(jnp.int32), tot_req, req_ts,
      values.astype(jnp.float32), ts_in,
      pa_sum.astype(jnp.float32), pa_sumsq.astype(jnp.float32),
      pa_min.astype(jnp.float32), pa_max.astype(jnp.float32),
      pa_count.astype(jnp.float32))

    res: Dict[str, jax.Array] = {}
    for f, a in zip(fields, outs):
        res[f] = a[:, 0] if f == "count" else a
    return res
