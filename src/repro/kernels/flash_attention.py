"""Pallas TPU kernel: causal GQA flash attention (train/prefill path).

Standard IO-aware attention (FlashAttention re-tiled for TPU): the
(Sq, Sk) score matrix is never materialised in HBM; blocks of Q stream
against blocks of K/V held in VMEM with an online-softmax accumulator in
f32 scratch. GQA is handled by indexing the KV head as ``h // rep`` in the
BlockSpec index maps — no repeat-materialisation of KV.

Grid: (B, Hq, Sq/bq, Sk/bk), K-blocks innermost (accumulation order).
Causal + sliding-window blocks that are fully masked are skipped via
``pl.when`` (they still appear in the grid — TPU grids are static — but do
zero work).

Blocks (MXU-aligned): q (1, bq, 1, D) · k/v (1, bk, 1, D); default
bq = bk = 128, D is the head dim (64/80/96/128 for the assigned archs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30

__all__ = ["flash_attention_pallas"]


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, n_kb: int, q_offset: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(2)
    # global positions; queries are right-aligned when Sq < Sk
    q_lo = i * bq + q_offset
    k_lo = j * bk

    # block-level skip: fully-masked (causal/window) blocks do no work
    run = True
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + bq - 1)
    if window is not None:
        run = jnp.logical_and(run, k_lo + bk - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_kb - 1)
    def _finalize():
        l = l_ref[...]
        l_safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Hq % Hkv != 0:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    rep = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        raise ValueError(f"seq lens ({Sq},{Sk}) must divide blocks ({bq},{bk})")
    n_kb = Sk // bk
    q_offset = Sk - Sq   # right-aligned queries (prefill continuation)

    grid = (B, Hq, Sq // bq, n_kb)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kb=n_kb, q_offset=q_offset)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, i, j, rep=rep: (b, j, h // rep, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, i, j, rep=rep: (b, j, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
