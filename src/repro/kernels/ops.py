"""Jit-ready kernel entry points with platform dispatch.

Each op has a Pallas TPU kernel (``repro.kernels.<name>``) and a pure-jnp
oracle (``repro.kernels.ref``). Dispatch order:

* explicit override via ``set_backend("pallas"|"ref"|"auto")``
* "auto": Pallas on TPU backends, reference elsewhere (this container is
  CPU-only, so CI exercises the Pallas kernels through ``interpret=True``
  in the kernel test-suite, and the reference path everywhere else).

The ops are *functionally identical* across backends — the kernel tests
sweep shapes/dtypes asserting allclose against ref.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

__all__ = ["window_agg", "fused_window", "preagg_window", "last_join",
           "flash_attention", "decode_attention", "set_backend",
           "get_backend"]

_VALID = ("auto", "pallas", "ref")
# REPRO_KERNEL_BACKEND pins the dispatch for a whole process (the CI ref
# leg runs the suite with it set to "ref" so the pure-JAX fallback cannot
# rot on machines whose default backend would pick Pallas). A typo must
# fail loudly — silently coercing to "auto" would turn the pinned CI leg
# into a no-op that tests the default path.
_BACKEND = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
if _BACKEND not in _VALID:
    raise ValueError(
        f"REPRO_KERNEL_BACKEND={_BACKEND!r} invalid; use one of {_VALID}")


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in _VALID:
        raise ValueError(f"backend must be one of {_VALID}, got {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _use_pallas(interpret_ok: bool = False) -> bool:
    if _BACKEND == "pallas":
        return True
    if _BACKEND == "ref":
        return False
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Feature-engine ops
# ---------------------------------------------------------------------------

def window_agg(values: jax.Array, ts: jax.Array, total: jax.Array,
               req_key: jax.Array, req_ts: jax.Array, *,
               rows_preceding: Optional[int] = None,
               range_preceding: Optional[float] = None,
               evt_mask: Optional[jax.Array] = None,
               assume_latest: bool = False,
               fields: Optional[Tuple[str, ...]] = None,
               interpret: bool = False) -> Dict[str, jax.Array]:
    """Fused multi-aggregate sliding-window scan (naive path)."""
    if _use_pallas() or interpret:
        from repro.kernels import window_agg as k
        return k.window_agg_pallas(
            values, ts, total, req_key, req_ts,
            rows_preceding=rows_preceding, range_preceding=range_preceding,
            evt_mask=evt_mask, assume_latest=assume_latest, fields=fields,
            interpret=interpret)
    return ref.window_agg_ref(
        values, ts, total, req_key, req_ts,
        rows_preceding=rows_preceding, range_preceding=range_preceding,
        evt_mask=evt_mask, assume_latest=assume_latest, fields=fields)


def fused_window(values: jax.Array, ts: jax.Array, total: jax.Array,
                 req_key: jax.Array, req_ts: jax.Array, *,
                 spec_rows: Tuple[Optional[int], ...],
                 spec_ranges: Tuple[Optional[float], ...],
                 spec_fields: Tuple[Tuple[str, ...], ...],
                 evt_mask: Optional[jax.Array] = None,
                 assume_latest: bool = False,
                 interpret: bool = False) -> Dict[str, jax.Array]:
    """Single-scan fused MULTI-WINDOW aggregation.

    Computes every window spec in the static per-deployment spec table
    (``spec_rows`` / ``spec_ranges`` / per-spec ``spec_fields`` masks)
    from ONE scan of the union value columns — one kernel launch for all
    of a deployment's plain windows. Returns dict field -> (B, S, V)
    (count -> (B, S)); fields a spec did not request are zero.
    """
    if _use_pallas() or interpret:
        from repro.kernels import fused_window as k
        return k.fused_window_pallas(
            values, ts, total, req_key, req_ts,
            spec_rows=spec_rows, spec_ranges=spec_ranges,
            spec_fields=spec_fields, evt_mask=evt_mask,
            assume_latest=assume_latest, interpret=interpret)
    return ref.fused_window_ref(
        values, ts, total, req_key, req_ts,
        spec_rows=spec_rows, spec_ranges=spec_ranges,
        spec_fields=spec_fields, evt_mask=evt_mask,
        assume_latest=assume_latest)


def last_join(values: jax.Array, ts: jax.Array, total: jax.Array,
              req_key: jax.Array, req_ts: jax.Array, *,
              col_idx: Tuple[int, ...],
              assume_latest: bool = False,
              with_ts: bool = False,
              interpret: bool = False) -> Tuple[jax.Array, ...]:
    """Point-in-time LAST JOIN row lookup against a right table's ring.

    Selects, per request, the latest retained row of ``req_key`` with
    ``ts <= req_ts`` and gathers its ``col_idx`` columns. Returns
    ``(row (B, len(col_idx)) f32, matched (B,) bool)``; with
    ``with_ts=True`` also the selected row's timestamp ``(B,) f32``
    (right-row staleness metrics input).
    """
    if _use_pallas() or interpret:
        from repro.kernels import last_join as k
        return k.last_join_pallas(
            values, ts, total, req_key, req_ts, col_idx=col_idx,
            assume_latest=assume_latest, with_ts=with_ts,
            interpret=interpret)
    return ref.last_join_ref(
        values, ts, total, req_key, req_ts, col_idx=col_idx,
        assume_latest=assume_latest, with_ts=with_ts)


def preagg_window(values: jax.Array, ts: jax.Array, total: jax.Array,
                  pa_sum: jax.Array, pa_sumsq: jax.Array, pa_min: jax.Array,
                  pa_max: jax.Array, pa_count: jax.Array,
                  req_key: jax.Array, req_ts: jax.Array, *,
                  bucket_size: int,
                  rows_preceding: Optional[int] = None,
                  range_preceding: Optional[float] = None,
                  assume_latest: bool = False,
                  fields: Optional[Tuple[str, ...]] = None,
                  interpret: bool = False) -> Dict[str, jax.Array]:
    """Pre-aggregated window lookup (paper Eq. 2 path)."""
    if _use_pallas() or interpret:
        from repro.kernels import preagg_window as k
        return k.preagg_window_pallas(
            values, ts, total, pa_sum, pa_sumsq, pa_min, pa_max, pa_count,
            req_key, req_ts, bucket_size=bucket_size,
            rows_preceding=rows_preceding, range_preceding=range_preceding,
            assume_latest=assume_latest, fields=fields, interpret=interpret)
    return ref.preagg_window_ref(
        values, ts, total, pa_sum, pa_sumsq, pa_min, pa_max, pa_count,
        req_key, req_ts, bucket_size=bucket_size,
        rows_preceding=rows_preceding, range_preceding=range_preceding,
        assume_latest=assume_latest, fields=fields)


# ---------------------------------------------------------------------------
# Model-side attention ops
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_k: int = 0, unroll: bool = False,
                    interpret: bool = False) -> jax.Array:
    """Causal (optionally sliding-window) GQA attention.

    q (B, Sq, Hq, D); k/v (B, Sk, Hkv, D) -> (B, Sq, Hq, D).

    ``block_k > 0`` selects the streaming online-softmax form on the
    non-Pallas path (flash algorithm in XLA ops — no S² materialisation);
    ``unroll=True`` additionally unrolls the KV-block loop so dry-run cost
    analysis counts every block.
    """
    if _use_pallas() or interpret:
        from repro.kernels import flash_attention as kmod
        return kmod.flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=interpret)
    if block_k and k.shape[1] > block_k:
        return ref.flash_attention_xla(q, k, v, causal=causal,
                                       window=window, scale=scale,
                                       block_k=block_k, unroll=unroll)
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   scale=scale)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, window: Optional[int] = None,
                     scale: Optional[float] = None, ring: bool = False,
                     interpret: bool = False) -> jax.Array:
    """Single-token GQA decode vs a KV cache.

    q (B, Hq, D); caches (B, S, Hkv, D); lengths (B,) -> (B, Hq, D).
    ``ring=True``: rolling-ring layout; lengths = absolute positions.
    """
    if _use_pallas() or interpret:
        from repro.kernels import decode_attention as kmod
        return kmod.decode_attention_pallas(
            q, k_cache, v_cache, lengths, window=window, scale=scale,
            ring=ring, interpret=interpret)
    return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                    window=window, scale=scale, ring=ring)
