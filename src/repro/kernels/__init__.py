"""Pallas TPU kernels for the perf-critical hot spots.

Layout per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd wrappers with platform dispatch), ``ref.py`` (pure-jnp
oracles). Kernels are validated on CPU via ``interpret=True`` against the
oracles (tests/test_kernels.py sweeps shapes/dtypes).

Kernels:
    window_agg       — fused multi-aggregate sliding-window scan (engine)
    fused_window     — single-scan MULTI-WINDOW form: a deployment's whole
                       spec table (S distinct frames) in one launch
    preagg_window    — bucketed pre-aggregate window lookup, DMA partials
    last_join        — point-in-time LAST JOIN row lookup over a right
                       table's ring (relational tier, DESIGN.md §8)
    flash_attention  — causal/SWA GQA flash attention (train/prefill)
    decode_attention — grouped-head KV-cache decode attention (serving)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
