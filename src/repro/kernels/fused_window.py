"""Pallas TPU kernel: single-scan fused MULTI-WINDOW aggregation.

``window_agg`` fuses N aggregates of ONE window frame into one scan; this
kernel goes one level up and fuses N window *frames* (the per-deployment
spec table) into one scan — the TPU analogue of OpenMLDB's multi-window
parallel execution (Zhou et al., §"query optimization"): a deployment with
S distinct plain windows costs ONE kernel launch and ONE HBM read of the
request's ring block instead of S.

One grid step per request. The request's ring buffer (the union of the
specs' value columns, ``(C, V)``, plus the ``(C,)`` timestamp block) is
staged into VMEM once via scalar-prefetched request keys. The kernel
derives the slot→position map and the shared upper bound ``p1`` (it
depends only on req_ts, not on the frame) once, then unrolls over the
static spec table: per spec a lower bound ``p0_s`` (ROWS count or RANGE
time predicate), a window mask, and the spec's requested aggregate fields.

Block layout:
    values (K, C, V)  ->  (1, C, V) VMEM block at row ``req_key[i]``
    ts     (K, C)     ->  (1, C)    VMEM block at row ``req_key[i]``
    outputs           ->  (1, S, V) / (1, S) blocks at row ``i``

VMEM working set per step = C·(V+1)·4 bytes (+C mask) — identical to the
single-window kernel because the scan is shared; only the (tiny) output
blocks scale with S. Fields a spec did not request are written as ZERO
(out blocks must not carry garbage), matching ``ref.fused_window_ref``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import FUSED_FIELDS, check_fused_specs

# python scalars on purpose: jnp constants would be captured as traced
# consts by the kernel body, which pallas_call rejects
NEG_INF = -3.0e38
POS_INF = 3.0e38
_BIG_I32 = 2**30

__all__ = ["fused_window_pallas"]


def _kernel(req_key_ref, tot_ref, rts_ref,    # scalar prefetch (SMEM)
            v_ref, ts_ref, mask_ref,          # VMEM blocks (mask optional)
            *out_refs,
            fields: Tuple[str, ...],
            spec_rows: Tuple[Optional[int], ...],
            spec_ranges: Tuple[Optional[float], ...],
            spec_fields: Tuple[Tuple[str, ...], ...],
            C: int, V: int,
            assume_latest: bool, has_mask: bool):
    i = pl.program_id(0)
    tot = tot_ref[i]
    t_req = rts_ref[i]
    v = v_ref[0]                                     # (C, V)
    tsb = ts_ref[0][:, None]                         # (C, 1)

    # ---- shared scan state: positions + upper bound (once per request)
    slots = jax.lax.broadcasted_iota(jnp.int32, (C, 1), 0)
    head = tot % C
    rel = jax.lax.rem(slots - head + C, C)
    p = tot - C + rel                                # (C, 1) global positions
    valid = (p >= 0) & (p < tot)
    if assume_latest:
        p1 = tot
    else:
        after = valid & (tsb > t_req)
        p1 = tot - jnp.sum(after.astype(jnp.int32))
    base = valid
    if has_mask:
        base = base & mask_ref[0][:, None]

    # ---- static unroll over the spec table ------------------------------
    for s, (w_rows, w_range, sf) in enumerate(
            zip(spec_rows, spec_ranges, spec_fields)):
        if w_rows is not None:
            p0 = p1 - jnp.int32(w_rows)
        else:
            in_range = valid & (tsb >= t_req - w_range) & (tsb <= t_req)
            p0 = p1 - jnp.sum(in_range.astype(jnp.int32))
        p0 = jnp.maximum(jnp.maximum(p0, 0), tot - C)
        win = base & (p >= p0) & (p < p1)            # (C, 1)
        winf = win.astype(jnp.float32)

        zv = jnp.zeros((V,), jnp.float32)
        o = 0
        for f in fields:
            want = f in sf
            if f == "count":
                out_refs[o][0, s] = jnp.sum(winf) if want else 0.0
            elif f == "sum":
                out_refs[o][0, s, :] = (jnp.sum(v * winf, axis=0)
                                        if want else zv)
            elif f == "sumsq":
                out_refs[o][0, s, :] = (jnp.sum(v * v * winf, axis=0)
                                        if want else zv)
            elif f == "min":
                out_refs[o][0, s, :] = (
                    jnp.min(jnp.where(win, v, POS_INF), axis=0)
                    if want else zv)
            elif f == "max":
                out_refs[o][0, s, :] = (
                    jnp.max(jnp.where(win, v, NEG_INF), axis=0)
                    if want else zv)
            elif f == "first":
                if want:
                    # unique positions -> exact one-hot select, no gather
                    p_first = jnp.min(jnp.where(win, p, _BIG_I32))
                    sel = (p == p_first) & win
                    out_refs[o][0, s, :] = jnp.sum(
                        v * sel.astype(jnp.float32), axis=0)
                else:
                    out_refs[o][0, s, :] = zv
            elif f == "last":
                if want:
                    p_last = jnp.max(jnp.where(win, p, -1))
                    sel = (p == p_last) & win
                    out_refs[o][0, s, :] = jnp.sum(
                        v * sel.astype(jnp.float32), axis=0)
                else:
                    out_refs[o][0, s, :] = zv
            o += 1


def fused_window_pallas(values: jax.Array, ts: jax.Array, total: jax.Array,
                        req_key: jax.Array, req_ts: jax.Array, *,
                        spec_rows: Tuple[Optional[int], ...],
                        spec_ranges: Tuple[Optional[float], ...],
                        spec_fields: Tuple[Tuple[str, ...], ...],
                        evt_mask: Optional[jax.Array] = None,
                        assume_latest: bool = False,
                        interpret: bool = False) -> Dict[str, jax.Array]:
    """Pallas implementation of :func:`repro.kernels.ref.fused_window_ref`."""
    check_fused_specs(spec_rows, spec_ranges, spec_fields)
    S = len(spec_rows)
    fields = tuple(f for f in FUSED_FIELDS
                   if any(f in sf for sf in spec_fields))
    K, C, V = values.shape
    B = req_key.shape[0]
    tot_req = total[req_key].astype(jnp.int32)
    req_ts = req_ts.astype(jnp.float32)
    has_mask = evt_mask is not None

    def key_block3(i, keys, tots, rtss):
        return (keys[i], 0, 0)

    def key_block2(i, keys, tots, rtss):
        return (keys[i], 0)

    in_specs = [
        pl.BlockSpec((1, C, V), key_block3),
        pl.BlockSpec((1, C), key_block2),
    ]
    inputs = [values.astype(jnp.float32), ts.astype(jnp.float32)]
    if has_mask:
        in_specs.append(pl.BlockSpec((1, C), key_block2))
        inputs.append(evt_mask.astype(jnp.bool_))
    else:
        # dummy (1,1) block the kernel ignores
        in_specs.append(pl.BlockSpec((1, 1), lambda i, k, t, r: (0, 0)))
        inputs.append(jnp.zeros((1, 1), jnp.bool_))

    out_specs = []
    out_shapes = []
    for f in fields:
        if f == "count":
            out_specs.append(pl.BlockSpec((1, S), lambda i, k, t, r: (i, 0)))
            out_shapes.append(jax.ShapeDtypeStruct((B, S), jnp.float32))
        else:
            out_specs.append(
                pl.BlockSpec((1, S, V), lambda i, k, t, r: (i, 0, 0)))
            out_shapes.append(jax.ShapeDtypeStruct((B, S, V), jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    kern = functools.partial(
        _kernel, fields=fields, spec_rows=tuple(spec_rows),
        spec_ranges=tuple(spec_ranges),
        spec_fields=tuple(tuple(sf) for sf in spec_fields),
        C=C, V=V, assume_latest=assume_latest, has_mask=has_mask)
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=tuple(out_shapes),
        interpret=interpret,
    )(req_key.astype(jnp.int32), tot_req, req_ts, *inputs)

    return dict(zip(fields, outs))
