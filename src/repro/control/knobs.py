"""Knob controller: AIMD, hysteresis-bounded tuning of the serving knobs.

Three knobs (each optional — pass ``None`` to leave one unmanaged):

* ``delay_s``        — batching deadline (``BatcherConfig.max_delay_s``
  or the router's ``coalesce_delay_s``). The latency/throughput trade:
  longer delay = fuller batches = fewer launches, at queueing cost.
* ``dispatch_rows``  — the router's coalescing chunk size.
* ``max_inflight``   — the admission bound.

Control law (classic AIMD with hysteresis, DESIGN.md §10):

* **Overload** (p99 over target, or any shed/reject this tick) sustained
  for ``hysteresis_ticks``: *multiplicative decrease* of the delay
  (halve it — stop trading latency for batching) and, when the breach
  was backpressure, *additive increase* of ``max_inflight``.
* **Underload** (p99 under ``low_load_fraction``·target, shallow queue,
  no sheds) sustained: *additive increase* of the delay (claw back
  batching efficiency) and of ``dispatch_rows``.
* Anything else: do nothing. Hysteresis means one noisy tick never moves
  a knob, and the two regions are separated by a dead band so the
  controller cannot oscillate between them on the same signal.

``step()`` is a pure function of (internal counters, observation) — no
clocks, no RNG — so a recorded ``(seed, observations)`` log replays to
the identical decision sequence (``KnobController.replay``), which is
how the tests pin controller behaviour.
"""
from __future__ import annotations

import math
from dataclasses import asdict, dataclass
from typing import Any, Dict, List, Optional

__all__ = ["KnobConfig", "LoadObservation", "KnobDecision",
           "KnobController"]


@dataclass(frozen=True)
class KnobConfig:
    target_p99_s: float = 0.010      # the latency SLO the loop chases
    low_load_fraction: float = 0.3   # under-target dead-band edge
    hysteresis_ticks: int = 2        # consecutive ticks before acting
    backoff: float = 0.5             # multiplicative decrease factor
    delay_step_s: float = 0.0005     # additive delay increase
    rows_step: int = 64              # additive dispatch_rows increase
    inflight_step: int = 2           # additive max_inflight increase
    min_delay_s: float = 0.0
    max_delay_s: float = 0.010
    min_dispatch_rows: int = 32
    max_dispatch_rows: int = 2048
    min_inflight: int = 2
    max_inflight: int = 128


@dataclass(frozen=True)
class LoadObservation:
    """One tick's interval signals (deltas, not cumulative totals)."""

    p99_s: float = float("nan")   # NaN = no latency samples this tick
    queue_depth: int = 0
    oldest_age_s: float = 0.0
    shed: int = 0                 # sheds this interval
    rejected: int = 0             # backpressure rejections this interval
    requests: int = 0             # requests served this interval
    slo_burning: bool = False     # an action="tune" SLO is ALERTING


@dataclass(frozen=True)
class KnobDecision:
    tick: int
    knob: str        # "delay_s" | "dispatch_rows" | "max_inflight"
    old: float
    new: float
    reason: str


@dataclass
class _State:
    hot: int = 0     # consecutive overload ticks
    cool: int = 0    # consecutive underload ticks


class KnobController:
    """Deterministic AIMD knob tuner with a replayable decision log."""

    def __init__(self, cfg: KnobConfig = KnobConfig(), *, seed: int = 0,
                 delay_s: Optional[float] = None,
                 dispatch_rows: Optional[int] = None,
                 max_inflight: Optional[int] = None):
        self.cfg = cfg
        self.seed = seed            # recorded in the log for replay id
        self.knobs: Dict[str, float] = {}
        if delay_s is not None:
            self.knobs["delay_s"] = float(delay_s)
        if dispatch_rows is not None:
            self.knobs["dispatch_rows"] = int(dispatch_rows)
        if max_inflight is not None:
            self.knobs["max_inflight"] = int(max_inflight)
        self._state = _State()
        self._tick = 0
        # the replayable record: one entry per step, observation included
        self.log: List[Dict[str, Any]] = []

    # ----------------------------------------------------------------- step
    def step(self, obs: LoadObservation) -> List[KnobDecision]:
        """Advance one tick. Pure in (state, obs): same construction +
        same observation sequence ⇒ same decisions, bit for bit."""
        cfg = self.cfg
        tick = self._tick
        self._tick += 1
        has_p99 = not math.isnan(obs.p99_s)
        overload = (obs.shed > 0 or obs.rejected > 0 or obs.slo_burning
                    or (has_p99 and obs.p99_s > cfg.target_p99_s))
        underload = (not overload and obs.shed == 0 and obs.rejected == 0
                     and obs.queue_depth <= 1 and has_p99
                     and obs.p99_s < cfg.low_load_fraction * cfg.target_p99_s)
        st = self._state
        if overload:
            st.hot, st.cool = st.hot + 1, 0
        elif underload:
            st.cool, st.hot = st.cool + 1, 0
        else:
            st.hot = st.cool = 0

        decisions: List[KnobDecision] = []

        def move(knob: str, new: float, reason: str) -> None:
            old = self.knobs[knob]
            if new != old:
                self.knobs[knob] = new
                decisions.append(KnobDecision(tick, knob, old, new, reason))

        if st.hot >= cfg.hysteresis_ticks:
            st.hot = 0     # re-arm: act once per sustained breach
            if "delay_s" in self.knobs:
                move("delay_s",
                     max(cfg.min_delay_s,
                         self.knobs["delay_s"] * cfg.backoff),
                     f"overload: p99={obs.p99_s:.4f}s shed={obs.shed} "
                     f"rejected={obs.rejected} -> delay x{cfg.backoff}")
            if obs.rejected > 0 and "max_inflight" in self.knobs:
                move("max_inflight",
                     min(cfg.max_inflight,
                         int(self.knobs["max_inflight"])
                         + cfg.inflight_step),
                     f"backpressure: rejected={obs.rejected} "
                     f"-> inflight +{cfg.inflight_step}")
        elif st.cool >= cfg.hysteresis_ticks:
            st.cool = 0
            if "delay_s" in self.knobs:
                move("delay_s",
                     min(cfg.max_delay_s,
                         self.knobs["delay_s"] + cfg.delay_step_s),
                     f"underload: p99={obs.p99_s:.4f}s "
                     f"-> delay +{cfg.delay_step_s}")
            if "dispatch_rows" in self.knobs:
                move("dispatch_rows",
                     min(cfg.max_dispatch_rows,
                         int(self.knobs["dispatch_rows"]) + cfg.rows_step),
                     f"underload -> dispatch_rows +{cfg.rows_step}")

        self.log.append({
            "tick": tick, "seed": self.seed,
            "obs": asdict(obs),
            "decisions": [asdict(d) for d in decisions],
            "knobs": dict(self.knobs),
        })
        return decisions

    # --------------------------------------------------------------- replay
    @classmethod
    def replay(cls, cfg: KnobConfig, seed: int,
               initial: Dict[str, float],
               log: List[Dict[str, Any]]) -> "KnobController":
        """Reconstruct a controller from a recorded log's observations.
        The returned controller's ``log`` must equal the input log —
        the determinism contract the tests assert."""
        c = cls(cfg, seed=seed,
                delay_s=initial.get("delay_s"),
                dispatch_rows=initial.get("dispatch_rows"),
                max_inflight=initial.get("max_inflight"))
        for entry in log:
            c.step(LoadObservation(**entry["obs"]))
        return c

    def snapshot(self) -> Dict[str, Any]:
        return {"seed": self.seed, "tick": self._tick,
                "knobs": dict(self.knobs),
                "hot": self._state.hot, "cool": self._state.cool,
                "decisions": sum(len(e["decisions"]) for e in self.log)}
