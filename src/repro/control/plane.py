"""ControlPlane: the tick loop that closes the loop.

One ``tick()`` runs the whole feedback cycle, in order:

1. **Sample** — ``MetricsCollector.sample()`` turns the runtime's
   monotonic counters into interval deltas.
2. **Attribute + calibrate** — the tick's measured serve time is split
   across the live plan's element profile (scan/preagg/join shares under
   the current model) and fed to the :class:`CostCalibrator`.
3. **Replan** — when the fitted model differs materially from the
   installed one, hand it to the :class:`Replanner` (probe → swap →
   monitor); every tick also runs the post-swap health check so a
   regressed swap rolls back within ``min_health_batches``.
4. **Tune** — build a :class:`LoadObservation` from the sample and apply
   the :class:`KnobController`'s decisions to whichever knob surfaces
   exist (batcher, router, admission).

``tick()`` is synchronous and deterministic given the underlying
metrics; ``start()``/``stop()`` wrap it in a daemon thread for
deployments that want a live loop. Every tick returns (and records) a
JSON-serializable report.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Dict, List, Optional

from repro.control.calibrate import (CostCalibrator, differs_materially,
                                     plan_element_profile)
from repro.control.knobs import (KnobConfig, KnobController,
                                 LoadObservation)
from repro.control.replan import Replanner
from repro.control.telemetry import MetricsCollector
from repro.obs.freshness import FreshnessTracker
from repro.obs.slo import ALERTING, SLOEngine

__all__ = ["ControlPlane"]


class ControlPlane:
    """Telemetry → calibration → re-planning → knob tuning, per tick."""

    def __init__(self, engine, deployment: str, *, server=None,
                 collector: Optional[MetricsCollector] = None,
                 calibrator: Optional[CostCalibrator] = None,
                 knobs: Optional[KnobController] = None,
                 replanner: Optional[Replanner] = None,
                 knob_cfg: KnobConfig = KnobConfig(),
                 replan: bool = True,
                 rel_tol: float = 0.2,
                 seed: int = 0,
                 slo: Optional[SLOEngine] = None,
                 flight=None):
        self.engine = engine
        self.deployment = deployment
        self.server = server
        self.collector = collector or MetricsCollector(engine,
                                                       server=server)
        self.calibrator = calibrator or CostCalibrator()
        self.replanner = replanner or Replanner(engine, deployment)
        self.replan_enabled = replan
        self.rel_tol = rel_tol
        self.knobs = knobs if knobs is not None else self._default_knobs(
            knob_cfg, seed)
        self.slo = slo
        self.flight = flight if flight is not None \
            else getattr(engine, "flight", None)
        self.reports: List[Dict[str, Any]] = []
        self._tick = 0
        self._prev_restarts = 0.0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _default_knobs(self, cfg: KnobConfig, seed: int) -> KnobController:
        """Manage whichever knobs the wired components actually expose."""
        delay = None
        b = getattr(self.server, "batcher", None) if self.server else None
        if b is not None:
            delay = b.cfg.max_delay_s
        router = getattr(self.engine, "router", None)
        rows = router.dispatch_rows if router is not None else None
        if delay is None and router is not None and router.lanes:
            delay = router.lanes[0].coalesce_delay_s
        res = getattr(self.engine, "resources", None)
        inflight = res.cfg.max_inflight if res is not None else None
        return KnobController(cfg, seed=seed, delay_s=delay,
                              dispatch_rows=rows, max_inflight=inflight)

    # ------------------------------------------------------------ calibrate
    def _drain_profile(self) -> List[Dict[str, Any]]:
        obs_fn = getattr(self.engine, "drain_profile_observations", None)
        return obs_fn(self.deployment) if obs_fn is not None else []

    def _feed_calibrator(self, sample: Dict[str, Any]) -> int:
        """Feed the calibrator this tick's observations. Preferred
        source: the operator profiler's MEASURED per-operator exec times
        (``drain_profile_observations`` — kernel-clock seconds split per
        unit-cost element, host/plan residuals excluded). Fallback when
        no profile is available (e.g. process-backend shards keep their
        profilers worker-side): the original EM-style split of the
        tick's serve seconds under the current model's weighted shares.
        Returns observations fed."""
        prof_obs = self._drain_profile()
        if prof_obs:
            for o in prof_obs:
                self.calibrator.observe(o["kind"], o["elements"],
                                        o["seconds"],
                                        table=o.get("table"))
            return len(prof_obs)
        dep = sample["deployments"].get(self.deployment)
        if dep is None:
            return 0
        delta = dep["delta"]
        reqs = delta.get("requests", 0)
        serve_s = delta.get("serve_s", 0.0)
        if reqs <= 0 or serve_s <= 0:
            return 0
        handle = self.engine.handle(self.deployment)
        prof = plan_element_profile(handle)
        model = self.engine.cost_model
        weights = {"scan": model.scan_el, "preagg": model.preagg_el,
                   "join": model.join_el}
        kinds = {k: v for k, v in prof.items() if k in weights and v > 0}
        total_w = sum(weights[k] * v for k, v in kinds.items())
        if total_w <= 0:
            return 0
        sec_per_req = serve_s / reqs
        fed = 0
        for kind, el in kinds.items():
            share = (weights[kind] * el) / total_w
            self.calibrator.observe(kind, el, sec_per_req * share)
            fed += 1
        # per-table join split, proportional to each table's elements
        join_el = kinds.get("join", 0.0)
        if join_el > 0:
            join_sec = sec_per_req * (weights["join"] * join_el) / total_w
            for key, el in prof.items():
                if key.startswith("join:") and el > 0:
                    self.calibrator.observe(
                        "join", el, join_sec * el / join_el,
                        table=key.split(":", 1)[1])
                    fed += 1
        return fed

    # ----------------------------------------------------------------- knob
    def _load_observation(self, sample: Dict[str, Any]) -> LoadObservation:
        dep = sample["deployments"].get(self.deployment, {})
        snap = dep.get("snapshot", {})
        delta = dep.get("delta", {})
        shed = int(delta.get("shed_requests", 0) or 0)
        rejected = 0
        adm = sample.get("admission")
        if adm is not None:
            shed += int(adm["delta"].get("shed_deadline", 0))
            rejected += int(adm["delta"].get("rejected_inflight", 0))
            rejected += int(adm["delta"].get("rejected_queue_depth", 0))
        depth, age = 0, 0.0
        p99 = float(snap.get("latency_p99_s", float("nan")))
        b = sample.get("batcher")
        if b is not None:
            depth = int(b["queue_depth"])
            age = float(b["oldest_age_s"])
            rejected += int(b["delta"].get("rejected", 0))
            shed += int(b["delta"].get("expired", 0))
            # prefer the CLIENT-observed (queueing-inclusive) p99 when a
            # batcher fronts the engine: the serve-side p99 stays flat
            # while a queue builds in front of it, so a controller fed
            # only serve latency would sleep through the buildup
            client_p99 = float(b.get("client_p99_s", float("nan")))
            if math.isfinite(client_p99):
                p99 = client_p99
        return LoadObservation(
            p99_s=p99,
            queue_depth=depth, oldest_age_s=age, shed=shed,
            rejected=rejected, requests=int(delta.get("requests", 0)))

    # ------------------------------------------------------------------ slo
    def _slo_metrics(self, obs: LoadObservation) -> Dict[str, float]:
        """The metric names SLO specs bind to: interval latency/shed from
        the load observation, freshness/drift pulled live from the
        engine's exports."""
        served = max(obs.requests + obs.shed + obs.rejected, 1)
        metrics: Dict[str, float] = {
            "latency_p99_s": obs.p99_s,
            "shed_ratio": (obs.shed + obs.rejected) / served,
        }
        fexp = getattr(self.engine, "freshness_export", None)
        if fexp is not None:
            try:
                exp = fexp()
            except Exception:
                exp = {}
            metrics["feature_age_p99"] = \
                FreshnessTracker.worst_age_p99(exp)
            i2v = [v for k, v in exp.items()
                   if k.endswith("/ingest_visible_p99_s")
                   and isinstance(v, float) and math.isfinite(v)]
            metrics["ingest_visible_p99_s"] = \
                max(i2v) if i2v else float("nan")
        drep = getattr(self.engine, "drift_report", None)
        if drep is not None:
            try:
                psis = [c.get("psi", float("nan"))
                        for c in drep().values()]
            except Exception:
                psis = []
            finite = [p for p in psis if math.isfinite(p)]
            metrics["drift_psi_max"] = \
                max(finite) if finite else float("nan")
        return metrics

    def _evaluate_slo(self, obs: LoadObservation
                      ) -> (bool, Optional[Dict[str, Any]]):
        if self.slo is None:
            return False, None
        metrics = self._slo_metrics(obs)
        events = self.slo.evaluate(metrics)
        if self.flight is not None:
            for ev in events:
                self.flight.record("slo_transition", **ev)
                if ev["state"] == ALERTING:
                    # breach: persist the ring NOW — the offending
                    # batches' trace ids are still in it
                    self.flight.dump(f"slo-{ev['slo']}")
        burning = bool(self.slo.active_alerts(action="tune"))
        return burning, {
            "events": events,
            "alerting": sorted(s.name
                               for s in self.slo.active_alerts()),
            "metrics": metrics,
        }

    def _apply(self, decisions) -> List[Dict[str, Any]]:
        applied = []
        b = getattr(self.server, "batcher", None) if self.server else None
        router = getattr(self.engine, "router", None)
        res = getattr(self.engine, "resources", None)
        for d in decisions:
            ok = False
            if d.knob == "delay_s":
                if b is not None:
                    b.reconfigure(max_delay_s=float(d.new))
                    ok = True
                if router is not None:
                    router.set_coalesce_delay(float(d.new))
                    ok = True
            elif d.knob == "dispatch_rows" and router is not None:
                router.set_dispatch_rows(int(d.new))
                ok = True
            elif d.knob == "max_inflight" and res is not None:
                res.reconfigure(max_inflight=int(d.new))
                ok = True
            applied.append({"knob": d.knob, "old": d.old, "new": d.new,
                            "reason": d.reason, "applied": ok})
        return applied

    # ------------------------------------------------------------- recovery
    def _recovering(self, sample: Dict[str, Any]) -> bool:
        """True while the runtime is absorbing a worker death: a restart
        happened since the last tick, or a shard client is still not
        ready (catalog/WAL replay in flight). A recovery tick's latency
        and shed counters describe the FAILURE, not the workload — fitting
        the cost model or moving knobs on them would tune the steady
        state to a transient."""
        decomp = sample.get("latency_decomposition", {})
        restarts = float(decomp.get("worker_restarts", 0) or 0)
        prev, self._prev_restarts = self._prev_restarts, restarts
        if restarts > prev:
            return True
        backend = getattr(self.engine, "backend", None)
        clients = getattr(backend, "clients", None) if backend else None
        if clients:
            return any(not c.ready and not getattr(c, "retired", False)
                       for c in clients)
        return False

    # ----------------------------------------------------------------- tick
    def tick(self) -> Dict[str, Any]:
        t = self._tick
        self._tick += 1
        sample = self.collector.sample()

        if self._recovering(sample):
            # sample was still taken (baselines advance: the recovery
            # interval's deltas are consumed here, not leaked into the
            # next steady tick) but nothing is fitted, replanned or tuned
            self._drain_profile()    # discard: recovery-interval timings
            report = {
                "tick": t, "recovering": True, "observations_fed": 0,
                "replan": {"action": "recovering"},
                "health": {"action": "recovering"},
                "load": None, "slo": None, "knob_decisions": [],
                "knobs": dict(self.knobs.knobs),
            }
            self.reports.append(report)
            return report

        fed = self._feed_calibrator(sample)

        replan_report: Dict[str, Any] = {"action": "disabled"}
        health: Dict[str, Any] = {"action": "idle"}
        if self.replan_enabled:
            health = self.replanner.check_health()
            if self.replanner.state == Replanner.IDLE:
                fitted = self.calibrator.fit(base=self.engine.cost_model)
                if fitted is not None and differs_materially(
                        fitted, self.engine.cost_model, self.rel_tol):
                    replan_report = self.replanner.maybe_replan(fitted)
                else:
                    replan_report = {"action": "steady",
                                     "fitted": fitted is not None}
            else:
                replan_report = {"action": "monitoring"}

        obs = self._load_observation(sample)
        burning, slo_report = self._evaluate_slo(obs)
        if burning:
            obs = dataclasses.replace(obs, slo_burning=True)
        decisions = self.knobs.step(obs)
        applied = self._apply(decisions)

        report = {
            "tick": t,
            "recovering": False,
            "observations_fed": fed,
            "replan": replan_report,
            "health": health,
            "load": {"p99_s": obs.p99_s, "queue_depth": obs.queue_depth,
                     "shed": obs.shed, "rejected": obs.rejected,
                     "requests": obs.requests,
                     "slo_burning": obs.slo_burning},
            "slo": slo_report,
            "knob_decisions": applied,
            "knobs": dict(self.knobs.knobs),
        }
        self.reports.append(report)
        return report

    # ------------------------------------------------------------ lifecycle
    def start(self, interval_s: float = 0.1) -> None:
        """Run ``tick()`` on a daemon thread every ``interval_s``."""
        if self._thread is not None:
            raise RuntimeError("control plane already started")
        self._stop.clear()

        def loop():
            while not self._stop.wait(interval_s):
                try:
                    self.tick()
                except Exception:      # noqa: BLE001 — the loop survives
                    pass

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="control-plane")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    def __enter__(self) -> "ControlPlane":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False

    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable plane state: telemetry, knob log, replan
        events, last report."""
        return {
            "deployment": self.deployment,
            "telemetry": self.collector.snapshot(),
            "knobs": self.knobs.snapshot(),
            "knob_log": self.knobs.log,
            "replan_events": self.replanner.events,
            "slo": self.slo.snapshot() if self.slo is not None else None,
            "last_report": self.reports[-1] if self.reports else None,
        }
