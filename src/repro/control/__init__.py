"""Adaptive runtime control plane (DESIGN.md §10).

The optimizer's cost constants, the batcher's deadline, the router's
chunking and the admission bounds are all *guesses* at deploy time; this
package closes the loop around them with three layers over the existing
versioned-handle machinery:

* :mod:`repro.control.telemetry` — ``MetricsCollector``: bounded
  ring-buffer time series over engine/cache/handle/batcher/admission
  counters, sampled as interval deltas (monotonic snapshots, no racing
  of mutating fields).
* :mod:`repro.control.calibrate` — ``CostCalibrator``: least-squares
  re-fit of the optimizer's per-element cost weights against measured
  execution time, per access class (scan / preagg / join, per-table).
* :mod:`repro.control.knobs` — ``KnobController``: AIMD,
  hysteresis-bounded adaptation of ``max_delay_s`` / ``dispatch_rows``
  / admission bounds; every decision goes into a replayable log.
* :mod:`repro.control.replan` — ``Replanner``: when calibrated costs
  flip an optimizer decision, rebuild through ``build_version`` →
  pre-warm → ``publish_version`` and auto-roll back if post-swap p99
  regresses.
* :mod:`repro.control.plane` — ``ControlPlane``: one ``tick()`` =
  sample → calibrate → (maybe) replan → tune knobs → health-check.
"""
from repro.control.calibrate import (CostCalibrator, CostObservation,
                                     differs_materially,
                                     plan_element_profile)
from repro.control.knobs import (KnobConfig, KnobController, KnobDecision,
                                 LoadObservation)
from repro.control.plane import ControlPlane
from repro.control.replan import Replanner
from repro.control.telemetry import MetricsCollector, RingSeries

__all__ = [
    "RingSeries", "MetricsCollector",
    "CostObservation", "CostCalibrator", "plan_element_profile",
    "differs_materially",
    "LoadObservation", "KnobConfig", "KnobDecision", "KnobController",
    "Replanner", "ControlPlane",
]
