"""Cost-model calibration: refit the optimizer's per-element weights
against measured execution time.

The optimizer prices a plan in *elements touched* (``estimate_window_cost``
/ ``estimate_join_cost``) with one weight per access class — sequential
ring scan, pre-agg tier walk, join probe. The defaults assume every
element costs the same; on real hardware they don't (a tier walk is
pointer-chasing, a fused scan is a coalesced read), and the paper's 35%
plan-optimization gain depends on the choices those weights drive.

``CostCalibrator`` accumulates ``(kind, elements, seconds)`` observations
and fits one coefficient per kind by least squares through the origin::

    coeff_k = Σ(sec·el) / Σ(el²)        over kind-k observations

then normalizes so scan keeps weight 1.0 — the optimizer only ever
compares costs, so only the *ratios* matter, and normalizing keeps the
calibrated model's numbers commensurate with the uncalibrated one.
Per-table join weights come from grouping join observations by right
table. The fit is deterministic: plain sums in insertion order, no RNG.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.optimizer import (CostModel, TableMeta, estimate_join_cost,
                                  estimate_window_cost)

__all__ = ["CostObservation", "CostCalibrator", "plan_element_profile",
           "differs_materially"]

KINDS = ("scan", "preagg", "join")


@dataclass(frozen=True)
class CostObservation:
    """One measured unit of work: ``elements`` model-units (priced at
    weight 1.0) took ``seconds`` of execution."""

    kind: str                      # "scan" | "preagg" | "join"
    elements: float                # unit-model elements touched
    seconds: float                 # measured execution seconds
    table: Optional[str] = None    # join right table (kind == "join")


def plan_element_profile(handle) -> Dict[str, float]:
    """Per-request unit-model elements of a deployed plan, by access
    class — the attribution weights that split a measured per-request
    latency across kinds. Keys: subset of ``{"scan", "preagg", "join"}``
    plus ``"join:<table>"`` per joined right table."""
    phys = handle.phys
    table = handle.table
    meta = TableMeta(capacity=table.capacity, bucket_size=table.bucket_size,
                     n_value_cols=len(table.schema.value_cols),
                     has_preagg=table.preagg is not None)
    unit = CostModel()
    prof: Dict[str, float] = {}
    n_fused = sum(1 for g in phys.groups if g.impl == "fused") or 1
    for g in phys.groups:
        n_cols = max(1, len(g.plain_cols) + len(g.derived_args))
        share = n_fused if g.impl == "fused" else 1
        el = estimate_window_cost(g.spec, meta, impl=g.impl, n_cols=n_cols,
                                  needs_ts_scan=True, shared_scan=share,
                                  model=unit)
        kind = "preagg" if g.impl == "preagg" else "scan"
        prof[kind] = prof.get(kind, 0.0) + el
    engine = getattr(handle, "engine", None)
    tables = getattr(engine, "tables", {}) if engine is not None else {}
    for j in handle.plan.joins:
        right = tables.get(j.table)
        cap = right.capacity if right is not None else meta.capacity
        el = estimate_join_cost(cap, max(1, len(j.columns)),
                                assume_latest=True, model=unit)
        prof["join"] = prof.get("join", 0.0) + el
        prof[f"join:{j.table}"] = prof.get(f"join:{j.table}", 0.0) + el
    return prof


class CostCalibrator:
    """Bounded-window regression of per-element cost weights.

    ``observe()`` feeds measurements (the control plane attributes
    interval latency across the live plan's element profile; tests inject
    skewed observations directly). ``fit()`` returns a calibrated
    :class:`CostModel` once every *observed* kind has ``min_samples``
    samples, else ``None`` — never a model fitted from noise.
    """

    def __init__(self, min_samples: int = 8, max_samples: int = 512):
        self.min_samples = min_samples
        self._obs: Dict[str, collections.deque] = {}
        self._table_obs: Dict[str, collections.deque] = {}
        self.max_samples = max_samples
        self.total_observed = 0

    def observe(self, kind: str, elements: float, seconds: float,
                table: Optional[str] = None) -> None:
        if kind not in KINDS:
            raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
        if elements <= 0 or seconds < 0:
            return
        q = self._obs.setdefault(
            kind, collections.deque(maxlen=self.max_samples))
        q.append((float(elements), float(seconds)))
        self.total_observed += 1
        if kind == "join" and table is not None:
            tq = self._table_obs.setdefault(
                table, collections.deque(maxlen=self.max_samples))
            tq.append((float(elements), float(seconds)))

    def observe_obs(self, obs: CostObservation) -> None:
        self.observe(obs.kind, obs.elements, obs.seconds, table=obs.table)

    def n_samples(self, kind: str) -> int:
        return len(self._obs.get(kind, ()))

    @staticmethod
    def _lsq(pairs) -> Optional[float]:
        """Least squares through the origin: sec ≈ coeff · el."""
        num = sum(el * sec for el, sec in pairs)
        den = sum(el * el for el, sec in pairs)
        return num / den if den > 0 else None

    def fit(self, base: CostModel = CostModel()) -> Optional[CostModel]:
        """Calibrated model, or ``None`` when under-sampled. Kinds with
        no observations keep ``base``'s weight (you can't calibrate a
        path that never ran); ``launch_overhead`` carries over."""
        observed = {k: q for k, q in self._obs.items() if q}
        if not observed:
            return None
        if any(len(q) < self.min_samples for q in observed.values()):
            return None
        coeff: Dict[str, float] = {}
        for kind, q in observed.items():
            c = self._lsq(q)
            if c is not None and c > 0:
                coeff[kind] = c
        if not coeff:
            return None
        # normalize: scan stays 1.0 (ratios are all the optimizer uses)
        scale = coeff.get("scan")
        if scale is None or scale <= 0:
            # no scan observations — anchor on whichever kind we have,
            # preserving its base weight
            k0 = next(iter(coeff))
            base_w = {"scan": base.scan_el, "preagg": base.preagg_el,
                      "join": base.join_el}[k0]
            scale = coeff[k0] / max(base_w, 1e-12)
        table_el: List[Tuple[str, float]] = []
        join_c = coeff.get("join")
        if join_c is not None and join_c > 0:
            for tname, tq in sorted(self._table_obs.items()):
                if len(tq) < self.min_samples:
                    continue
                tc = self._lsq(tq)
                if tc is not None and tc > 0:
                    table_el.append((tname, tc / join_c))
        return CostModel(
            scan_el=coeff.get("scan", base.scan_el * scale) / scale,
            preagg_el=coeff.get("preagg", base.preagg_el * scale) / scale,
            join_el=coeff.get("join", base.join_el * scale) / scale,
            launch_overhead=base.launch_overhead,
            table_el=tuple(table_el),
        )

    def reset(self) -> None:
        self._obs.clear()
        self._table_obs.clear()


def differs_materially(a: CostModel, b: CostModel,
                       rel_tol: float = 0.2) -> bool:
    """True when two models disagree by more than ``rel_tol`` on any
    weight ratio — the replan trigger threshold (re-planning on 2% noise
    would churn builds forever)."""
    def rel(x: float, y: float) -> float:
        m = max(abs(x), abs(y), 1e-12)
        return abs(x - y) / m
    if (rel(a.scan_el, b.scan_el) > rel_tol
            or rel(a.preagg_el, b.preagg_el) > rel_tol
            or rel(a.join_el, b.join_el) > rel_tol):
        return True
    ta, tb = dict(a.table_el), dict(b.table_el)
    for t in set(ta) | set(tb):
        if rel(ta.get(t, 1.0), tb.get(t, 1.0)) > rel_tol:
            return True
    return False
