"""Closed-loop re-planning over the versioned hot-swap machinery.

When calibration moves the cost model enough to *flip an optimizer
decision* — a window's naive/preagg choice, the fusion grouping, or the
LAST JOIN probe order — the currently-live plan is stale. The
:class:`Replanner` turns that into a safe swap:

1. **Probe**: install the calibrated model and ``build_version`` a
   candidate. If its plan fingerprint equals the live one (the flip
   didn't materialise), discard the candidate — no swap, no risk.
2. **Swap**: otherwise pre-warm and ``publish_version`` — the same
   atomic path as a manual redeploy, so in-flight batches finish on the
   old version and zero requests fail during the cut-over.
3. **Monitor**: the new handle's latency reservoir fills with post-swap
   batches only. Once ``min_health_batches`` have landed, compare its
   p99 against the pre-swap baseline; regress beyond
   ``regress_factor``× and the swap auto-rolls back through
   ``Engine.rollback`` (and the previous cost model is restored so the
   next calibration pass doesn't immediately re-propose the same swap).

State machine: ``idle`` → (probe) → ``monitoring`` → ``idle`` with the
outcome recorded as ``committed`` or ``rolled_back`` in ``events``.

Works against both the single :class:`~repro.core.engine.Engine`
(build → warm → publish) and the :class:`~repro.shard.engine
.ShardedEngine` (probe on shard 0, swap via the atomic all-shard
``deploy``).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

from repro.core.optimizer import CostModel

__all__ = ["Replanner"]


class Replanner:
    IDLE = "idle"
    MONITORING = "monitoring"

    def __init__(self, engine, deployment: str, *,
                 regress_factor: float = 1.5,
                 min_health_batches: int = 16,
                 warm_buckets: Optional[List[int]] = None):
        self.engine = engine
        self.deployment = deployment
        self.regress_factor = regress_factor
        self.min_health_batches = min_health_batches
        self.warm_buckets = warm_buckets
        self.state = self.IDLE
        self._swap: Optional[Dict[str, Any]] = None
        self.events: List[Dict[str, Any]] = []   # JSON-serializable audit

    # ------------------------------------------------------------- helpers
    def _live(self):
        return self.engine.handle(self.deployment)

    @staticmethod
    def _query_of(handle):
        # single-engine handles carry the query; sharded ones delegate
        # to their shard-0 inner handle
        if hasattr(handle, "query"):
            return handle.query
        return handle.handles[0].query

    def _probe_fingerprint(self, query) -> str:
        """Fingerprint of the plan the CURRENT cost model produces,
        without touching any live version. Single engine: a warming
        build (discarded if unchanged). Sharded: probe on shard 0 only —
        every shard compiles the same plan, so one shard answers the
        would-it-change question at 1/S of the build cost."""
        eng = self.engine
        if hasattr(eng, "build_version"):
            probe = eng.build_version(self.deployment, query)
            return probe, probe.plan.fingerprint()
        probe = eng.shards[0].build_version(self.deployment, query)
        return probe, probe.plan.fingerprint()

    def _discard_probe(self, probe) -> None:
        eng = self.engine
        if hasattr(eng, "build_version"):
            eng.discard_version(probe)
        else:
            eng.shards[0].discard_version(probe)

    def _event(self, action: str, **kw) -> Dict[str, Any]:
        ev = {"action": action, "deployment": self.deployment, **kw}
        self.events.append(ev)
        return ev

    # --------------------------------------------------------------- replan
    def maybe_replan(self, model: CostModel) -> Dict[str, Any]:
        """Install ``model``; if it flips the plan, swap to the re-planned
        version (returns the action report either way)."""
        if self.state == self.MONITORING:
            # never stack swaps — the in-flight one must resolve first,
            # or a rollback could land on the wrong baseline
            return self._event("deferred", reason="swap in flight")
        eng = self.engine
        live = self._live()
        query = self._query_of(live)
        prev_model = eng.set_cost_model(model)
        probe, new_fp = self._probe_fingerprint(query)
        if new_fp == live.plan.fingerprint():
            self._discard_probe(probe)
            # keep the calibrated model installed: same plan, truer costs
            return self._event("no_change", version=live.version,
                              model=repr(model))
        baseline_p99 = live.metrics.latency_percentile(99)
        if hasattr(eng, "build_version"):
            if self.warm_buckets:
                probe.warm(self.warm_buckets)
            eng.publish_version(probe)
            new = probe
        else:
            # sharded: the probe was shard-0-only; discard it and roll
            # the real swap through the atomic all-shard deploy
            self._discard_probe(probe)
            new = eng.deploy(self.deployment, query,
                             warm_buckets=self.warm_buckets)
        self.state = self.MONITORING
        self._swap = {
            "old_version": live.version, "new_version": new.version,
            "baseline_p99_s": baseline_p99,
            "prev_model": prev_model,
        }
        return self._event("swapped", old_version=live.version,
                           new_version=new.version,
                           baseline_p99_s=baseline_p99,
                           model=repr(model))

    # --------------------------------------------------------------- health
    def check_health(self) -> Dict[str, Any]:
        """Post-swap p99 gate: commit or auto-rollback. Call every tick;
        no-op while idle or while the reservoir is still filling."""
        if self.state != self.MONITORING:
            return {"action": "idle"}
        rec = self._swap
        new = self._live()
        if new.version != rec["new_version"]:
            # someone else swapped underneath us — abandon the watch
            self.state = self.IDLE
            self._swap = None
            return self._event("superseded", expected=rec["new_version"],
                               found=new.version)
        m = new.metrics
        if len(m.latency_s) < self.min_health_batches:
            return {"action": "monitoring",
                    "batches": len(m.latency_s),
                    "need": self.min_health_batches}
        new_p99 = m.latency_percentile(99)
        baseline = rec["baseline_p99_s"]
        self.state = self.IDLE
        self._swap = None
        if (not math.isnan(baseline)
                and new_p99 > self.regress_factor * baseline):
            self.engine.rollback(self.deployment)
            # restore the pre-swap cost model too, or the next tick
            # would re-propose the exact swap we just rejected
            self.engine.set_cost_model(rec["prev_model"])
            return self._event("rolled_back",
                               new_version=rec["new_version"],
                               restored_version=rec["old_version"],
                               new_p99_s=new_p99,
                               baseline_p99_s=baseline,
                               regress_factor=self.regress_factor)
        return self._event("committed", version=rec["new_version"],
                           new_p99_s=new_p99, baseline_p99_s=baseline)
