"""Telemetry layer: bounded time series over the runtime's counters.

The collector never reads a mutating field twice to compute a rate —
every source exposes a *monotonic counters snapshot* (``EngineStats
.snapshot``, ``CacheStats.snapshot``, ``HandleMetrics.snapshot``, the
admission/batcher stats dicts) and the collector diffs consecutive
snapshots into **interval deltas**. Deltas, not cumulative totals, are
what the calibrator and the knob controller consume: "this tick saw 40
requests at p99 9 ms and 3 sheds", not "1.2 M requests since boot".

Engine duck-typing: anything with ``latency_decomposition()`` and
``deployments`` works — both :class:`repro.core.engine.Engine` and
:class:`repro.shard.engine.ShardedEngine`; sharded extras (router,
admission) are picked up when present.

The raw counter surfaces are read through the unified
:class:`repro.obs.export.MetricsRegistry` (one collector per surface,
shared with the Prometheus/JSONL exporters) — the collector's job here
is the part the registry deliberately does not do: baselines, interval
deltas and bounded ring series.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.obs.export import registry_from_engine

__all__ = ["RingSeries", "MetricsCollector"]


def _jsonable(v):
    """Coerce numpy scalars/containers into plain JSON-serializable
    Python values (NaN stays NaN — json emits it and the consumers here
    treat it as 'no sample')."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, bool) or v is None or isinstance(v, str):
        return v
    if isinstance(v, int):
        return v
    if hasattr(v, "item"):      # numpy scalar
        return v.item()
    if isinstance(v, float):
        return v
    return v


class RingSeries:
    """Bounded ``(t, value)`` time series — the collector's storage unit.
    Appending beyond ``maxlen`` drops the oldest point (FIFO), so memory
    is O(maxlen) per metric no matter how long the plane runs."""

    __slots__ = ("t", "v")

    def __init__(self, maxlen: int = 512):
        self.t: Deque[float] = collections.deque(maxlen=maxlen)
        self.v: Deque[float] = collections.deque(maxlen=maxlen)

    def append(self, t: float, value: float) -> None:
        self.t.append(float(t))
        self.v.append(float(value))

    def __len__(self) -> int:
        return len(self.v)

    def last(self) -> Optional[float]:
        return self.v[-1] if self.v else None

    def values(self) -> List[float]:
        return list(self.v)

    def mean(self, n: Optional[int] = None) -> float:
        vals = list(self.v)[-n:] if n else list(self.v)
        return sum(vals) / len(vals) if vals else 0.0

    def to_json(self) -> Dict[str, List[float]]:
        return {"t": list(self.t), "v": list(self.v)}


# counter fields of a HandleMetrics/ShardedHandleMetrics snapshot the
# collector diffs into interval deltas (gauges like p99 are NOT diffed)
_HANDLE_COUNTERS = ("requests", "batches", "serve_s", "unknown_keys",
                    "shed_requests", "shed_batches")
_CACHE_COUNTERS = ("hits", "misses", "evictions", "invalidations",
                   "compile_seconds")


class MetricsCollector:
    """Samples the runtime into ring-buffer series + interval deltas.

    ``sample()`` returns one JSON-serializable sample dict (and appends
    the headline metrics to the named series); ``snapshot()`` returns
    the whole per-deployment state for export. The first ``sample()``
    establishes the baselines, so its deltas are the totals so far.
    """

    def __init__(self, engine, *, server=None, maxlen: int = 512):
        self.engine = engine
        self.server = server       # FeatureServer (its batcher), optional
        self.maxlen = maxlen
        # the same registry the Prometheus/JSONL exporters walk; the
        # collector reads its raw counter groups through it
        self.registry = registry_from_engine(engine, server=server)
        self.series: Dict[str, RingSeries] = {}
        self.samples: Deque[Dict[str, Any]] = collections.deque(maxlen=maxlen)
        self._prev_engine: Dict[str, float] = {}
        self._prev_cache: Dict[str, float] = {}
        self._prev_handles: Dict[str, Dict[str, float]] = {}
        self._prev_admission: Dict[str, float] = {}
        self._prev_batcher: Dict[str, float] = {}
        self._prev_decomp: Dict[str, float] = {}

    # ------------------------------------------------------------- sources
    def _engine_stats(self) -> Dict[str, float]:
        return self.registry.collect("engine")["engine"]

    def _cache_stats(self) -> Dict[str, float]:
        return self.registry.collect("cache")["cache"]

    # ----------------------------------------------------------- exporters
    def render_prometheus(self) -> str:
        """Prometheus text exposition over the shared registry."""
        return self.registry.render_prometheus()

    def render_jsonl(self, now: Optional[float] = None) -> str:
        """One JSON snapshot line over the shared registry."""
        return self.registry.render_jsonl(now)

    @staticmethod
    def _delta(now: Dict[str, float], prev: Dict[str, float],
               fields=None) -> Dict[str, float]:
        keys = fields if fields is not None else [
            k for k, v in now.items() if isinstance(v, (int, float))]
        return {k: max(now.get(k, 0) - prev.get(k, 0), 0) for k in keys
                if isinstance(now.get(k, 0), (int, float))}

    # -------------------------------------------------------------- sample
    def sample(self, now: Optional[float] = None) -> Dict[str, Any]:
        t = time.monotonic() if now is None else now
        eng = self.engine

        decomp = eng.latency_decomposition()
        eng_snap = self._engine_stats()
        eng_delta = self._delta(eng_snap, self._prev_engine)
        self._prev_engine = eng_snap

        cache_snap = self._cache_stats()
        cache_delta = self._delta(cache_snap, self._prev_cache,
                                  _CACHE_COUNTERS)
        self._prev_cache = cache_snap

        deployments: Dict[str, Dict[str, Any]] = {}
        for name, dep in getattr(eng, "deployments", {}).items():
            snap = dep.metrics.snapshot()
            prev = self._prev_handles.get(name, {})
            delta = self._delta(snap, prev, _HANDLE_COUNTERS)
            self._prev_handles[name] = snap
            joins = dep.join_staleness()     # {} for join-free plans
            deployments[name] = {"version": dep.version, "snapshot": snap,
                                 "delta": delta, "joins": joins}
            self._push(t, f"dep.{name}.p99_s",
                       snap.get("latency_p99_s", float("nan")))
            self._push(t, f"dep.{name}.requests", delta.get("requests", 0))
            for table, st in joins.items():
                self._push(t, f"dep.{name}.join.{table}.match_rate",
                           st.get("match_rate", 0.0))
                self._push(t, f"dep.{name}.join.{table}.age_p99",
                           st.get("age_p99", float("nan")))

        batcher: Optional[Dict[str, Any]] = None
        b = getattr(self.server, "batcher", None) if self.server else None
        if b is not None:
            stats = dict(b.stats)
            client_p99 = float("nan")
            if hasattr(b, "client_latency_percentile"):
                client_p99 = b.client_latency_percentile(99)
            batcher = {
                "queue_depth": b.queue_depth(),
                "oldest_age_s": b.oldest_age_s(),
                # queueing-INCLUSIVE latency the caller actually saw —
                # the serve-side p99 goes blind exactly when a queue
                # builds in front of the engine; this signal doesn't
                "client_p99_s": client_p99,
                "max_delay_s": b.cfg.max_delay_s,
                "max_batch": b.cfg.max_batch,
                "stats": stats,
                "delta": self._delta(stats, self._prev_batcher),
            }
            self._prev_batcher = stats
            self._push(t, "batcher.queue_depth", batcher["queue_depth"])
            self._push(t, "batcher.oldest_age_s", batcher["oldest_age_s"])
            self._push(t, "batcher.client_p99_s", client_p99)

        admission: Optional[Dict[str, Any]] = None
        res = getattr(eng, "resources", None)
        if res is not None:
            stats = res.metrics()
            admission = {"stats": stats,
                         "delta": self._delta(stats, self._prev_admission)}
            self._prev_admission = stats
            self._push(t, "admission.shed",
                       admission["delta"].get("shed_deadline", 0))
            self._push(t, "admission.shed_worker_down",
                       admission["delta"].get("shed_worker_down", 0))
            self._push(t, "admission.served_degraded",
                       admission["delta"].get("served_degraded", 0))

        router = getattr(eng, "router", None)
        if router is not None:
            self._push(t, "router.max_queue_depth",
                       max(router.queue_depths() or [0]))

        self._push(t, "engine.exec_s", eng_delta.get("exec_s", 0.0))
        self._push(t, "engine.kernel_launches",
                   eng_delta.get("kernel_launches", 0))
        self._push(t, "cache.hit_rate", cache_snap.get("hit_rate", 0.0))

        # durability / chaos tier (ShardedEngine only; keys absent on a
        # single Engine's decomposition). Counters are diffed into
        # per-tick deltas; replay lag is a gauge (last recovery's value)
        if "worker_restarts" in decomp:
            dd = self._delta(decomp, self._prev_decomp,
                             ("worker_restarts", "transport_retries",
                              "transport_frame_corrupt",
                              "transport_rpc_timeouts",
                              "recovery_wal_replayed_events"))
            self._prev_decomp = {
                k: decomp.get(k, 0) for k in
                ("worker_restarts", "transport_retries",
                 "transport_frame_corrupt", "transport_rpc_timeouts",
                 "recovery_wal_replayed_events")}
            self._push(t, "engine.worker_restarts",
                       dd.get("worker_restarts", 0))
            self._push(t, "transport.retries",
                       dd.get("transport_retries", 0))
            self._push(t, "transport.frame_corrupt",
                       dd.get("transport_frame_corrupt", 0))
            self._push(t, "recovery.wal_replay_lag_s",
                       decomp.get("recovery_wal_replay_lag_s", 0.0))

        sample = _jsonable({
            "t": t,
            "latency_decomposition": decomp,
            "engine": eng_snap, "engine_delta": eng_delta,
            "cache": cache_snap, "cache_delta": cache_delta,
            "deployments": deployments,
            "batcher": batcher,
            "admission": admission,
        })
        self.samples.append(sample)
        return sample

    def _push(self, t: float, name: str, value) -> None:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = RingSeries(self.maxlen)
        try:
            s.append(t, float(value))
        except (TypeError, ValueError):
            pass

    # ------------------------------------------------------------ snapshot
    def last(self) -> Optional[Dict[str, Any]]:
        return self.samples[-1] if self.samples else None

    def snapshot(self) -> Dict[str, Any]:
        """Full JSON-serializable export: every series plus the latest
        sample (per-deployment)."""
        return {
            "series": {k: s.to_json() for k, s in self.series.items()},
            "latest": self.last(),
            "n_samples": len(self.samples),
        }
