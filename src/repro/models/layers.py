"""Primitive layers: norms, rotary embeddings (incl. M-RoPE), MLPs.

Conventions: params are dicts of arrays; ``init_*`` takes a PRNG key and
returns params in ``cfg.param_dtype``; compute runs in ``cfg.dtype`` with
f32 accumulation where it matters (norm statistics, softmax, loss).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_norm", "apply_norm", "rope_freqs", "apply_rope",
           "mrope_positions_text", "init_mlp", "apply_mlp", "init_linear",
           "apply_linear", "init_embedding"]

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(d: int, kind: str, dtype) -> Params:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: Params, x: jax.Array, kind: str,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(f"unknown norm {kind!r}")
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies (head_dim//2,) in f32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope_sections: Optional[Tuple[int, int, int]] = None
               ) -> jax.Array:
    """Rotate q or k. x (..., S, H, D); positions (..., S) int32 for
    standard RoPE, or (3, ..., S) for M-RoPE (temporal/height/width id
    streams; Qwen2-VL §2.1). ``mrope_sections`` gives the number of
    frequency PAIRS driven by each stream (sums to D/2)."""
    D = x.shape[-1]
    inv = rope_freqs(D, theta)                      # (D/2,)
    if mrope_sections is None:
        ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, D/2)
    else:
        assert positions.shape[0] == 3, "M-RoPE wants (3, ..., S) positions"
        secs = mrope_sections
        assert sum(secs) == D // 2, (secs, D)
        parts = []
        off = 0
        for s_i, sec in enumerate(secs):
            p = positions[s_i][..., None].astype(jnp.float32)  # (..., S, 1)
            parts.append(p * inv[off:off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)       # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def mrope_positions_text(positions: jax.Array) -> jax.Array:
    """Text-only M-RoPE: all three id streams equal the text position."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------

def init_linear(key, d_in: int, d_out: int, dtype,
                bias: bool = False) -> Params:
    w = jax.random.normal(key, (d_in, d_out), jnp.float32)
    w = w * (1.0 / math.sqrt(d_in))
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def apply_linear(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_mlp(key, d: int, d_ff: int, act: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {"wi": init_linear(ks[0], d, d_ff, dtype)["w"],
                "wg": init_linear(ks[1], d, d_ff, dtype)["w"],
                "wo": init_linear(ks[2], d_ff, d, dtype)["w"]}
    return {"wi": init_linear(ks[0], d, d_ff, dtype)["w"],
            "wo": init_linear(ks[2], d_ff, d, dtype)["w"]}


def apply_mlp(p: Params, x: jax.Array, act: str) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    if act == "swiglu":
        g = x @ p["wg"].astype(x.dtype)
        h = jax.nn.silu(g) * h
    elif act == "geglu":
        g = x @ p["wg"].astype(x.dtype)
        h = jax.nn.gelu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown act {act!r}")
    return h @ p["wo"].astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)
            * (1.0 / math.sqrt(d))).astype(dtype)
