"""Mamba-2 (SSD — state-space duality) mixer block.

Chunked "matrix transformer" form of the SSD recurrence
(arXiv:2405.21060 §6): within chunks of length Q the output is a masked
(C·Bᵀ ⊙ decay) attention-like product; across chunks a tiny sequential
scan carries the (H, N, P) states. Chunking keeps the lowered HLO small
(one fori step per chunk) and the working set VMEM-friendly, which is what
lets the 500k-token decode shape compile: decode is a pure O(1) recurrent
state update, no sequence-length tensor at all.

Layout: x (B, L, H, P) heads×headdim; B/C (B, L, G, N) groups broadcast to
heads; a = Δt·A (B, L, H) log-decays (A < 0).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import apply_norm, init_linear, init_norm

Params = Dict[str, jax.Array]

__all__ = ["init_mamba", "mamba_train", "mamba_decode", "init_mamba_cache",
           "ssd_chunked"]


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., Q) -> (..., Q, Q) with S[q, k] = sum_{j=k+1..q} a_j for
    q >= k, -inf elsewhere (decay exponents within a chunk)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    qi = jnp.arange(Q)
    mask = qi[:, None] >= qi[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, Bm: jax.Array, Cm: jax.Array,
                chunk: int, h0: jax.Array = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Run the SSD recurrence h_t = e^{a_t} h_{t-1} + B_t x_tᵀ,
    y_t = C_t·h_t over a full sequence.

    x (B, L, H, P); a (B, L, H); Bm/Cm (B, L, G, N). Returns
    (y (B, L, H, P), final_state (B, H, N, P)).
    """
    B_, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    L_orig = L
    if L % Q:
        # pad tail: x/B zeros and a=0 (decay 1) leave the state untouched
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nc = L // Q
    Bh = jnp.repeat(Bm, rep, axis=2)         # (B, L, H, N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    xr = x.reshape(B_, nc, Q, H, P)
    ar = a.reshape(B_, nc, Q, H).astype(jnp.float32)
    Br = Bh.reshape(B_, nc, Q, H, N)
    Cr = Ch.reshape(B_, nc, Q, H, N)

    a_cum = jnp.cumsum(ar, axis=2)                         # (B, nc, Q, H)
    # ---- intra-chunk (dual / attention-like form) -----------------------
    Lmat = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))      # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cr.astype(jnp.float32),
                        Br.astype(jnp.float32)) * Lmat
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores,
                        xr.astype(jnp.float32))

    # ---- chunk boundary states -----------------------------------------
    decay_states = jnp.exp(a_cum[:, :, -1:, :] - a_cum)    # (B, nc, Q, H)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp",
                        Br.astype(jnp.float32), decay_states,
                        xr.astype(jnp.float32))            # (B, nc, H, N, P)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])              # (B, nc, H)

    def scan_fn(carry, inp):
        st, dec = inp                                      # (B,H,N,P),(B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                  # emit state BEFORE

    init = (jnp.zeros((B_, H, N, P), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    final, carried = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    carried = carried.transpose(1, 0, 2, 3, 4)             # (B, nc, H, N, P)

    # ---- inter-chunk contribution ---------------------------------------
    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp",
                       Cr.astype(jnp.float32), carried, jnp.exp(a_cum))
    y = (y_diag + y_off).reshape(B_, L, H, P).astype(x.dtype)
    return y[:, :L_orig], final.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Full Mamba-2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    nh = s.nheads(cfg.d_model)
    conv_ch = d_in + 2 * s.ngroups * s.d_state
    return s, d_in, nh, conv_ch


def init_mamba(key, cfg: ModelConfig) -> Params:
    s, d_in, nh, conv_ch = _dims(cfg)
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    d_proj = 2 * d_in + 2 * s.ngroups * s.d_state + nh
    p = {
        "in_proj": init_linear(ks[0], d, d_proj, dtype)["w"],
        "conv_w": (jax.random.normal(ks[1], (s.conv_kernel, conv_ch),
                                     jnp.float32)
                   * (1.0 / math.sqrt(s.conv_kernel))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": init_norm(d_in, "rmsnorm", dtype),
        "out_proj": init_linear(ks[2], d_in, d, dtype)["w"],
    }
    return p


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s, d_in, nh, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    z, xc, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, xc, Bc, Cc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. xbc (B, L, C); w (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for k in range(K):     # K is 4 — unrolled taps beat conv lowering here
        out = out + pad[:, k:k + xbc.shape[1], :].astype(jnp.float32) \
            * w[K - 1 - k].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(xbc.dtype)


def mamba_train(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B, L, d) -> (B, L, d)."""
    s, d_in, nh, conv_ch = _dims(cfg)
    B_, L, d = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xc, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xc, Bc, Cc], axis=-1)           # (B, L, conv_ch)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xc, Bc, Cc = jnp.split(xbc, [d_in, d_in + s.ngroups * s.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, L, nh)
    A = -jnp.exp(p["A_log"])                                # (nh,) negative
    xh = xc.reshape(B_, L, nh, s.headdim)
    Bm = Bc.reshape(B_, L, s.ngroups, s.d_state)
    Cm = Cc.reshape(B_, L, s.ngroups, s.d_state)
    y, _ = ssd_chunked((xh.astype(jnp.float32)
                        * dt[..., None]).astype(x.dtype),
                       dt * A, Bm, Cm, s.chunk)
    y = y + xh * p["D"][:, None].astype(x.dtype)
    y = y.reshape(B_, L, d_in)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ p["out_proj"].astype(x.dtype)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    s, d_in, nh, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_kernel - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, nh, s.d_state, s.headdim), jnp.float32),
    }


def mamba_decode(p: Params, x: jax.Array, cfg: ModelConfig,
                 cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One token: x (B, d) -> (y (B, d), new cache). O(1) in context len."""
    s, d_in, nh, conv_ch = _dims(cfg)
    B_, d = x.shape
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xc, Bc, Cc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xc, Bc, Cc], axis=-1)            # (B, conv_ch)
    window = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)
    # window is oldest->newest; _causal_conv applies w[m] to the input m
    # steps back, so the taps must be reversed here
    conv_out = jnp.sum(window.astype(jnp.float32)
                       * p["conv_w"][::-1].astype(jnp.float32), axis=1)
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)
                      ).astype(x.dtype)
    new_conv = window[:, 1:, :]
    xc, Bc, Cc = jnp.split(xbc, [d_in, d_in + s.ngroups * s.d_state],
                           axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                    # (B, nh)
    xh = xc.reshape(B_, nh, s.headdim).astype(jnp.float32)
    rep = nh // s.ngroups
    Bm = jnp.repeat(Bc.reshape(B_, s.ngroups, s.d_state), rep, 1)  # (B,nh,N)
    Cm = jnp.repeat(Cc.reshape(B_, s.ngroups, s.d_state), rep, 1)
    # h (B, nh, N, P)
    h = cache["ssm"] * da[:, :, None, None] + \
        (dt[:, :, None] * Bm)[..., None] * xh[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * p["D"][:, None]
    y = y.reshape(B_, d_in).astype(x.dtype)
    y = apply_norm(p["norm"], y * jax.nn.silu(z), "rmsnorm")
    return y @ p["out_proj"].astype(x.dtype), {"conv": new_conv, "ssm": h}
