"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

FLOP-honest TPU MoE (MegaBlocks/MaxText-style "dropping" implementation):
token→expert assignments are sorted by expert, each expert processes a
fixed-capacity ``(E, cap, d)`` slab via one grouped einsum, and outputs are
combined with the (renormalised) router gates. Compute is
``E·cap·d·ff ≈ top_k·T·cf·d·ff`` — the *active* parameter FLOPs, not the
dense all-experts product, so the roofline analysis sees the real MoE
arithmetic intensity. Overflowing tokens are dropped (capacity_factor
bounds the imbalance); dropped tokens pass through the residual stream
(and the shared experts, if configured).

The expert dimension E is a real array axis, shardable for expert
parallelism (see distributed/sharding.py).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import apply_mlp, init_linear, init_mlp

Params = Dict[str, jax.Array]

__all__ = ["init_moe", "apply_moe", "expert_capacity"]


def expert_capacity(n_tokens: int, moe: MoEConfig) -> int:
    cap = int(math.ceil(n_tokens * moe.top_k * moe.capacity_factor
                        / moe.num_experts))
    return max(8, ((cap + 7) // 8) * 8)   # pad to lane-friendly multiple


def init_moe(key, cfg: ModelConfig) -> Params:
    moe = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3 + moe.num_shared)
    router = init_linear(ks[0], d, moe.num_experts, dtype)["w"]

    def stack_mlps(key, n, dff):
        keys = jax.random.split(key, n)
        ps = [init_mlp(k, d, dff, cfg.act, dtype) for k in keys]
        return {name: jnp.stack([p[name] for p in ps])
                for name in ps[0]}

    p: Params = {"router": router,
                 "experts": stack_mlps(ks[1], moe.num_experts,
                                       moe.d_ff_expert)}
    if moe.num_shared:
        p["shared"] = stack_mlps(ks[2], moe.num_shared, moe.d_ff_expert)
    return p


def _expert_ffn(experts: Params, x: jax.Array, act: str) -> jax.Array:
    """x (E, cap, d) through per-expert MLPs (E, d, ff)/(E, ff, d)."""
    h = jnp.einsum("ecd,edf->ecf", x, experts["wi"].astype(x.dtype))
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", x, experts["wg"].astype(x.dtype))
        h = (jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, experts["wo"].astype(x.dtype))


def _dispatch_group(p: Params, xf: jax.Array, cfg: ModelConfig, cap: int):
    """Sort-based capacity dispatch for one token group ``xf (t, d)``.

    Returns (y (t, d), aux-metric tuple). Every op here is local to the
    group — when the caller vmaps over DP-shard-aligned groups, no op
    crosses a data shard, so the lowered program has NO dispatch
    collectives (vs a global argsort over all tokens, which all-gathers
    the token stream — mixtral train baseline, EXPERIMENTS.md §Perf).
    """
    moe = cfg.moe
    t, d = xf.shape
    E, K = moe.num_experts, moe.top_k

    logits = (xf.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))          # (t, E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                  # (t, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # ---- sort assignments by expert -----------------------------------
    expert_flat = eidx.reshape(-1)                         # (t*K,)
    token_flat = jnp.repeat(jnp.arange(t, dtype=jnp.int32), K)
    gate_flat = gates.reshape(-1)
    order = jnp.argsort(expert_flat, stable=True)
    se = expert_flat[order]
    st = token_flat[order]
    sg = gate_flat[order]

    counts = jnp.bincount(expert_flat, length=E)           # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = (jnp.arange(t * K, dtype=jnp.int32)
                - starts[se].astype(jnp.int32))

    keep = pos_in_e < cap
    dst_e = jnp.where(keep, se, E)          # E = out-of-bounds -> dropped
    dst_c = jnp.where(keep, pos_in_e, 0)

    disp = jnp.zeros((E, cap, d), xf.dtype)
    disp = disp.at[dst_e, dst_c].set(xf[st])               # OOB writes drop

    out_e = _expert_ffn(p["experts"], disp, cfg.act)       # (E, cap, d)

    gathered = out_e[jnp.minimum(dst_e, E - 1), dst_c]     # (t*K, d)
    weighted = gathered * (sg * keep.astype(sg.dtype)
                           )[:, None].astype(gathered.dtype)
    y = jnp.zeros((t, d), xf.dtype).at[st].add(weighted)

    # ---- aux losses (Switch-style load balance + router z-loss) --------
    f = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    pmean = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * pmean)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = jnp.sum(1.0 - keep.astype(jnp.float32)) / (t * K)
    return y, (aux.astype(jnp.float32), z.astype(jnp.float32), dropped)


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B, S, d) -> (y (B, S, d), aux metrics incl. load-balance loss).

    ``cfg.moe_groups > 1`` splits the token stream into that many
    DP-shard-aligned groups with per-group capacity (standard per-shard
    capacity semantics); dispatch then stays local to each data shard.
    """
    moe = cfg.moe
    B, S, d = x.shape
    T = B * S
    G = max(cfg.moe_groups, 1)
    if T % G or (B % G and G > 1):
        G = 1
    xf = x.reshape(T, d)

    if G == 1:
        cap = expert_capacity(T, moe)
        y, (aux, z, dropped) = _dispatch_group(p, xf, cfg, cap)
    else:
        cap = expert_capacity(T // G, moe)
        xg = xf.reshape(G, T // G, d)
        y, (aux_g, z_g, drop_g) = jax.vmap(
            lambda xx: _dispatch_group(p, xx, cfg, cap))(xg)
        y = y.reshape(T, d)
        aux, z = jnp.mean(aux_g), jnp.mean(z_g)
        dropped = jnp.mean(drop_g)

    if moe.num_shared:
        sh = p["shared"]
        for i in range(moe.num_shared):
            one = {k: v[i] for k, v in sh.items()}
            y = y + apply_mlp(one, xf, cfg.act)

    metrics = {"moe_aux": aux, "moe_zloss": z, "moe_drop_frac": dropped}
    return y.reshape(B, S, d), metrics
