"""Decoder-only language model: embed → stacked blocks → norm → logits.

Entry points used by the launcher and serving runtime:

    init_lm(key, cfg)                        -> params
    forward_train(params, cfg, tokens, ...)  -> logits
    loss_fn(params, cfg, batch)              -> (loss, metrics)
    prefill(params, cfg, tokens, cache_len)  -> (last_logits, caches)
    decode_step(params, cfg, caches, token, position) -> (logits, caches)
    init_cache(cfg, batch, cache_len)        -> concrete cache pytree

[vlm]/[audio] archs prepend stub frontend embeddings (precomputed patch /
frame vectors, per the assignment) to the token embeddings; loss is masked
to token positions.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models.layers import (apply_norm, init_embedding, init_norm,
                                 mrope_positions_text)

Params = Dict[str, Any]

__all__ = ["init_lm", "forward_train", "loss_fn", "prefill", "decode_step",
           "init_cache", "cache_specs"]


def init_lm(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "blocks": B.init_stacked_blocks(ks[1], cfg),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_embedding(ks[2], cfg.vocab_size, cfg.d_model,
                                      dtype)
    return p


def _positions(cfg: ModelConfig, B_: int, S: int, offset: int = 0):
    pos = jnp.arange(S, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (B_, S))
    if cfg.mrope_sections is not None:
        return mrope_positions_text(pos)
    return pos


def _embed_inputs(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  embeds: Optional[jax.Array]) -> jax.Array:
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(cfg.compute_dtype), x], axis=1)
    return x


def _logits(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return x.astype(jnp.float32) @ head.astype(jnp.float32).T


def forward_train(params: Params, cfg: ModelConfig, tokens: jax.Array,
                  embeds: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """tokens (B, S_txt) [+ embeds (B, F, d)] -> logits (B, S, V)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    B_, S = x.shape[:2]
    pos = _positions(cfg, B_, S)
    x, aux = B.run_blocks_train(params["blocks"], x, cfg, pos)
    return _logits(params, cfg, x), aux


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (f32), MoE aux losses folded in.

    batch: tokens (B, S_txt), targets (B, S_txt) with -100 = masked,
    optional embeds (B, F, d_model).
    """
    tokens = batch["tokens"]
    targets = batch["targets"]
    embeds = batch.get("embeds")
    logits, aux = forward_train(params, cfg, tokens, embeds)
    if embeds is not None:
        logits = logits[:, embeds.shape[1]:, :]   # loss on text positions
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    metrics = {"nll": loss}
    if cfg.moe is not None:
        loss = (loss + cfg.moe.aux_coef * aux["moe_aux"] / cfg.n_layers
                + cfg.moe.router_z_coef * aux["moe_zloss"] / cfg.n_layers)
        metrics.update(aux)
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array,
            cache_len: int, embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, Tuple]:
    x = _embed_inputs(params, cfg, tokens, embeds)
    B_, S = x.shape[:2]
    pos = _positions(cfg, B_, S)
    x, caches = B.run_blocks_prefill(params["blocks"], x, cfg, pos,
                                     cache_len)
    return _logits(params, cfg, x[:, -1:, :])[:, 0, :], caches


def decode_step(params: Params, cfg: ModelConfig, caches: Tuple,
                token: jax.Array, position: jax.Array
                ) -> Tuple[jax.Array, Tuple]:
    """token (B,) int32; position (B,) int32 -> (logits (B, V), caches)."""
    x = params["embed"].astype(cfg.compute_dtype)[token]   # (B, d)
    x, caches = B.run_blocks_decode(params["blocks"], x, cfg, caches,
                                    position)
    return _logits(params, cfg, x[:, None, :])[:, 0, :], caches


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Tuple:
    """Concrete zero caches, stacked to match the scan layout."""
    pattern = B.normalize_pattern(cfg)
    reps = cfg.n_layers // len(pattern)
    dtype = cfg.compute_dtype
    out = []
    for token in pattern:
        one = B.init_block_cache(cfg, token, batch, cache_len, dtype)
        out.append(jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), one))
    return tuple(out)


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int) -> Tuple:
    """ShapeDtypeStruct cache pytree (dry-run: no allocation)."""
    concrete = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
    return concrete
