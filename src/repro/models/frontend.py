"""Modality frontend STUBS for [vlm]/[audio] architectures.

Per the assignment, the transformer BACKBONE is the deliverable; the
vision/audio encoder is a stub whose job is to produce *precomputed*
patch/frame embeddings with the right shapes and deterministic content.
``input_specs()`` (configs/base.py) already advertises the embedding
inputs; these helpers materialize concrete ones for smoke tests, examples
and the serving driver.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["stub_vision_embeddings", "stub_audio_frames", "stub_frontend"]


def stub_vision_embeddings(key, cfg: ModelConfig, batch: int,
                           n_patches: Optional[int] = None,
                           image_hw: Tuple[int, int] = (224, 224)
                           ) -> jax.Array:
    """Precomputed ViT patch embeddings (B, P, d_model), unit RMS.

    Dynamic resolution (qwen2-vl): ``n_patches`` defaults to the 14x14
    patch grid of ``image_hw``; callers may pass any count — the backbone
    is resolution-agnostic because M-RoPE positions are supplied per token.
    """
    if n_patches is None:
        n_patches = (image_hw[0] // 14) * (image_hw[1] // 14)
    x = jax.random.normal(key, (batch, n_patches, cfg.d_model), jnp.float32)
    return (x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
            ).astype(cfg.compute_dtype)


def stub_audio_frames(key, cfg: ModelConfig, batch: int,
                      n_frames: Optional[int] = None,
                      seconds: float = 5.0, frame_hz: float = 50.0
                      ) -> jax.Array:
    """Precomputed fbank-encoder frame embeddings (B, T, d_model)."""
    if n_frames is None:
        n_frames = int(seconds * frame_hz)
    x = jax.random.normal(key, (batch, n_frames, cfg.d_model), jnp.float32)
    return (x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-6)
            ).astype(cfg.compute_dtype)


def stub_frontend(key, cfg: ModelConfig, batch: int,
                  n_positions: Optional[int] = None) -> Optional[jax.Array]:
    """Dispatch on ``cfg.frontend``; None for text-only models."""
    if cfg.frontend is None:
        return None
    n = n_positions or cfg.frontend_len
    if cfg.frontend == "vision":
        return stub_vision_embeddings(key, cfg, batch, n)
    if cfg.frontend == "audio":
        return stub_audio_frames(key, cfg, batch, n)
    raise ValueError(f"unknown frontend {cfg.frontend!r}")
