"""Encoder-decoder backbone (Seamless-M4T medium class).

Per the assignment the modality frontend is a STUB: the encoder consumes
precomputed audio-frame embeddings ``(B, S_enc, d_model)`` directly.
Encoder: bidirectional attention + MLP. Decoder: causal self-attention
(KV-cached for serving) + cross-attention over encoder memory + MLP.
Both stacks are layer-stacked and scanned like the decoder-only models.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (apply_mlp, apply_norm, init_embedding,
                                 init_mlp, init_norm)

Params = Dict[str, Any]

__all__ = ["init_encdec", "encode", "forward_train", "loss_fn",
           "dec_prefill", "dec_decode_step", "init_dec_cache"]


def _init_enc_block(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {
        "norm1": init_norm(cfg.d_model, cfg.norm, dtype),
        "attn": attn.init_attention(ks[0], cfg),
        "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _init_dec_block(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {
        "norm1": init_norm(cfg.d_model, cfg.norm, dtype),
        "self_attn": attn.init_attention(ks[0], cfg),
        "norm_x": init_norm(cfg.d_model, cfg.norm, dtype),
        "cross_attn": attn.init_cross_attention(ks[1], cfg),
        "norm2": init_norm(cfg.d_model, cfg.norm, dtype),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _stack(key, init_one, n: int) -> Params:
    keys = jax.random.split(key, n)
    ps = [init_one(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ps)


def init_encdec(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "enc_blocks": _stack(ks[0], lambda k: _init_enc_block(k, cfg),
                             cfg.encoder_layers),
        "enc_norm": init_norm(cfg.d_model, cfg.norm, dtype),
        "embed": init_embedding(ks[1], cfg.vocab_size, cfg.d_model, dtype),
        "dec_blocks": _stack(ks[2], lambda k: _init_dec_block(k, cfg),
                             cfg.n_layers),
        "final_norm": init_norm(cfg.d_model, cfg.norm, dtype),
    }


def _run_stack(scan_fn, x, stacked, cfg: ModelConfig):
    """lax.scan over stacked blocks, or a python loop when unrolled
    (``cfg.scan_layers=False``, dry-run cost measurement)."""
    if not cfg.scan_layers:
        reps = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        ys = []
        for i in range(reps):
            x, y = scan_fn(x, jax.tree_util.tree_map(
                lambda a: a[i], stacked))
            ys.append(y)
        if ys and ys[0] is not None:
            return x, jax.tree_util.tree_map(lambda *s: jnp.stack(s), *ys)
        return x, None
    return jax.lax.scan(scan_fn, x, stacked)


def encode(params: Params, cfg: ModelConfig,
           enc_embeds: jax.Array) -> jax.Array:
    x = enc_embeds.astype(cfg.compute_dtype)
    B_, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B_, S))

    def scan_fn(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attn.attend_train(p["attn"], h, cfg, pos, causal=False)
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + apply_mlp(p["mlp"], h, cfg.act)
        return x, None

    x, _ = _run_stack(scan_fn, x, params["enc_blocks"], cfg)
    return apply_norm(params["enc_norm"], x, cfg.norm)


def _dec_blocks_train(params: Params, cfg: ModelConfig, x: jax.Array,
                      enc_out: jax.Array, pos: jax.Array) -> jax.Array:
    def scan_fn(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attn.attend_train(p["self_attn"], h, cfg, pos, causal=True)
        h = apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attn.cross_attend(p["cross_attn"], h, enc_out, cfg)
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + apply_mlp(p["mlp"], h, cfg.act)
        return x, None

    x, _ = _run_stack(scan_fn, x, params["dec_blocks"], cfg)
    return x


def _logits(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x.astype(jnp.float32) @ params["embed"].astype(jnp.float32).T


def forward_train(params: Params, cfg: ModelConfig, enc_embeds: jax.Array,
                  tokens: jax.Array) -> jax.Array:
    enc_out = encode(params, cfg, enc_embeds)
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    B_, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B_, S))
    x = _dec_blocks_train(params, cfg, x, enc_out, pos)
    return _logits(params, cfg, x)


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array]
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = forward_train(params, cfg, batch["enc_embeds"],
                           batch["tokens"])
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    loss = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "nll": loss}


# ---------------------------------------------------------------------------
# Serving (decoder incremental; encoder memory fixed)
# ---------------------------------------------------------------------------

def init_dec_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Params:
    dtype = cfg.compute_dtype
    hkv, hd = cfg.n_kv_heads, cfg.hd
    one = {"k": jnp.zeros((batch, cache_len, hkv, hd), dtype),
           "v": jnp.zeros((batch, cache_len, hkv, hd), dtype)}
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), one)


def dec_prefill(params: Params, cfg: ModelConfig, enc_out: jax.Array,
                tokens: jax.Array, cache_len: int
                ) -> Tuple[jax.Array, Params]:
    x = params["embed"].astype(cfg.compute_dtype)[tokens]
    B_, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B_, S))

    def scan_fn(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm)
        o, cache = attn.attend_prefill(p["self_attn"], h, cfg, pos,
                                       cache_len)
        x = x + o
        h = apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attn.cross_attend(p["cross_attn"], h, enc_out, cfg)
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + apply_mlp(p["mlp"], h, cfg.act)
        return x, cache

    x, caches = _run_stack(scan_fn, x, params["dec_blocks"], cfg)
    return _logits(params, cfg, x[:, -1:, :])[:, 0, :], caches


def dec_decode_step(params: Params, cfg: ModelConfig, enc_out: jax.Array,
                    caches: Params, token: jax.Array, position: jax.Array
                    ) -> Tuple[jax.Array, Params]:
    x = params["embed"].astype(cfg.compute_dtype)[token]   # (B, d)

    def scan_fn(x, inp):
        p, cache = inp
        h = apply_norm(p["norm1"], x, cfg.norm)
        o, cache = attn.attend_decode(p["self_attn"], h, cfg, cache,
                                      position)
        x = x + o
        h = apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attn.cross_attend_decode(p["cross_attn"], h, enc_out, cfg)
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + apply_mlp(p["mlp"], h[:, None, :], cfg.act)[:, 0, :]
        return x, cache

    x, caches = _run_stack(scan_fn, x, (params["dec_blocks"], caches), cfg)
    return _logits(params, cfg, x[:, None, :])[:, 0, :], caches
