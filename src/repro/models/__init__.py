"""Composable model zoo: decoder-only / hybrid / MoE / enc-dec backbones.

Pure-functional JAX: params are nested dicts of arrays, every module is an
``init_*(key, cfg) -> params`` plus an ``apply``-style function. Layers are
stacked along a leading axis and iterated with ``lax.scan`` so the lowered
HLO stays small enough to compile 56-layer models on the 512-device
dry-run mesh.
"""
__all__ = ["lm", "encdec", "attention", "moe", "ssm", "layers", "blocks"]
