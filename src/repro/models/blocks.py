"""Decoder blocks + scan-over-layers stacking.

Block kinds are two-character tokens ``<mixer><ffn>``:

    mixer:  'a' = GQA attention, 'm' = Mamba-2 SSD
    ffn:    'd' = dense MLP, 'e' = MoE, '-' = none (pure mixer block)

e.g. qwen2 = ('ad',), mamba2 = ('m-',), mixtral = ('ae',), jamba's period-8
pattern = ('md','me','md','me','ad','me','md','me').

Layers are stacked: for a pattern of period R over L layers, the params of
pattern position r are stacked along a leading ``L/R`` axis and the whole
model body is ONE ``lax.scan`` over super-blocks of R layers. This keeps
the lowered HLO size independent of depth — required for the 56-layer
Mixtral dry-run to compile quickly on 512 host devices — and is also the
idiomatic TPU training layout (weight-stationary pipelining falls out of
the same stacking).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm

Params = Dict[str, Any]

__all__ = ["init_block", "init_stacked_blocks", "run_blocks_train",
           "run_blocks_prefill", "run_blocks_decode", "init_block_cache",
           "normalize_pattern"]


def normalize_pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    """Expand legacy one-char tokens to <mixer><ffn> form."""
    out = []
    for t in cfg.pattern:
        if len(t) == 1:
            if t == "a":
                out.append("ae" if cfg.moe else "ad")
            elif t == "m":
                out.append("m-")
            else:
                raise ValueError(f"bad pattern token {t!r}")
        else:
            out.append(t)
    return tuple(out)


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, token: str) -> Params:
    mixer, ffn = token[0], token[1]
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": init_norm(cfg.d_model, cfg.norm, dtype)}
    if mixer == "a":
        p["attn"] = attn.init_attention(ks[0], cfg)
    else:
        p["mamba"] = ssm.init_mamba(ks[0], cfg)
    if ffn == "d":
        p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype)
    elif ffn == "e":
        p["norm2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    return p


def _ffn_apply(p: Params, x: jax.Array, cfg: ModelConfig, token: str
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    ffn = token[1]
    metrics: Dict[str, jax.Array] = {}
    if ffn == "-":
        return x, metrics
    h = apply_norm(p["norm2"], x, cfg.norm)
    if ffn == "d":
        y = apply_mlp(p["mlp"], h, cfg.act)
    else:
        y, metrics = moe_mod.apply_moe(p["moe"], h, cfg)
    return x + y, metrics


def block_train(p: Params, x: jax.Array, cfg: ModelConfig, token: str,
                positions: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    h = apply_norm(p["norm1"], x, cfg.norm)
    if token[0] == "a":
        x = x + attn.attend_train(p["attn"], h, cfg, positions)
    else:
        x = x + ssm.mamba_train(p["mamba"], h, cfg)
    return _ffn_apply(p, x, cfg, token)


def block_prefill(p: Params, x: jax.Array, cfg: ModelConfig, token: str,
                  positions: jax.Array, cache_len: int
                  ) -> Tuple[jax.Array, Params]:
    h = apply_norm(p["norm1"], x, cfg.norm)
    if token[0] == "a":
        o, cache = attn.attend_prefill(p["attn"], h, cfg, positions,
                                       cache_len)
        x = x + o
    else:
        # prefill == train pass that keeps the final SSD/conv state
        s, d_in, nh, conv_ch = ssm._dims(cfg)
        B_, L, d = h.shape
        zxbcdt = h @ p["mamba"]["in_proj"].astype(h.dtype)
        z, xc, Bc, Cc, dt = ssm._split_proj(cfg, zxbcdt)
        xbc = jnp.concatenate([xc, Bc, Cc], axis=-1)
        conv_tail = xbc[:, -(s.conv_kernel - 1):, :]
        xbc = ssm._causal_conv(xbc, p["mamba"]["conv_w"],
                               p["mamba"]["conv_b"])
        xc, Bc, Cc = jnp.split(xbc, [d_in, d_in + s.ngroups * s.d_state],
                               axis=-1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["mamba"]["dt_bias"])
        A = -jnp.exp(p["mamba"]["A_log"])
        xh = xc.reshape(B_, L, nh, s.headdim)
        Bm = Bc.reshape(B_, L, s.ngroups, s.d_state)
        Cm = Cc.reshape(B_, L, s.ngroups, s.d_state)
        y, hfin = ssm.ssd_chunked(
            (xh.astype(jnp.float32) * dtv[..., None]).astype(h.dtype),
            dtv * A, Bm, Cm, s.chunk)
        y = y + xh * p["mamba"]["D"][:, None].astype(h.dtype)
        y = y.reshape(B_, L, d_in)
        y = apply_norm(p["mamba"]["norm"], y * jax.nn.silu(z), "rmsnorm")
        x = x + y @ p["mamba"]["out_proj"].astype(h.dtype)
        # (B, nh, N, P) -> store transposed to decode layout (B,nh,N,P)
        cache = {"conv": conv_tail, "ssm": hfin}
    x, _ = _ffn_apply(p, x, cfg, token)
    return x, cache


def block_decode(p: Params, x: jax.Array, cfg: ModelConfig, token: str,
                 cache: Params, position: jax.Array
                 ) -> Tuple[jax.Array, Params]:
    """x (B, d) single token."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if token[0] == "a":
        o, cache = attn.attend_decode(p["attn"], h, cfg, cache, position)
        x = x + o
    else:
        o, cache = ssm.mamba_decode(p["mamba"], h, cfg, cache)
        x = x + o
    x2, _ = _ffn_apply(p, x[:, None, :], cfg, token)
    return x2[:, 0, :], cache


def init_block_cache(cfg: ModelConfig, token: str, batch: int,
                     cache_len: int, dtype) -> Params:
    if token[0] == "a":
        S = cache_len
        if cfg.sliding_window:
            S = min(cache_len, cfg.sliding_window)  # rolling ring
        hkv, hd = cfg.n_kv_heads, cfg.hd
        return {"k": jnp.zeros((batch, S, hkv, hd), dtype),
                "v": jnp.zeros((batch, S, hkv, hd), dtype)}
    return ssm.init_mamba_cache(cfg, batch, dtype)


# ---------------------------------------------------------------------------
# Stacked layers + scan
# ---------------------------------------------------------------------------

def init_stacked_blocks(key, cfg: ModelConfig) -> Tuple[Params, ...]:
    """Returns per-pattern-position stacked params: tuple of length R,
    each a pytree with leading axis reps = n_layers / R."""
    pattern = normalize_pattern(cfg)
    R = len(pattern)
    reps = cfg.n_layers // R
    out = []
    for r, token in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, r), reps)
        ps = [init_block(k, cfg, token) for k in keys]
        out.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *ps))
    return tuple(out)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return fn


def _layer_slice(stacked, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], stacked)


def run_blocks_train(stacked: Tuple[Params, ...], x: jax.Array,
                     cfg: ModelConfig, positions: jax.Array
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    pattern = normalize_pattern(cfg)

    def superblock(x, layer_params):
        aux = jnp.zeros((), jnp.float32)
        zl = jnp.zeros((), jnp.float32)
        for r, token in enumerate(pattern):
            x, m = block_train(layer_params[r], x, cfg, token, positions)
            if "moe_aux" in m:
                aux = aux + m["moe_aux"]
                zl = zl + m["moe_zloss"]
        return x, (aux, zl)

    body = _maybe_remat(superblock, cfg)

    if not cfg.scan_layers:          # unrolled (dry-run cost measurement)
        reps = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        aux_t = zl_t = jnp.zeros((), jnp.float32)
        for i in range(reps):
            x, (aux, zl) = body(x, _layer_slice(stacked, i))
            aux_t, zl_t = aux_t + aux, zl_t + zl
        return x, {"moe_aux": aux_t, "moe_zloss": zl_t}

    def scan_fn(carry, layer_params):
        x = carry
        x, (aux, zl) = body(x, layer_params)
        return x, (aux, zl)

    x, (auxs, zls) = jax.lax.scan(scan_fn, x, stacked)
    return x, {"moe_aux": jnp.sum(auxs), "moe_zloss": jnp.sum(zls)}


def run_blocks_prefill(stacked: Tuple[Params, ...], x: jax.Array,
                       cfg: ModelConfig, positions: jax.Array,
                       cache_len: int) -> Tuple[jax.Array, Tuple]:
    pattern = normalize_pattern(cfg)

    def scan_fn(x, layer_params):
        caches = []
        for r, token in enumerate(pattern):
            x, c = block_prefill(layer_params[r], x, cfg, token, positions,
                                 cache_len)
            caches.append(c)
        return x, tuple(caches)

    if not cfg.scan_layers:          # unrolled (dry-run cost measurement)
        reps = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        all_caches = []
        for i in range(reps):
            x, c = scan_fn(x, _layer_slice(stacked, i))
            all_caches.append(c)
        caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *all_caches)
        return x, caches

    x, caches = jax.lax.scan(scan_fn, x, stacked)
    return x, caches   # tuple of per-position caches stacked on reps axis


def run_blocks_decode(stacked: Tuple[Params, ...], x: jax.Array,
                      cfg: ModelConfig, caches: Tuple, position: jax.Array
                      ) -> Tuple[jax.Array, Tuple]:
    pattern = normalize_pattern(cfg)

    def scan_fn(x, inp):
        layer_params, layer_caches = inp
        new_caches = []
        for r, token in enumerate(pattern):
            x, c = block_decode(layer_params[r], x, cfg, token,
                                layer_caches[r], position)
            new_caches.append(c)
        return x, tuple(new_caches)

    if not cfg.scan_layers:          # unrolled (dry-run cost measurement)
        reps = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        outs = []
        for i in range(reps):
            x, c = scan_fn(x, (_layer_slice(stacked, i),
                               _layer_slice(caches, i)))
            outs.append(c)
        new_caches = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs)
        return x, new_caches

    x, new_caches = jax.lax.scan(scan_fn, x, (stacked, caches))
    return x, new_caches
