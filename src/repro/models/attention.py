"""GQA attention block with RoPE/M-RoPE, QKV bias, sliding window, KV cache.

Three entry points sharing one parameter set:

* ``attend_train``   — full-sequence causal attention (flash kernel path);
* ``attend_prefill`` — same math, but also returns the KV cache;
* ``attend_decode``  — one token against a cache (decode kernel path).

Cache layout (per layer): ``k/v (B, S_max, Hkv, D)`` ring-free append at
``position`` (positions are monotone during serving), plus cross-attention
variants for the encoder-decoder models.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import (apply_linear, apply_rope, init_linear,
                                 mrope_positions_text)

Params = Dict[str, jax.Array]

__all__ = ["init_attention", "attend_train", "attend_prefill",
           "attend_decode", "init_cross_attention", "cross_attend",
           "cross_attend_decode"]


def init_attention(key, cfg: ModelConfig) -> Params:
    d, hd = cfg.d_model, cfg.hd
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, dtype, cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, dtype, cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, dtype, cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, dtype),
    }


def _pin_dp(t: jax.Array, cfg: ModelConfig, seq_too: bool = False
            ) -> jax.Array:
    """Pin an activation's batch dim to the DP axes (replicated elsewhere);
    with ``seq_too`` also shard its sequence dim over ``cfg.act_sp``
    (context parallelism for the query side of streaming attention).
    Without the pin, GSPMD picks depth-dependent layouts for flash
    accumulators and all-reduces them per KV block (EXPERIMENTS.md §Perf)."""
    if not cfg.act_dp:
        return t
    from jax.sharding import PartitionSpec as P
    seq_ax = (cfg.act_sp if seq_too and cfg.act_sp is not None
              and t.shape[1] % 16 == 0 else None)
    return jax.lax.with_sharding_constraint(
        t, P(tuple(cfg.act_dp), seq_ax, *([None] * (t.ndim - 2))))


def _project_qkv(p: Params, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array):
    B, S, _ = x.shape
    hd = cfg.hd
    q = apply_linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = apply_linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = apply_linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return (_pin_dp(q, cfg, seq_too=True), _pin_dp(k, cfg),
            _pin_dp(v, cfg))


def attend_train(p: Params, x: jax.Array, cfg: ModelConfig,
                 positions: jax.Array, causal: bool = True) -> jax.Array:
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = ops.flash_attention(q, k, v, causal=causal,
                            window=cfg.sliding_window if causal else None,
                            block_k=cfg.attn_block_k,
                            unroll=not cfg.scan_layers)
    B, S = x.shape[:2]
    return apply_linear(p["wo"], o.reshape(B, S, cfg.n_heads * cfg.hd))


def attend_prefill(p: Params, x: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, cache_len: int
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (output, kv-cache padded to ``cache_len``)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = ops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window,
                            block_k=cfg.attn_block_k,
                            unroll=not cfg.scan_layers)
    B, S = x.shape[:2]
    pad = cache_len - S
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = apply_linear(p["wo"], o.reshape(B, S, cfg.n_heads * cfg.hd))
    return out, {"k": kc, "v": vc}


def attend_decode(p: Params, x: jax.Array, cfg: ModelConfig,
                  cache: Dict[str, jax.Array], position: jax.Array
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B, d) one token; cache k/v (B, S_max, Hkv, D); position (B,).

    With a sliding window the cache is a rolling ring of size >= window:
    writes land at ``position % S_max`` and the kernel masks by absolute
    position (window math handles wraparound because only the last
    ``window`` positions are ever valid).
    """
    B, d = x.shape
    hd = cfg.hd
    q = apply_linear(p["wq"], x).reshape(B, 1, cfg.n_heads, hd)
    k = apply_linear(p["wk"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    v = apply_linear(p["wv"], x).reshape(B, 1, cfg.n_kv_heads, hd)
    pos = position[:, None]
    if cfg.mrope_sections is not None:
        pos = mrope_positions_text(pos)   # text decode: t=h=w=position
    q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, pos, cfg.rope_theta, cfg.mrope_sections)

    S_max = cache["k"].shape[1]
    slot = position % S_max if cfg.sliding_window else position
    # One-hot masked write instead of a scatter: a scatter with runtime
    # (batch, slot) indices into the sequence-sharded cache forces GSPMD to
    # all-gather the whole cache per layer (537 MB/device/layer measured —
    # EXPERIMENTS.md §Perf); the masked blend partitions elementwise.
    hit = (jnp.arange(S_max, dtype=jnp.int32)[None, :]
           == slot[:, None])[:, :, None, None]             # (B, S, 1, 1)
    kc = jnp.where(hit, k[:, 0][:, None].astype(cache["k"].dtype),
                   cache["k"])
    vc = jnp.where(hit, v[:, 0][:, None].astype(cache["v"].dtype),
                   cache["v"])

    if cfg.sliding_window:
        # Ring layout: softmax is permutation-invariant, so attend in ring
        # order directly and mask by each slot's ABSOLUTE position —
        # no take_along_axis reorder (which would also gather the
        # sequence-sharded cache).
        o = ops.decode_attention(q[:, 0], kc, vc, position,
                                 window=cfg.sliding_window, ring=True)
    else:
        lengths = position + 1
        o = ops.decode_attention(q[:, 0], kc, vc, lengths, window=None)
    out = apply_linear(p["wo"], o.reshape(B, cfg.n_heads * hd))
    return out, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ModelConfig) -> Params:
    return init_attention(key, cfg)


def cross_attend(p: Params, x: jax.Array, enc_out: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    """Decoder queries over encoder memory (no causal mask, no rope)."""
    B, S, _ = x.shape
    Se = enc_out.shape[1]
    hd = cfg.hd
    q = apply_linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = apply_linear(p["wk"], enc_out).reshape(B, Se, cfg.n_kv_heads, hd)
    v = apply_linear(p["wv"], enc_out).reshape(B, Se, cfg.n_kv_heads, hd)
    o = ops.flash_attention(q, k, v, causal=False, window=None,
                            block_k=cfg.attn_block_k,
                            unroll=not cfg.scan_layers)
    return apply_linear(p["wo"], o.reshape(B, S, cfg.n_heads * hd))


def cross_attend_decode(p: Params, x: jax.Array, enc_out: jax.Array,
                        cfg: ModelConfig) -> jax.Array:
    """One decoder token (B, d) against encoder memory (B, Se, d)."""
    B, d = x.shape
    Se = enc_out.shape[1]
    hd = cfg.hd
    q = apply_linear(p["wq"], x).reshape(B, cfg.n_heads, hd)
    k = apply_linear(p["wk"], enc_out).reshape(B, Se, cfg.n_kv_heads, hd)
    v = apply_linear(p["wv"], enc_out).reshape(B, Se, cfg.n_kv_heads, hd)
    lengths = jnp.full((B,), Se, jnp.int32)
    o = ops.decode_attention(q, k, v, lengths, window=None)
    return apply_linear(p["wo"], o.reshape(B, cfg.n_heads * hd))
