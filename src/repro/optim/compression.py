"""Gradient compression for cross-pod all-reduce: int8 quantization and
top-k sparsification, both with error feedback.

At 2 pods × 256 chips the inter-pod links (data-center network or optical
ICI) are the scarce resource; compressing the *pod-axis* gradient
all-reduce is the classic fix (Deep Gradient Compression; 1-bit Adam).
We keep the intra-pod reduce in full precision and compress only the
``psum`` over the ``pod`` axis (see distributed/collectives.py).

Error feedback: the quantization residual is carried into the next step's
gradient so the compression bias vanishes in expectation — required for
convergence at int8/top-k rates.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any
__all__ = ["compress_int8", "decompress_int8", "compress_topk",
           "decompress_topk", "ErrorFeedback"]


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization -> (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_topk(x: jax.Array, k_frac: float
                  ) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """Keep the top ``k_frac`` fraction of entries by magnitude.

    Returns (values (k,), indices (k,) i32, original shape).
    """
    xf = x.astype(jnp.float32).reshape(-1)
    k = max(1, int(xf.shape[0] * k_frac))
    vals, idx = jax.lax.top_k(jnp.abs(xf), k)
    return xf[idx], idx.astype(jnp.int32), x.shape


def decompress_topk(vals: jax.Array, idx: jax.Array,
                    shape: Tuple[int, ...]) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return jnp.zeros((n,), jnp.float32).at[idx].set(vals).reshape(shape)


@jax.tree_util.register_dataclass
@dataclass
class ErrorFeedback:
    """Per-leaf carried quantization residual (f32 pytree)."""

    residual: Params

    @staticmethod
    def init(params: Params) -> "ErrorFeedback":
        return ErrorFeedback(residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_int8_roundtrip(g: jax.Array, res: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
    """One error-feedback int8 round trip for a single leaf: returns the
    decompressed gradient actually applied and the new residual."""
    corrected = g.astype(jnp.float32) + res
    q, s = compress_int8(corrected)
    deq = decompress_int8(q, s)
    return deq, corrected - deq
