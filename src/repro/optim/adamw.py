"""AdamW with schedules, global-norm clipping and gradient accumulation.

Implemented from scratch (no optax in this environment) as pure pytree
functions so the optimizer state shards exactly like the parameters
(``distributed.sharding.opt_specs`` maps param specs leaf-wise onto ``m``
and ``v``).

Mixed precision contract: params may be bf16; ``m``/``v`` are always f32;
the update is computed in f32 and cast back to the param dtype. This is
the standard TPU training recipe (bf16 weights tolerate Adam noise at
these scales; a separate f32 master copy can be enabled with
``master_weights=True`` for the paranoid path).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "make_schedule"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: str = "cosine"         # constant | linear | cosine
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    master_weights: bool = False


@jax.tree_util.register_dataclass
@dataclass
class OptState:
    m: Params
    v: Params
    count: jax.Array                  # () i32
    master: Optional[Params] = None   # f32 copy when enabled


def make_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    """step (i32/f32 scalar) -> lr (f32 scalar); jit-safe."""

    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        if cfg.schedule == "constant":
            decay = jnp.float32(1.0)
        elif cfg.schedule == "linear":
            decay = 1.0 - (1.0 - cfg.min_lr_frac) * t
        elif cfg.schedule == "cosine":
            decay = (cfg.min_lr_frac + (1.0 - cfg.min_lr_frac)
                     * 0.5 * (1.0 + jnp.cos(jnp.pi * t)))
        else:
            raise ValueError(f"unknown schedule {cfg.schedule!r}")
        return cfg.lr * warm * decay

    return sched


def _zeros_f32_like(params: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def adamw_init(params: Params, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    master = None
    if cfg.master_weights:
        master = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return OptState(m=_zeros_f32_like(params), v=_zeros_f32_like(params),
                    count=jnp.zeros((), jnp.int32), master=master)


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return (jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads),
        gnorm)


def _decay_mask(path) -> bool:
    """Decay matmul weights; skip norms/biases/scalars (standard recipe)."""
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    joined = "/".join(str(n) for n in names)
    for skip in ("norm", "bias", "scale", "dt_bias", "A_log", "D", "b"):
        if joined.endswith(skip) or f"/{skip}/" in joined:
            return False
    return True


def adamw_update(grads: Params, state: OptState, params: Params,
                 cfg: AdamWConfig) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    metrics: Dict[str, jax.Array] = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    count = state.count + 1
    cf = count.astype(jnp.float32)
    sched = make_schedule(cfg)
    lr = sched(count)
    metrics["lr"] = lr
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf

    src = state.master if state.master is not None else params

    def upd(path, g, m, v, p):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * gf * gf
        step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        if cfg.weight_decay and _decay_mask(path):
            step = step + cfg.weight_decay * pf
        return pf - lr * step, m2, v2

    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    paths = [p for p, _ in flat]
    g_l = [g for _, g in flat]
    m_l = jax.tree_util.tree_leaves(state.m)
    v_l = jax.tree_util.tree_leaves(state.v)
    p_l = jax.tree_util.tree_leaves(src)
    new = [upd(path, g, m, v, p)
           for path, g, m, v, p in zip(paths, g_l, m_l, v_l, p_l)]
    treedef = jax.tree_util.tree_structure(grads)
    new_f32 = jax.tree_util.tree_unflatten(treedef, [n[0] for n in new])
    new_m = jax.tree_util.tree_unflatten(treedef, [n[1] for n in new])
    new_v = jax.tree_util.tree_unflatten(treedef, [n[2] for n in new])

    new_params = jax.tree_util.tree_map(
        lambda nf, p: nf.astype(p.dtype), new_f32, params)
    new_master = new_f32 if state.master is not None else None
    return new_params, OptState(m=new_m, v=new_v, count=count,
                                master=new_master), metrics
