from repro.optim.adamw import (AdamWConfig, OptState, adamw_init,
                               adamw_update, clip_by_global_norm,
                               make_schedule)
from repro.optim.compression import (compress_int8, decompress_int8,
                                     compress_topk, decompress_topk,
                                     ErrorFeedback)

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "clip_by_global_norm", "make_schedule", "compress_int8",
           "decompress_int8", "compress_topk", "decompress_topk",
           "ErrorFeedback"]
