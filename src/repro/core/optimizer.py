"""Logical-plan optimizer — the paper's O1 "query/plan optimization" layer.

Pass pipeline (each individually toggleable so the Figure-2 ablation can
attribute gains):

1.  ``constant_folding``      — fold literal arithmetic.
2.  ``simplify_filter``       — drop always-true WHERE; detect always-false.
3.  ``window_merge``          — windows with identical frames collapse into
                                one (shared scan + fused aggregation).
4.  ``decompose_aggregates``  — AVG→SUM/COUNT, STD/VAR→moments, so shared
                                moments are computed once (enables CSE).
5.  ``cse``                   — deduplicate identical aggregate subtrees.
6.  ``column_pruning``        — narrow the storage scan to referenced cols.
7.  ``select_window_impl``    — cost-based choice of naive scan vs
                                pre-aggregated execution per window (O3).
8.  ``fuse_windows``          — windows left on the raw-scan path join ONE
                                fused multi-window launch (shared ring
                                scan); preagg windows whose columns the
                                shared scan already reads are pulled in
                                when that is marginally cheaper.

Passes are pure ``LogicalPlan -> LogicalPlan`` rewrites; ``optimize``
returns the new plan plus a human-readable rewrite log (surfaced by
``Engine.explain`` and the benchmarks).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import expr as E
from repro.core.logical import (Filter, Join, LogicalPlan, Scan,
                                WindowProject, validate)

__all__ = ["OptFlags", "TableMeta", "CostModel", "optimize",
           "estimate_window_cost", "estimate_join_cost",
           "pass_fuse_windows", "pass_resolve_joins",
           "pass_prune_join_columns", "pass_order_joins"]


@dataclass(frozen=True)
class TableMeta:
    """Catalog info the cost model needs about a storage table."""

    capacity: int
    bucket_size: int
    n_value_cols: int
    has_preagg: bool


@dataclass(frozen=True)
class CostModel:
    """Calibratable constants of the elements-touched cost model.

    The defaults reproduce the original hard-coded model exactly: every
    access class costs 1.0 per f32 element and launches are free. The
    adaptive control plane (``repro.control``) regresses these against
    *measured* per-launch times and re-plans deployments when the
    calibrated constants flip a decision (DESIGN.md §10) — the
    coefficients are relative weights, so only their ratios matter to the
    optimizer's comparisons.

    * ``scan_el``   — per-element weight of raw ring-scan reads (naive and
      fused window execution, and timestamp scans);
    * ``preagg_el`` — per-element weight of pre-aggregate tier reads;
    * ``join_el``   — per-element weight of LAST JOIN right-ring reads;
    * ``launch_overhead`` — fixed per-kernel-launch cost in scan-element
      units (amortised across the members of a fused launch);
    * ``table_el``  — per-right-table multiplicative overrides on top of
      ``join_el`` (sorted name/weight pairs so the model stays hashable
      and its repr is stable for fingerprints/logs).
    """

    scan_el: float = 1.0
    preagg_el: float = 1.0
    join_el: float = 1.0
    launch_overhead: float = 0.0
    table_el: Tuple[Tuple[str, float], ...] = ()

    def table_weight(self, table: Optional[str]) -> float:
        if table is not None:
            for t, w in self.table_el:
                if t == table:
                    return self.join_el * w
        return self.join_el

    def with_table(self, table: str, weight: float) -> "CostModel":
        kept = tuple((t, w) for t, w in self.table_el if t != table)
        return dataclasses.replace(
            self, table_el=tuple(sorted(kept + ((table, float(weight)),))))


@dataclass(frozen=True)
class OptFlags:
    """Optimization switches (paper Fig. 2 ablation axes)."""

    query_opt: bool = True        # passes 1–6
    preagg: bool = True           # pass 7 may pick pre-aggregation
    plan_cache: bool = True       # consumed by the engine, carried here
    vectorized: bool = True       # engine: batched vs per-row execution
    assume_latest: bool = True    # engine: online fast path (req_ts is newest)
    parallel_workers: int = 1     # engine: worker-pool fan-out (paper Fig. 2)
    fuse_windows: bool = True     # pass 8: single-scan multi-window launch


# ---------------------------------------------------------------------------
# Expression rewriting helpers
# ---------------------------------------------------------------------------

def _rewrite(e: E.Expr, fn: Callable[[E.Expr], E.Expr]) -> E.Expr:
    """Bottom-up rewrite."""
    kids = tuple(_rewrite(c, fn) for c in E.children(e))
    return fn(E.replace_children(e, kids))


_FOLDABLE_BIN = {"+", "-", "*", "/", ">", ">=", "<", "<=", "==", "!="}
_FOLDABLE_FN = {"log", "log1p", "abs", "sqrt", "exp", "neg", "floor", "ceil"}

import math as _math

_PY_BIN = {
    "+": lambda a, b: a + b, "-": lambda a, b: a - b,
    "*": lambda a, b: a * b, "/": lambda a, b: a / b if b != 0 else float("inf"),
    ">": lambda a, b: float(a > b), ">=": lambda a, b: float(a >= b),
    "<": lambda a, b: float(a < b), "<=": lambda a, b: float(a <= b),
    "==": lambda a, b: float(a == b), "!=": lambda a, b: float(a != b),
}
_PY_FN = {
    "log": _math.log, "log1p": _math.log1p, "abs": abs,
    "sqrt": _math.sqrt, "exp": _math.exp, "neg": lambda x: -x,
    "floor": _math.floor, "ceil": _math.ceil,
}


def _fold(e: E.Expr) -> E.Expr:
    if (isinstance(e, E.BinOp) and e.op in _FOLDABLE_BIN
            and isinstance(e.lhs, E.Lit) and isinstance(e.rhs, E.Lit)):
        try:
            return E.Lit(float(_PY_BIN[e.op](e.lhs.value, e.rhs.value)))
        except (ValueError, OverflowError):
            return e
    if (isinstance(e, E.Func) and e.name in _FOLDABLE_FN
            and len(e.args) == 1 and isinstance(e.args[0], E.Lit)):
        try:
            return E.Lit(float(_PY_FN[e.name](e.args[0].value)))
        except (ValueError, OverflowError):
            return e
    # algebraic identities
    if isinstance(e, E.BinOp):
        if e.op == "+" and isinstance(e.rhs, E.Lit) and e.rhs.value == 0.0:
            return e.lhs
        if e.op == "+" and isinstance(e.lhs, E.Lit) and e.lhs.value == 0.0:
            return e.rhs
        if e.op == "*" and isinstance(e.rhs, E.Lit) and e.rhs.value == 1.0:
            return e.lhs
        if e.op == "*" and isinstance(e.lhs, E.Lit) and e.lhs.value == 1.0:
            return e.rhs
        if e.op == "and":
            if isinstance(e.lhs, E.Lit):
                return e.rhs if e.lhs.value else E.Lit(0.0)
            if isinstance(e.rhs, E.Lit):
                return e.lhs if e.rhs.value else E.Lit(0.0)
    return e


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------

def pass_constant_folding(plan: LogicalPlan, log: List[str]) -> LogicalPlan:
    n_before = sum(len(list(E.walk(e))) for _, e in plan.project.outputs)
    outs = tuple((n, _rewrite(e, _fold)) for n, e in plan.project.outputs)
    pred = (_rewrite(plan.filter.pred, _fold)
            if plan.filter.pred is not None else None)
    n_after = sum(len(list(E.walk(e))) for _, e in outs)
    if n_after < n_before:
        log.append(f"constant_folding: {n_before - n_after} nodes folded")
    return plan.with_(project=dataclasses.replace(plan.project, outputs=outs),
                      filter=Filter(pred))


def pass_simplify_filter(plan: LogicalPlan, log: List[str]) -> LogicalPlan:
    pred = plan.filter.pred
    if isinstance(pred, E.Lit):
        if pred.value:
            log.append("simplify_filter: dropped always-true WHERE")
            return plan.with_(filter=Filter(None))
        log.append("simplify_filter: WHERE is always-false (empty windows)")
    return plan


def pass_window_merge(plan: LogicalPlan, log: List[str]) -> LogicalPlan:
    """Windows with identical frames share one name (one fused scan)."""
    canon: Dict[str, str] = {}   # frame fingerprint -> canonical window name
    rename: Dict[str, str] = {}  # old name -> canonical name
    keep: List[Tuple[str, E.WindowSpec]] = []
    for name, spec in plan.project.windows:
        fp = spec.frame_fingerprint()
        if fp in canon:
            rename[name] = canon[fp]
        else:
            canon[fp] = name
            keep.append((name, spec))
    if not rename:
        return plan

    def fix(e: E.Expr) -> E.Expr:
        if isinstance(e, E.Agg) and e.window in rename:
            return dataclasses.replace(e, window=rename[e.window])
        return e

    outs = tuple((n, _rewrite(e, fix)) for n, e in plan.project.outputs)
    log.append(f"window_merge: merged {len(rename)} duplicate window(s) "
               f"({', '.join(f'{a}->{b}' for a, b in rename.items())})")
    return plan.with_(project=WindowProject(outs, tuple(keep)))


def pass_decompose_aggregates(plan: LogicalPlan, log: List[str]) -> LogicalPlan:
    """AVG(x) -> safe_div(SUM(x), COUNT(x)); STD/VAR -> moment form."""
    n = [0]

    def fix(e: E.Expr) -> E.Expr:
        if not isinstance(e, E.Agg):
            return e
        if e.func == E.AggFunc.AVG:
            n[0] += 1
            return E.Func("safe_div", (
                E.Agg(E.AggFunc.SUM, e.arg, e.window),
                E.Agg(E.AggFunc.COUNT, e.arg, e.window)))
        if e.func in (E.AggFunc.STD, E.AggFunc.VAR):
            n[0] += 1
            fname = "safe_std" if e.func == E.AggFunc.STD else "safe_var"
            sq = E.Agg(E.AggFunc.SUM, E.BinOp("*", e.arg, e.arg), e.window)
            s = E.Agg(E.AggFunc.SUM, e.arg, e.window)
            c = E.Agg(E.AggFunc.COUNT, e.arg, e.window)
            return E.Func(fname, (sq, s, c))
        return e

    outs = tuple((name, _rewrite(e, fix)) for name, e in plan.project.outputs)
    if n[0]:
        log.append(f"decompose_aggregates: {n[0]} compound aggregate(s) "
                   f"rewritten to shared moments")
    return plan.with_(project=dataclasses.replace(plan.project, outputs=outs))


def pass_cse(plan: LogicalPlan, log: List[str]) -> LogicalPlan:
    """Count duplicate aggregate subtrees (dedup happens in the physical
    planner via fingerprint keying; this pass records the win)."""
    seen: Dict[str, int] = {}
    for _, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            seen[agg.fingerprint()] = seen.get(agg.fingerprint(), 0) + 1
    dups = sum(c - 1 for c in seen.values() if c > 1)
    if dups:
        log.append(f"cse: {dups} duplicate aggregate(s) shared "
                   f"({len(seen)} unique)")
    return plan


def pass_column_pruning(plan: LogicalPlan, log: List[str]) -> LogicalPlan:
    cols: Dict[str, None] = {}
    for _, e in plan.project.outputs:
        for c in E.collect_columns(e):
            cols.setdefault(c)
    if plan.filter.pred is not None:
        for c in E.collect_columns(plan.filter.pred):
            cols.setdefault(c)
    pruned = tuple(c for c in plan.scan.columns if c in cols)
    if len(pruned) < len(plan.scan.columns):
        dropped = set(plan.scan.columns) - set(pruned)
        log.append(f"column_pruning: dropped {sorted(dropped)}")
    return plan.with_(scan=Scan(plan.scan.table, pruned))


def sumsq_col(arg: E.Expr) -> Optional[str]:
    """Match the ``x*x`` pattern — maps onto the materialized sumsq tier."""
    if (isinstance(arg, E.BinOp) and arg.op == "*"
            and isinstance(arg.lhs, E.Col) and isinstance(arg.rhs, E.Col)
            and arg.lhs.name == arg.rhs.name):
        return arg.lhs.name
    return None


def _tiered_arg(a: E.Agg) -> bool:
    """True if the aggregate can be served from pre-aggregate tiers."""
    if isinstance(a.arg, E.Col):
        return True
    if isinstance(a.arg, E.Lit) and a.func == E.AggFunc.COUNT:
        return True
    if a.func == E.AggFunc.SUM and sumsq_col(a.arg) is not None:
        return True   # SUM(x*x) == the sumsq tier (STD/VAR decomposition)
    return False


def estimate_window_cost(spec: E.WindowSpec, meta: TableMeta, *,
                         impl: str, n_cols: int,
                         needs_ts_scan: bool,
                         shared_scan: int = 1,
                         model: CostModel = CostModel()) -> float:
    """Rough elements-touched cost model (f32 reads per request).

    ``shared_scan`` is the number of windows sharing one fused launch
    (``impl in ("naive", "fused")``): the timestamp scan and the
    window-bound math are computed once per launch, so their C-sized cost
    amortises across the members — the shared-scan discount that makes
    fusing a window into an existing launch cheaper than running it alone.
    For a raw-scan impl, ``needs_ts_scan=False`` prices the *marginal*
    member of an existing launch (the ts scan is already paid for).

    ``model`` scales each access class by its calibrated per-element
    weight (defaults reproduce the uncalibrated model bit-for-bit).
    """
    C, B = meta.capacity, meta.bucket_size
    nb = C // B
    share = max(shared_scan, 1)
    if impl in ("naive", "fused"):
        ts_cost = C / share if needs_ts_scan else 0.0
        return (model.scan_el * (C * n_cols + ts_cost)   # values + shared ts
                + model.launch_overhead / share)
    ts_cost = C if needs_ts_scan else 0
    return (model.preagg_el * (nb * (n_cols + 1) + 2 * B * n_cols)
            + model.scan_el * ts_cost + model.launch_overhead)


def pass_select_window_impl(plan: LogicalPlan, log: List[str], *,
                            meta: TableMeta,
                            flags: OptFlags,
                            model: CostModel = CostModel()) -> LogicalPlan:
    """Cost-based naive-vs-preagg choice per window (paper O3)."""
    by_window: Dict[str, List[E.Agg]] = {}
    for _, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            by_window.setdefault(agg.window, []).append(agg)
    impl: Dict[str, str] = {}
    for wname, spec in plan.project.windows:
        aggs = by_window.get(wname, [])
        reasons = []
        if not flags.preagg or not meta.has_preagg:
            reasons.append("preagg disabled")
        if plan.filter.pred is not None:
            reasons.append("WHERE filter present (tiers are unfiltered)")
        if any(a.func in (E.AggFunc.FIRST, E.AggFunc.LAST) for a in aggs):
            reasons.append("FIRST/LAST need raw scan")
        if any(not _tiered_arg(a) for a in aggs):
            reasons.append("derived aggregate argument (no materialized tier)")
        if spec.is_rows and spec.rows_preceding > meta.capacity - meta.bucket_size:
            reasons.append("window exceeds pre-agg retention safety margin")
        if reasons:
            impl[wname] = "naive"
            log.append(f"window {wname!r}: naive ({'; '.join(reasons)})")
            continue
        n_cols = len({a.arg.name for a in aggs if isinstance(a.arg, E.Col)}) or 1
        needs_ts = (not spec.is_rows) or (not flags.assume_latest)
        c_naive = estimate_window_cost(spec, meta, impl="naive",
                                       n_cols=n_cols, needs_ts_scan=True,
                                       model=model)
        c_pre = estimate_window_cost(spec, meta, impl="preagg",
                                     n_cols=n_cols, needs_ts_scan=needs_ts,
                                     model=model)
        chosen = "preagg" if c_pre < c_naive else "naive"
        impl[wname] = chosen
        log.append(f"window {wname!r}: {chosen} "
                   f"(cost naive={c_naive:.0f} preagg={c_pre:.0f})")
    return plan.with_(window_impl=tuple(sorted(impl.items())))


def _window_colset(aggs: List[E.Agg]) -> set:
    """Distinct value columns a window's aggregates read from the scan.

    Derived (non-Col) arguments count as virtual columns keyed by their
    expression fingerprint — they occupy one stacked column in the fused
    scan exactly like a storage column does."""
    cols: set = set()
    for a in aggs:
        if isinstance(a.arg, E.Col):
            cols.add(a.arg.name)
        elif isinstance(a.arg, E.Lit):
            continue                      # COUNT(*) reads no column
        else:
            cols.add(a.arg.fingerprint())
    return cols


def pass_fuse_windows(plan: LogicalPlan, log: List[str], *,
                      meta: TableMeta,
                      flags: OptFlags,
                      model: CostModel = CostModel()) -> LogicalPlan:
    """Mark windows for single-scan fused execution (multi-window launch).

    Every window the impl-selection pass left on the raw-scan path joins
    ONE fused launch when there are at least two of them: the launch, the
    ring-block read, the timestamp scan and the window-bound math are all
    shared (the ``shared_scan`` discount in ``estimate_window_cost``).
    Pre-aggregated windows are then pulled into the shared scan when the
    marginal cost of adding their columns to the union undercuts their
    tier lookup — e.g. a window over columns the scan already streams.
    """
    impl = dict(plan.window_impl)
    naive = sorted(w for w, v in impl.items() if v == "naive")
    if not flags.fuse_windows:
        if len(naive) >= 2:
            log.append(f"fuse_windows disabled: {len(naive)} raw-scan "
                       f"window(s) execute per-group")
        return plan
    if len(naive) < 2:
        return plan                       # nothing to share a scan with

    by_window: Dict[str, List[E.Agg]] = {}
    for _, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            by_window.setdefault(agg.window, []).append(agg)
    specs = plan.project.window_map()
    naive = [w for w in naive if by_window.get(w)]
    if len(naive) < 2:
        return plan

    cost_sep = sum(
        estimate_window_cost(specs[w], meta, impl="naive",
                             n_cols=len(_window_colset(by_window[w])) or 1,
                             needs_ts_scan=True, model=model)
        for w in naive)
    union: set = set()
    for w in naive:
        union |= _window_colset(by_window[w])
        impl[w] = "fused"
    fused_set = list(naive)
    # whole-launch cost: union scan + ONE shared ts read
    cost_fused = estimate_window_cost(
        specs[naive[0]], meta, impl="fused",
        n_cols=len(union) or 1, needs_ts_scan=True, shared_scan=1,
        model=model)

    # pull preagg windows into the shared scan when marginally cheaper
    for w in sorted(w for w, v in impl.items() if v == "preagg"):
        cols = _window_colset(by_window.get(w, []))
        # marginal member of an existing launch: only its NEW columns
        # cost anything (the ts scan is already paid by the fused set)
        marginal = estimate_window_cost(
            specs[w], meta, impl="fused", n_cols=len(cols - union),
            needs_ts_scan=False, shared_scan=len(fused_set) + 1,
            model=model)
        needs_ts = (not specs[w].is_rows) or (not flags.assume_latest)
        c_pre = estimate_window_cost(specs[w], meta, impl="preagg",
                                     n_cols=len(cols) or 1,
                                     needs_ts_scan=needs_ts, model=model)
        if marginal < c_pre:
            impl[w] = "fused"
            union |= cols
            fused_set.append(w)
            log.append(f"fuse_windows: pulled {w!r} into the shared scan "
                       f"(marginal={marginal:.0f} < preagg={c_pre:.0f})")

    log.append(f"fuse_windows: {len(fused_set)} window(s) -> ONE fused "
               f"launch ({', '.join(sorted(fused_set))}; "
               f"cost separate={cost_sep:.0f} fused={cost_fused:.0f})")
    return plan.with_(window_impl=tuple(sorted(impl.items())))


# ---------------------------------------------------------------------------
# Relational passes (LAST JOIN)
# ---------------------------------------------------------------------------

def _main_columns(schema) -> set:
    return set(schema.value_cols) | {schema.ts_col, schema.key_col}


def pass_resolve_joins(plan: LogicalPlan, log: List[str], *,
                       catalog) -> LogicalPlan:
    """Validate every LAST JOIN against the catalog and resolve column
    references.

    * the right table must be registered; ``on`` must be one of its
      *declared* join keys AND a main-table value column (the left side
      supplies the probe values);
    * ``order_by`` must be the right table's timestamp column — the ring
      buffer is physically ordered by it, which is what makes the
      point-in-time lookup a masked argmax instead of a sort;
    * unqualified column names that live only on one joined table are
      qualified to ``"table.col"``; ambiguous names are rejected;
    * window aggregates and WHERE may not reference joined columns
      (windows scan the main ring; WHERE filters raw events).
    """
    if not plan.joins:
        return plan
    try:
        main = catalog.get(plan.scan.table).schema
    except KeyError as e:
        raise ValueError(str(e)) from None
    jmap = {}
    for j in plan.joins:
        try:
            entry = catalog.get(j.table)
        except KeyError:
            raise ValueError(
                f"LAST JOIN references unknown table {j.table!r}; "
                f"registered tables: {list(catalog.tables())} "
                f"(create_table first)") from None
        if j.on not in entry.join_keys:
            raise ValueError(
                f"LAST JOIN {j.table!r} ON {j.on!r}: {j.on!r} is not a "
                f"declared join key of {j.table!r} (declared: "
                f"{sorted(entry.join_keys)}); joins must probe a declared "
                f"key so they resolve through the table's key directory")
        if j.on not in main.value_cols:
            raise ValueError(
                f"LAST JOIN {j.table!r} ON {j.on!r}: the main table "
                f"{main.name!r} has no value column {j.on!r} to supply the "
                f"probe keys (columns: {list(main.value_cols)})")
        if j.order_by != entry.schema.ts_col:
            raise ValueError(
                f"LAST JOIN {j.table!r} ORDER BY {j.order_by!r}: the "
                f"point-in-time ordering must be the right table's "
                f"timestamp column {entry.schema.ts_col!r} — the ring "
                f"buffer is physically ordered by it")
        jmap[j.table] = entry.schema

    main_cols = _main_columns(main)

    def owners(name: str) -> List[str]:
        return [t for t, rs in jmap.items() if name in rs.value_cols]

    def check_no_join_cols(e: E.Expr, what: str) -> None:
        for c in E.collect_columns(e):
            if "." in c:
                t = c.split(".", 1)[0]
                if t in jmap:
                    raise ValueError(
                        f"{what} references joined column {c!r}; "
                        f"{what.split()[0]} evaluates over main-table "
                        f"events — joined columns are per-request values "
                        f"and are out of scope there")
            elif c not in main_cols and owners(c):
                raise ValueError(
                    f"{what} references column {c!r}, which only exists "
                    f"on joined table(s) {owners(c)}; {what.split()[0]} "
                    f"evaluates over main-table events — joined columns "
                    f"are per-request values and are out of scope there")

    def resolve(e: E.Expr) -> E.Expr:
        if isinstance(e, E.Agg):
            check_no_join_cols(
                e.arg, f"window aggregate {e.func.value.upper()} over "
                       f"{e.window!r}")
            return e
        if isinstance(e, E.Col):
            n = e.name
            if "." in n:
                t, c = n.split(".", 1)
                if t not in jmap:
                    raise ValueError(
                        f"qualified column {n!r} references table {t!r}, "
                        f"which is not LAST JOINed in this query (joined: "
                        f"{sorted(jmap)})")
                if c not in jmap[t].value_cols:
                    raise ValueError(
                        f"joined table {t!r} has no value column {c!r}; "
                        f"columns: {list(jmap[t].value_cols)}")
                return e
            if n in main_cols:
                return e
            own = owners(n)
            if len(own) > 1:
                raise ValueError(
                    f"column {n!r} is ambiguous across joined tables "
                    f"{sorted(own)}; qualify it as <table>.{n}")
            if own:
                return E.Col(f"{own[0]}.{n}")
            return e
        kids = tuple(resolve(c) for c in E.children(e))
        return E.replace_children(e, kids)

    n_qual = [0]

    def resolve_counting(e: E.Expr) -> E.Expr:
        before = sum(1 for x in E.walk(e)
                     if isinstance(x, E.Col) and "." in x.name)
        out = resolve(e)
        after = sum(1 for x in E.walk(out)
                    if isinstance(x, E.Col) and "." in x.name)
        n_qual[0] += after - before
        return out

    outs = tuple((n, resolve_counting(e)) for n, e in plan.project.outputs)
    if plan.filter.pred is not None:
        check_no_join_cols(plan.filter.pred,
                           "WHERE (raw-event filter before the join)")
    for wname, spec in plan.project.windows:
        for role, c in (("PARTITION BY", spec.partition_by),
                        ("ORDER BY", spec.order_by)):
            if c not in main_cols and (owners(c) or "." in c):
                raise ValueError(
                    f"window {wname!r} {role} references joined-table "
                    f"column {c!r}; windows index the main table's "
                    f"(key, ts) only — LAST JOIN results are per-request "
                    f"values and cannot partition or order a window")
    if n_qual[0]:
        log.append(f"resolve_joins: qualified {n_qual[0]} joined column "
                   f"reference(s)")
    return plan.with_(project=dataclasses.replace(plan.project,
                                                  outputs=outs))


def pass_prune_join_columns(plan: LogicalPlan, log: List[str], *,
                            catalog) -> LogicalPlan:
    """Join-aware column pruning: each join carries only the right-table
    columns the query references; a join nothing references is dropped
    entirely (its probe + launch would be pure waste)."""
    if not plan.joins:
        return plan
    used: Dict[str, Dict[str, None]] = {j.table: {} for j in plan.joins}
    for _, e in plan.project.outputs:
        for c in E.collect_columns(e):
            if "." in c:
                t, cc = c.split(".", 1)
                if t in used:
                    used[t].setdefault(cc)
    joins: List[Join] = []
    for j in plan.joins:
        cols = tuple(used[j.table])
        if not cols:
            log.append(f"join_prune: dropped unused join {j.table!r} "
                       f"(no joined column referenced)")
            continue
        full = catalog.get(j.table).schema.value_cols
        dropped = [c for c in full if c not in cols]
        if dropped:
            log.append(f"join_prune: {j.table!r} -> {list(cols)} "
                       f"(dropped {dropped})")
        joins.append(dataclasses.replace(j, columns=cols))
    return plan.with_(joins=tuple(joins))


def estimate_join_cost(capacity: int, n_cols: int, *,
                       assume_latest: bool,
                       model: CostModel = CostModel(),
                       table: Optional[str] = None) -> float:
    """Elements-touched probe cost of one LAST JOIN (f32 reads/request):
    the right ring block (C·n_cols), the timestamp scan (skipped on the
    online fast path where the newest row wins), and the key-directory
    probe. ``model.table_weight(table)`` lets calibration price one right
    table's probes differently from another's (e.g. a cold replica) — the
    lever that flips the probe order in ``pass_order_joins``."""
    ts_cost = 0.0 if assume_latest else float(capacity)
    return (model.table_weight(table) * float(capacity) * n_cols
            + model.scan_el * ts_cost + 2.0 + model.launch_overhead)


def pass_order_joins(plan: LogicalPlan, log: List[str], *,
                     catalog, flags: OptFlags,
                     model: CostModel = CostModel()) -> LogicalPlan:
    """Order joins by estimated right-table probe cost (cheapest first).

    LAST JOINs here are independent probes off the request row (no join
    chains yet), so ordering does not change results — it fixes the
    launch order so the cheapest lookups complete first and the probe
    order in EXPLAIN reflects the cost model.
    """
    if len(plan.joins) < 2:
        return plan
    costed = []
    for j in plan.joins:
        entry = catalog.get(j.table)
        n_cols = len(j.columns or entry.schema.value_cols)
        cost = estimate_join_cost(entry.table.capacity, n_cols,
                                  assume_latest=flags.assume_latest,
                                  model=model, table=j.table)
        costed.append((cost, j.table, j))
    costed.sort(key=lambda x: (x[0], x[1]))
    ordered = tuple(j for _, _, j in costed)
    if ordered != plan.joins:
        log.append("join_order: probe order "
                   + " -> ".join(f"{t}({c:.0f})" for c, t, _ in costed))
    return plan.with_(joins=ordered)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def optimize(plan: LogicalPlan, meta: TableMeta,
             flags: OptFlags = OptFlags(),
             catalog=None,
             cost_model: Optional[CostModel] = None
             ) -> Tuple[LogicalPlan, List[str]]:
    log: List[str] = []
    model = cost_model if cost_model is not None else CostModel()
    if model != CostModel():
        log.append(f"cost_model: calibrated {model}")
    if plan.joins:
        if catalog is None:
            raise ValueError(
                "plan contains LAST JOIN(s) but no relational catalog was "
                "provided; joins validate against Catalog-declared join "
                "keys (Engine passes its catalog automatically)")
        # resolution is semantics (name binding + validation), not an
        # optimization — it runs even with query_opt ablated
        plan = pass_resolve_joins(plan, log, catalog=catalog)
    if flags.query_opt:
        plan = pass_constant_folding(plan, log)
        plan = pass_simplify_filter(plan, log)
        plan = pass_window_merge(plan, log)
        plan = pass_decompose_aggregates(plan, log)
        plan = pass_cse(plan, log)
        plan = pass_column_pruning(plan, log)
        if plan.joins:
            plan = pass_prune_join_columns(plan, log, catalog=catalog)
            plan = pass_order_joins(plan, log, catalog=catalog, flags=flags,
                                    model=model)
            if plan.filter.pred is not None and plan.joins:
                # WHERE references main-table event columns only (resolve
                # enforced it), so it stays pushed below every join on the
                # raw scan — joined rows never widen the filtered set
                log.append(f"filter_pushdown: WHERE stays on the main-table "
                           f"scan below {len(plan.joins)} join(s)")
    else:
        log.append("query_opt disabled: plan executed as written")
    plan = pass_select_window_impl(plan, log, meta=meta, flags=flags,
                                   model=model)
    plan = pass_fuse_windows(plan, log, meta=meta, flags=flags, model=model)
    validate(plan)
    return plan, log
