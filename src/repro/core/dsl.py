"""Query front-ends: a Python builder DSL and a SQL-text parser.

Both produce :class:`repro.core.logical.Query`. The SQL dialect is the
OpenMLDB feature-query subset the paper exercises::

    SELECT user_id,
           SUM(amount)   OVER w  AS amt_sum,
           AVG(amount)   OVER w  AS amt_avg,
           COUNT(*)      OVER w2 AS n_recent,
           merchants.risk        AS m_risk,
           PREDICT(fraud_model, amt_sum, amt_avg, n_recent, m_risk) AS score
    FROM events
    LAST JOIN merchants ORDER BY mts ON merchant
    WHERE amount >= 0
    WINDOW w  AS (PARTITION BY user_id ORDER BY ts
                  ROWS BETWEEN 100 PRECEDING AND CURRENT ROW),
           w2 AS (PARTITION BY user_id ORDER BY ts
                  RANGE BETWEEN 3600 PRECEDING AND CURRENT ROW)

``LAST JOIN`` is the relational tier's point-in-time enrichment
(DESIGN.md §8): the latest right-table row with ORDER-BY-timestamp ≤ the
request timestamp, probed through the right table's declared join key.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

from repro.core import expr as E
from repro.core.logical import Join, Predict, Query

__all__ = ["Ex", "col", "lit", "tbl", "TableRef", "sum_", "count_", "avg_",
           "min_", "max_", "std_", "var_", "first_", "last_",
           "QueryBuilder", "parse_sql", "strip_explain_analyze"]


# ---------------------------------------------------------------------------
# Builder DSL
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Ex:
    """Operator-overloading wrapper around an Expr node."""

    node: E.Expr

    def _bin(self, op: str, other: "ExLike") -> "Ex":
        return Ex(E.BinOp(op, self.node, _unwrap(other)))

    def _rbin(self, op: str, other: "ExLike") -> "Ex":
        return Ex(E.BinOp(op, _unwrap(other), self.node))

    __add__ = lambda s, o: s._bin("+", o)
    __radd__ = lambda s, o: s._rbin("+", o)
    __sub__ = lambda s, o: s._bin("-", o)
    __rsub__ = lambda s, o: s._rbin("-", o)
    __mul__ = lambda s, o: s._bin("*", o)
    __rmul__ = lambda s, o: s._rbin("*", o)
    __truediv__ = lambda s, o: s._bin("/", o)
    __rtruediv__ = lambda s, o: s._rbin("/", o)
    __gt__ = lambda s, o: s._bin(">", o)
    __ge__ = lambda s, o: s._bin(">=", o)
    __lt__ = lambda s, o: s._bin("<", o)
    __le__ = lambda s, o: s._bin("<=", o)

    def eq(self, o: "ExLike") -> "Ex":
        return self._bin("==", o)

    def ne(self, o: "ExLike") -> "Ex":
        return self._bin("!=", o)

    def and_(self, o: "ExLike") -> "Ex":
        return self._bin("and", o)

    def or_(self, o: "ExLike") -> "Ex":
        return self._bin("or", o)

    def log1p(self) -> "Ex":
        return Ex(E.Func("log1p", (self.node,)))

    def abs(self) -> "Ex":
        return Ex(E.Func("abs", (self.node,)))

    def over(self, window: str) -> "Ex":
        """Attach a window to a pending aggregate (see ``sum_`` etc.)."""
        n = self.node
        if not (isinstance(n, E.Agg) and n.window == _PENDING_WINDOW):
            raise TypeError(".over() applies to aggregate expressions only")
        return Ex(E.Agg(n.func, n.arg, window))


ExLike = Union[Ex, E.Expr, float, int]


def _unwrap(x: ExLike) -> E.Expr:
    if isinstance(x, Ex):
        return x.node
    if isinstance(x, E.Expr):
        return x
    return E.Lit(float(x))


def col(name: str) -> Ex:
    return Ex(E.Col(name))


class TableRef:
    """``t.col`` disambiguation for joined tables.

    ``tbl("merchants").rating`` (or ``tbl("merchants")["rating"]``) builds
    a qualified column reference ``Col("merchants.rating")`` — required
    when an unqualified name is ambiguous across the main table and the
    LAST JOINed tables, handy always.
    """

    __slots__ = ("_name",)

    def __init__(self, name: str):
        object.__setattr__(self, "_name", name)

    def __getattr__(self, column: str) -> Ex:
        if column.startswith("_"):
            raise AttributeError(column)
        return Ex(E.Col(f"{self._name}.{column}"))

    def __getitem__(self, column: str) -> Ex:
        return Ex(E.Col(f"{self._name}.{column}"))

    def __repr__(self) -> str:
        return f"TableRef({self._name!r})"


def tbl(name: str) -> TableRef:
    return TableRef(name)


def lit(v: float) -> Ex:
    return Ex(E.Lit(float(v)))


_PENDING_WINDOW = "<pending>"


def _agg(func: E.AggFunc, arg: ExLike) -> Ex:
    return Ex(E.Agg(func, _unwrap(arg), _PENDING_WINDOW))


def sum_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.SUM, arg)


def count_(arg: ExLike = 1.0) -> Ex:
    return _agg(E.AggFunc.COUNT, arg)


def avg_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.AVG, arg)


def min_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.MIN, arg)


def max_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.MAX, arg)


def std_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.STD, arg)


def var_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.VAR, arg)


def first_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.FIRST, arg)


def last_(arg: ExLike) -> Ex:
    return _agg(E.AggFunc.LAST, arg)


class QueryBuilder:
    """Fluent builder producing a :class:`Query`."""

    def __init__(self, table: str):
        self._table = table
        self._outputs: List[Tuple[str, E.Expr]] = []
        self._windows: List[Tuple[str, E.WindowSpec]] = []
        self._where: Optional[E.Expr] = None
        self._predict: Optional[Predict] = None
        self._joins: List[Join] = []

    def last_join(self, table: str, *, on: str,
                  order_by: Optional[str] = None) -> "QueryBuilder":
        """Point-in-time LAST JOIN against ``table``.

        ``on`` names the main-table column holding ``table``'s keys (a
        declared join key of the right table); ``order_by`` is the right
        table's timestamp column — mandatory, because LAST JOIN selects
        the latest right row with that timestamp <= the request time.
        Reference joined columns as ``tbl(table).column``.
        """
        self._joins.append(Join(table=table, on=on, order_by=order_by))
        return self

    def window(self, name: str, *, partition_by: str, order_by: str,
               rows: Optional[int] = None,
               range_: Optional[float] = None) -> "QueryBuilder":
        self._windows.append((name, E.WindowSpec(
            name=name, partition_by=partition_by, order_by=order_by,
            rows_preceding=rows, range_preceding=range_)))
        return self

    def select(self, **named: ExLike) -> "QueryBuilder":
        for name, ex in named.items():
            self._outputs.append((name, _unwrap(ex)))
        return self

    def where(self, pred: ExLike) -> "QueryBuilder":
        self._where = _unwrap(pred)
        return self

    def predict(self, model: str, features: Sequence[str],
                output: str = "prediction") -> "QueryBuilder":
        self._predict = Predict(model, tuple(features), output)
        return self

    def build(self) -> Query:
        return Query(table=self._table, outputs=tuple(self._outputs),
                     windows=tuple(self._windows), where=self._where,
                     predict=self._predict, joins=tuple(self._joins))


# ---------------------------------------------------------------------------
# SQL parser (tokenizer + recursive descent)
# ---------------------------------------------------------------------------

_TOKEN_RE = re.compile(r"""
    (?P<ws>\s+)
  | (?P<num>\d+\.\d*|\.\d+|\d+)
  | (?P<id>[A-Za-z_][A-Za-z_0-9]*)
  | (?P<op><=|>=|!=|<>|==|[-+*/%(),.<>=])
""", re.VERBOSE)

# NOTE: "last" is deliberately NOT a keyword (LAST(x) OVER w is an
# aggregate call); the LAST JOIN clause is detected as the identifier
# "last" followed by the keyword "join".
_KEYWORDS = {
    "select", "from", "where", "window", "as", "partition", "by", "order",
    "rows", "range", "between", "preceding", "and", "current", "row", "or",
    "not", "over", "predict", "join", "on",
}

_AGG_NAMES = {
    "sum": E.AggFunc.SUM, "count": E.AggFunc.COUNT, "avg": E.AggFunc.AVG,
    "min": E.AggFunc.MIN, "max": E.AggFunc.MAX, "std": E.AggFunc.STD,
    "stddev": E.AggFunc.STD, "var": E.AggFunc.VAR, "variance": E.AggFunc.VAR,
    "first": E.AggFunc.FIRST, "last": E.AggFunc.LAST,
    "first_value": E.AggFunc.FIRST, "last_value": E.AggFunc.LAST,
}


@dataclass
class _Tok:
    kind: str   # "num" | "id" | "op" | "kw" | "eof"
    text: str
    pos: int


def _tokenize(sql: str) -> List[_Tok]:
    toks: List[_Tok] = []
    pos = 0
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            raise SyntaxError(f"SQL tokenize error at {pos}: {sql[pos:pos+20]!r}")
        pos = m.end()
        if m.lastgroup == "ws":
            continue
        text = m.group()
        kind = m.lastgroup or "op"
        if kind == "id" and text.lower() in _KEYWORDS:
            toks.append(_Tok("kw", text.lower(), m.start()))
        else:
            toks.append(_Tok(kind, text, m.start()))
    toks.append(_Tok("eof", "", len(sql)))
    return toks


class _Parser:
    def __init__(self, sql: str):
        self.sql = sql
        self.toks = _tokenize(sql)
        self.i = 0
        self._anon = 0

    # -- token helpers -----------------------------------------------------
    def peek(self, ahead: int = 0) -> _Tok:
        j = self.i + ahead
        return self.toks[j] if j < len(self.toks) else self.toks[-1]

    def next(self) -> _Tok:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, kind: str, text: Optional[str] = None) -> Optional[_Tok]:
        t = self.peek()
        if t.kind == kind and (text is None or t.text == text):
            return self.next()
        return None

    def expect(self, kind: str, text: Optional[str] = None) -> _Tok:
        t = self.accept(kind, text)
        if t is None:
            got = self.peek()
            raise SyntaxError(
                f"expected {text or kind} at char {got.pos}, got "
                f"{got.text!r} in {self.sql!r}")
        return t

    # -- grammar -----------------------------------------------------------
    def parse(self) -> Query:
        self.expect("kw", "select")
        outputs: List[Tuple[str, E.Expr]] = []
        predicts: List[Tuple[str, List[E.Expr], str]] = []
        while True:
            item, name = self._select_item()
            if isinstance(item, tuple):            # pending PREDICT
                model, args, out = item
                predicts.append((model, args, name or out))
            else:
                outputs.append((name or self._anon_name(), item))
            if not self.accept("op", ","):
                break
        self.expect("kw", "from")
        table = self.expect("id").text
        joins: List[Join] = []
        while (self.peek().kind == "id" and self.peek().text.lower() == "last"
               and self.peek(1).kind == "kw" and self.peek(1).text == "join"):
            joins.append(self._last_join())
        where = None
        if self.accept("kw", "where"):
            where = self._expr()
        windows: List[Tuple[str, E.WindowSpec]] = []
        if self.accept("kw", "window"):
            while True:
                windows.append(self._window_def())
                if not self.accept("op", ","):
                    break
        self.expect("eof")
        if len(predicts) > 1:
            raise SyntaxError("at most one PREDICT per query")
        predict = None
        if predicts:
            # resolve PREDICT args against the FULL select list so alias
            # references work regardless of their position; expression
            # (or raw request-column) args materialise as hidden outputs
            model, args, out = predicts[0]
            aliases = dict(outputs)
            feats: List[str] = []
            for e in args:
                if isinstance(e, E.Col) and e.name in aliases:
                    feats.append(e.name)
                else:
                    synth = f"__pred_arg{len(outputs)}"
                    outputs.append((synth, _sub_aliases(e, aliases)))
                    feats.append(synth)
            predict = Predict(model, tuple(feats), out)
        return Query(table=table, outputs=tuple(outputs),
                     windows=tuple(windows), where=where,
                     predict=predict, joins=tuple(joins))

    def _anon_name(self) -> str:
        self._anon += 1
        return f"_col{self._anon}"

    def _colname(self, strip_table: Optional[str] = None) -> str:
        """Possibly-qualified column name ``id[.id]``. When the qualifier
        equals ``strip_table`` it is dropped (``m.ts`` in a join clause of
        table ``m`` names its own ``ts`` column)."""
        name = self.expect("id").text
        if self.accept("op", "."):
            field = self.expect("id").text
            if strip_table is not None and name == strip_table:
                return field
            return f"{name}.{field}"
        return name

    def _last_join(self) -> Join:
        """``LAST JOIN <table> [ORDER BY <ts_col>] ON <key> [ORDER BY ...]``

        ORDER BY is accepted on either side of ON (OpenMLDB writes it
        before); it is mandatory for point-in-time semantics, but the
        missing-order_by error is raised by ``logical.validate`` so SQL
        and builder queries share one actionable message.
        """
        self.next()                       # "last" (id)
        self.expect("kw", "join")
        jtable = self.expect("id").text

        def order_clause() -> Optional[str]:
            if self.accept("kw", "order"):
                self.expect("kw", "by")
                return self._colname(strip_table=jtable)
            return None

        order_by = order_clause()
        self.expect("kw", "on")
        on = self._colname(strip_table=jtable)
        if order_by is None:
            order_by = order_clause()
        return Join(table=jtable, on=on, order_by=order_by)

    def _select_item(self):
        if self.peek().kind == "kw" and self.peek().text == "predict":
            self.next()
            self.expect("op", "(")
            model = self.expect("id").text
            args: List[E.Expr] = []
            while self.accept("op", ","):
                args.append(self._expr())
            self.expect("op", ")")
            name = None
            if self.accept("kw", "as"):
                name = self.expect("id").text
            # pending: args resolve in parse() once every alias is known
            return (model, args, name or "prediction"), name
        e = self._expr()
        name = None
        if self.accept("kw", "as"):
            name = self.expect("id").text
        elif isinstance(e, E.Col):
            name = e.name
        return e, name

    def _window_def(self) -> Tuple[str, E.WindowSpec]:
        name = self.expect("id").text
        self.expect("kw", "as")
        self.expect("op", "(")
        self.expect("kw", "partition")
        self.expect("kw", "by")
        part = self._colname()
        self.expect("kw", "order")
        self.expect("kw", "by")
        order = self._colname()
        rows = rng = None
        if self.accept("kw", "rows"):
            rows = int(self._frame_bound())
        elif self.accept("kw", "range"):
            rng = float(self._frame_bound())
        else:
            raise SyntaxError(f"window {name}: expected ROWS or RANGE")
        self.expect("op", ")")
        return name, E.WindowSpec(name=name, partition_by=part,
                                  order_by=order, rows_preceding=rows,
                                  range_preceding=rng)

    def _frame_bound(self) -> float:
        self.expect("kw", "between")
        n = float(self.expect("num").text)
        self.expect("kw", "preceding")
        self.expect("kw", "and")
        self.expect("kw", "current")
        self.expect("kw", "row")
        return n

    # expression precedence: or < and < not < cmp < addsub < muldiv < unary
    def _expr(self) -> E.Expr:
        return self._or()

    def _or(self) -> E.Expr:
        e = self._and()
        while self.accept("kw", "or"):
            e = E.BinOp("or", e, self._and())
        return e

    def _and(self) -> E.Expr:
        e = self._not()
        while self.accept("kw", "and"):
            e = E.BinOp("and", e, self._not())
        return e

    def _not(self) -> E.Expr:
        if self.accept("kw", "not"):
            return E.Func("not", (self._not(),))
        return self._cmp()

    def _cmp(self) -> E.Expr:
        e = self._addsub()
        t = self.peek()
        if t.kind == "op" and t.text in (">", ">=", "<", "<=", "=", "==",
                                         "!=", "<>"):
            self.next()
            op = {"=": "==", "<>": "!="}.get(t.text, t.text)
            return E.BinOp(op, e, self._addsub())
        return e

    def _addsub(self) -> E.Expr:
        e = self._muldiv()
        while True:
            t = self.peek()
            if t.kind == "op" and t.text in ("+", "-"):
                self.next()
                e = E.BinOp(t.text, e, self._muldiv())
            else:
                return e

    def _muldiv(self) -> E.Expr:
        e = self._unary()
        while True:
            t = self.peek()
            if t.kind == "op" and t.text in ("*", "/", "%"):
                self.next()
                e = E.BinOp(t.text, e, self._unary())
            else:
                return e

    def _unary(self) -> E.Expr:
        if self.accept("op", "-"):
            return E.Func("neg", (self._unary(),))
        return self._atom()

    def _atom(self) -> E.Expr:
        if self.accept("op", "("):
            e = self._expr()
            self.expect("op", ")")
            return e
        t = self.peek()
        if t.kind == "num":
            self.next()
            return E.Lit(float(t.text))
        if t.kind == "id":
            self.next()
            low = t.text.lower()
            if self.peek().kind == "op" and self.peek().text == "(":
                return self._call(low)
            if (self.peek().kind == "op" and self.peek().text == "."
                    and self.peek(1).kind == "id"):
                self.next()                      # "." — qualified t.col ref
                return E.Col(f"{t.text}.{self.next().text}")
            return E.Col(t.text)
        raise SyntaxError(f"unexpected token {t.text!r} at char {t.pos}")

    def _call(self, fname: str) -> E.Expr:
        self.expect("op", "(")
        args: List[E.Expr] = []
        if not (self.peek().kind == "op" and self.peek().text == ")"):
            if fname == "count" and self.accept("op", "*"):
                args.append(E.Lit(1.0))
            else:
                args.append(self._expr())
                while self.accept("op", ","):
                    args.append(self._expr())
        self.expect("op", ")")
        if fname in _AGG_NAMES:
            self.expect("kw", "over")
            win = self.expect("id").text
            arg = args[0] if args else E.Lit(1.0)
            return E.Agg(_AGG_NAMES[fname], arg, win)
        if fname in E.scalar_func_names():
            return E.Func(fname, tuple(args))
        raise SyntaxError(f"unknown function {fname!r}")


def _sub_aliases(e: E.Expr, aliases: dict) -> E.Expr:
    """Replace top-level references to earlier SELECT aliases with their
    defining expressions (PREDICT expression arguments evaluate in event/
    aggregate scope, where aliases don't exist). Agg nodes are leaves —
    their arguments are event columns, never aliases."""
    if isinstance(e, E.Agg):
        return e
    if isinstance(e, E.Col) and e.name in aliases:
        return aliases[e.name]
    kids = tuple(_sub_aliases(c, aliases) for c in E.children(e))
    return E.replace_children(e, kids)


def parse_sql(sql: str) -> Query:
    """Parse the OpenMLDB-style feature-query SQL subset into a Query."""
    return _Parser(sql).parse()


_EXPLAIN_ANALYZE_RE = re.compile(r"^\s*explain\s+analyze\b", re.IGNORECASE)


def strip_explain_analyze(sql: str) -> Optional[str]:
    """``"EXPLAIN ANALYZE SELECT ..."`` -> ``"SELECT ..."``; ``None``
    when ``sql`` does not start with the EXPLAIN ANALYZE prefix (the
    engine then treats it as a deployment name). EXPLAIN/ANALYZE are
    deliberately not parser keywords — they never appear inside a query
    body, only as this statement prefix."""
    m = _EXPLAIN_ANALYZE_RE.match(sql)
    if m is None:
        return None
    return sql[m.end():].lstrip()
