"""Logical plan for SQL+ML feature queries.

A query compiles to a small tree of logical operators:

    Scan -> [Filter] -> WindowProject -> [Predict] -> Output

``WindowProject`` is the workhorse: a set of named output expressions over
request columns and window aggregates (OpenMLDB's "window union" stage).
``Predict`` embeds an ML model invocation over computed features (the
paper's PREDICT_CHURN / DETECT_FRAUD style SQL+ML functions).

The logical plan is immutable; optimizer passes rewrite it functionally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core import expr as E

__all__ = [
    "LogicalPlan",
    "Scan",
    "Filter",
    "Join",
    "WindowProject",
    "Predict",
    "Query",
    "validate",
]


@dataclass(frozen=True)
class Scan:
    """Scan of one event table; ``columns`` narrowed by column pruning."""

    table: str
    columns: Tuple[str, ...]  # value columns needed from storage

    def __repr__(self) -> str:
        return f"Scan({self.table},cols={list(self.columns)})"


@dataclass(frozen=True)
class Filter:
    """Row-level predicate applied to events before window aggregation
    (WHERE clause over event columns)."""

    pred: Optional[E.Expr]

    def __repr__(self) -> str:
        return f"Filter({self.pred!r})"


@dataclass(frozen=True)
class Join:
    """Point-in-time ``LAST JOIN`` against one right-hand table.

    For every request the engine resolves ``on`` (a main-table column
    holding right-table keys) through the right table's key directory and
    selects the **latest** right row with ``order_by``-timestamp ≤ the
    request timestamp — OpenMLDB's LAST JOIN semantics on ring buffers.
    Joined columns enter the slot environment as ``"<table>.<col>"`` and
    behave exactly like request-row columns downstream.

    ``order_by`` is the right table's timestamp column; it is mandatory
    (LAST JOIN without an ordering is ambiguous) and must equal the right
    table's ``ts_col`` — the ring buffer is physically ordered by it.
    ``columns`` is narrowed by the optimizer's join-aware column pruning;
    ``()`` means "not yet pruned" (all right value columns).
    """

    table: str
    on: str
    order_by: Optional[str] = None
    columns: Tuple[str, ...] = ()

    def __repr__(self) -> str:
        return (f"LastJoin({self.table},on={self.on},"
                f"order_by={self.order_by},cols={list(self.columns)})")


@dataclass(frozen=True)
class WindowProject:
    """Named output expressions over request columns + window aggregates.

    ``outputs``   — (name, expr) pairs; exprs may contain Agg nodes.
    ``windows``   — window name -> WindowSpec.
    """

    outputs: Tuple[Tuple[str, E.Expr], ...]
    windows: Tuple[Tuple[str, E.WindowSpec], ...]

    def window_map(self) -> Dict[str, E.WindowSpec]:
        return dict(self.windows)

    def __repr__(self) -> str:
        outs = ",".join(f"{n}={e!r}" for n, e in self.outputs)
        wins = ",".join(f"{n}:{w!r}" for n, w in self.windows)
        return f"WindowProject([{outs}],windows=[{wins}])"


@dataclass(frozen=True)
class Predict:
    """ML inference over a subset of the projected features.

    ``model`` names a model registered with the engine;
    ``features`` are output names from the WindowProject stage;
    ``output`` is the name of the prediction column.
    """

    model: str
    features: Tuple[str, ...]
    output: str

    def __repr__(self) -> str:
        return f"Predict({self.model},{list(self.features)}->{self.output})"


@dataclass(frozen=True)
class LogicalPlan:
    scan: Scan
    filter: Filter
    project: WindowProject
    predict: Optional[Predict] = None
    # LAST JOINs in probe order (the optimizer's join-ordering pass sorts
    # them by estimated right-table probe cost)
    joins: Tuple[Join, ...] = field(default=())
    # Physical hints attached by the optimizer (not part of SQL semantics).
    # window name -> "naive" | "preagg" | "fused" (fused = member of the
    # deployment's single-scan multi-window launch)
    window_impl: Tuple[Tuple[str, str], ...] = field(default=())

    def fingerprint(self) -> str:
        """Stable structural fingerprint — the plan-cache key component."""
        return (f"{self.scan!r}|{self.filter!r}|{self.joins!r}|"
                f"{self.project!r}|{self.predict!r}|"
                f"{dict(self.window_impl)!r}")

    def with_(self, **kw) -> "LogicalPlan":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Query:
    """A parsed-but-unoptimized query (the DSL/SQL front-end output)."""

    table: str
    outputs: Tuple[Tuple[str, E.Expr], ...]
    windows: Tuple[Tuple[str, E.WindowSpec], ...]
    where: Optional[E.Expr] = None
    predict: Optional[Predict] = None
    joins: Tuple[Join, ...] = ()

    def to_logical(self) -> LogicalPlan:
        # Before optimization, scan conservatively requests every column
        # referenced anywhere (pruning narrows this later). Qualified
        # "table.col" references belong to joined tables, never to the
        # main scan.
        cols: Dict[str, None] = {}
        for _, e in self.outputs:
            for c in E.collect_columns(e):
                if "." not in c:
                    cols.setdefault(c)
        if self.where is not None:
            for c in E.collect_columns(self.where):
                if "." not in c:
                    cols.setdefault(c)
        plan = LogicalPlan(
            scan=Scan(self.table, tuple(cols)),
            filter=Filter(self.where),
            project=WindowProject(self.outputs, self.windows),
            predict=self.predict,
            joins=self.joins,
        )
        validate(plan)
        return plan


def validate(plan: LogicalPlan) -> None:
    """Check window references + predict feature references resolve."""
    # -- joins: structural checks that need no catalog ---------------------
    seen_tables = set()
    for j in plan.joins:
        if j.table == plan.scan.table:
            raise ValueError(
                f"LAST JOIN of table {j.table!r} with itself is not "
                f"supported; the right side must be a different table")
        if j.table in seen_tables:
            raise ValueError(
                f"table {j.table!r} is LAST JOINed twice; join each right "
                f"table at most once (alias support is a ROADMAP item)")
        seen_tables.add(j.table)
        if not j.order_by:
            raise ValueError(
                f"last_join on table {j.table!r} requires order_by: LAST "
                f"JOIN is point-in-time — it selects the latest right-table "
                f"row with timestamp <= the request timestamp, so the "
                f"ordering column is part of the semantics. Pass "
                f"order_by=<the right table's timestamp column>")
    # Windows index the main table's (key, ts) only: a joined table's
    # columns are per-request values and can neither partition nor order
    # a window over the main ring buffer.
    for wname, spec in plan.project.windows:
        for role, c in (("partition_by", spec.partition_by),
                        ("order_by", spec.order_by)):
            if "." in c and c.split(".", 1)[0] in seen_tables:
                raise ValueError(
                    f"window {wname!r} {role.upper().replace('_', ' ')} "
                    f"references joined-table column {c!r}; windows index "
                    f"the main table's (key, ts) only — LAST JOIN results "
                    f"are per-request values and cannot partition or order "
                    f"a window. Partition/order by main-table columns, or "
                    f"deploy the window query on {c.split('.', 1)[0]!r} "
                    f"directly")
    # Every qualified "table.col" reference must name a LAST JOINed table
    # — deploy-time error, never a KeyError on the serving path.
    for where, exprs in (("SELECT", [e for _, e in plan.project.outputs]),
                         ("WHERE", [plan.filter.pred]
                          if plan.filter.pred is not None else [])):
        for e in exprs:
            for c in E.collect_columns(e):
                if "." in c and c.split(".", 1)[0] not in seen_tables:
                    raise ValueError(
                        f"{where} references qualified column {c!r}, but "
                        f"table {c.split('.', 1)[0]!r} is not LAST JOINed "
                        f"in this query (joined: {sorted(seen_tables)})")
    wmap = plan.project.window_map()
    for name, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            if agg.window not in wmap:
                raise ValueError(
                    f"output {name!r} references undefined window "
                    f"{agg.window!r}; defined: {sorted(wmap)}")
    if plan.filter.pred is not None:
        # WHERE filters raw events BEFORE window aggregation: windowed
        # outputs (and window aggregates themselves) are out of scope.
        if E.collect_aggs(plan.filter.pred):
            raise ValueError(
                "WHERE cannot contain window aggregates; it filters raw "
                "events before window aggregation (filter on event "
                "columns, or post-filter the feature outputs)")
        # any non-identity SELECT alias (windowed or derived) is out of
        # scope in WHERE; identity aliases (SELECT user_id) still name
        # the underlying event column and stay legal
        aliased = {n for n, e in plan.project.outputs
                   if not (isinstance(e, E.Col) and e.name == n)}
        bad = sorted(c for c in E.collect_columns(plan.filter.pred)
                     if c in aliased)
        if bad:
            raise ValueError(
                f"WHERE references SELECT alias(es) {bad}; WHERE filters "
                f"raw events before projection and window aggregation — "
                f"reference event columns instead")
    if plan.predict is not None:
        out_names = {n for n, _ in plan.project.outputs}
        missing = [f for f in plan.predict.features if f not in out_names]
        if missing:
            raise ValueError(
                f"Predict references unknown features {missing}; "
                f"available: {sorted(out_names)}")
    # Every window must share the table's partition/order columns — the
    # storage layer indexes one (key, ts) pair per table.
    parts = {w.partition_by for _, w in plan.project.windows}
    orders = {w.order_by for _, w in plan.project.windows}
    if len(parts) > 1 or len(orders) > 1:
        raise ValueError(
            f"all windows in one query must share PARTITION BY / ORDER BY "
            f"columns (got partitions={sorted(parts)}, orders={sorted(orders)})")
