"""Structured serving results + request context.

The hot path used to speak ``Dict[str, np.ndarray]`` and nothing else:
no way to tell which deployment *version* served a batch, whether a key
was unknown, or what the request actually cost. :class:`FeatureFrame`
carries that metadata while remaining a drop-in ``Mapping`` — every
pre-existing call site (``out["amt_sum_10"]``, ``res.items()``,
``for name in out``) keeps working unchanged.

:class:`RequestContext` flows from ``FeatureServer.request`` through the
``DynamicBatcher`` into the engine. Its ``version_pin`` is the batch
grouping key — the batcher never mixes differently-pinned requests in
one batch, which (together with the engine resolving ONE handle per
batch) is what keeps a batch on a single deployment version mid-swap.
"""
from __future__ import annotations

import time
from collections.abc import Mapping
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["FeatureFrame", "RequestContext", "DeadlineExceeded",
           "STATUS_OK", "STATUS_UNKNOWN_KEY", "STATUS_SHED",
           "STATUS_DEGRADED"]

STATUS_OK = 0
STATUS_UNKNOWN_KEY = 1
# the request was load-shed (deadline passed, or admission control dropped
# it) BEFORE any feature computation — the whole batch carries this status,
# never a mix of shed and computed rows (repro.shard.resource)
STATUS_SHED = 2
# the owning shard is down/recovering and this row was answered from the
# stale-tier cache (last feature row the shard published for this key)
# instead of being shed — possibly-stale values, still usable for models
# that prefer a slightly-old feature to none (DESIGN.md §12 degradation
# ladder OK→DEGRADED→SHED); unlike SHED this CAN mix with OK rows in one
# batch (only the dead shard's keys degrade)
STATUS_DEGRADED = 3


class DeadlineExceeded(TimeoutError):
    """A request's deadline passed before (or while) it could be served."""


@dataclass(frozen=True)
class RequestContext:
    """Per-request serving context.

    ``deadline`` is an absolute ``time.monotonic()`` instant; expired
    requests are dropped by the batcher instead of wasting a batch slot.
    ``version_pin`` routes the request to one specific deployment version
    (e.g. replaying traffic against a retired version after a swap).
    ``trace_id``/``parent_span`` carry the distributed-tracing context
    (DESIGN.md §13): the id is generated ULID-style at the serving edge
    when absent, and each tier that opens a span re-parents the context
    it forwards (``dataclasses.replace(ctx, parent_span=span.span_id)``)
    so the reassembled trace is a tree, not a flat list.
    """

    deadline: Optional[float] = None
    trace_id: Optional[str] = None
    version_pin: Optional[int] = None
    parent_span: Optional[str] = None

    @classmethod
    def with_timeout(cls, timeout_s: float, **kw) -> "RequestContext":
        return cls(deadline=time.monotonic() + timeout_s, **kw)

    @property
    def expired(self) -> bool:
        return self.deadline is not None and time.monotonic() > self.deadline

    def remaining(self) -> Optional[float]:
        if self.deadline is None:
            return None
        return self.deadline - time.monotonic()


class FeatureFrame(Mapping):
    """Named feature columns + per-request status + serving metadata.

    Mapping protocol is over the feature columns, so a FeatureFrame is
    backwards-compatible with the raw dict the engine used to return.
    """

    __slots__ = ("columns", "status", "deployment", "version",
                 "table_version", "latency", "trace_id", "version_vector",
                 "watermark", "feature_age")

    def __init__(self, columns: Dict[str, np.ndarray], *,
                 status: Optional[np.ndarray] = None,
                 deployment: str = "", version: int = 0,
                 table_version: int = -1,
                 latency: Optional[Dict[str, float]] = None,
                 trace_id: Optional[str] = None,
                 version_vector: Optional[tuple] = None,
                 watermark: Optional[float] = None,
                 feature_age: Optional[float] = None):
        self.columns = dict(columns)
        if status is None:
            status = np.zeros((0,), np.int8)
        self.status = np.asarray(status, np.int8)
        self.deployment = deployment
        self.version = version
        self.table_version = table_version
        self.latency = dict(latency) if latency else {}
        self.trace_id = trace_id
        # sharded serving: per-shard table snapshot versions (shard order)
        # for the batch — the cross-shard analogue of ``table_version``
        self.version_vector = version_vector
        # freshness stamp (DESIGN.md §14): max event-time the served
        # snapshot covered, and this batch's worst feature age (request
        # event-time − watermark, event-time units; sharded serving
        # stamps the MIN watermark / MAX age across touched shards)
        self.watermark = watermark
        self.feature_age = feature_age

    # ---------------------------------------------------- Mapping protocol
    def __getitem__(self, name: str) -> np.ndarray:
        return self.columns[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self.columns)

    def __len__(self) -> int:
        return len(self.columns)

    # ------------------------------------------------------------- helpers
    def to_dict(self) -> Dict[str, np.ndarray]:
        """Plain dict of the feature columns (metadata dropped)."""
        return dict(self.columns)

    @property
    def all_ok(self) -> bool:
        return bool((self.status == STATUS_OK).all())

    @property
    def n_unknown(self) -> int:
        return int((self.status == STATUS_UNKNOWN_KEY).sum())

    @property
    def n_shed(self) -> int:
        return int((self.status == STATUS_SHED).sum())

    @property
    def n_degraded(self) -> int:
        return int((self.status == STATUS_DEGRADED).sum())

    def row(self, i: int) -> "FeatureFrame":
        """Single-request view (scalar columns), keeping the metadata —
        how the batcher splits one engine batch into per-caller results."""
        return FeatureFrame(
            {n: v[i] for n, v in self.columns.items()},
            status=self.status[i:i + 1] if self.status.size else None,
            deployment=self.deployment, version=self.version,
            table_version=self.table_version, latency=self.latency,
            trace_id=self.trace_id, version_vector=self.version_vector,
            watermark=self.watermark, feature_age=self.feature_age)

    def __repr__(self) -> str:
        return (f"FeatureFrame({sorted(self.columns)}, "
                f"deployment={self.deployment!r} v{self.version}, "
                f"n={self.status.size}, unknown={self.n_unknown})")
