"""Expression IR for SQL+ML feature queries.

Two expression layers, mirroring OpenMLDB's planner:

* scalar expressions (``Col``, ``Lit``, ``BinOp``, ``Func``, ``Cast``) that
  evaluate row-wise over event columns or over already-computed features, and
* aggregate expressions (``Agg``) that reduce a scalar expression over a
  named window.

Expressions are immutable, hashable dataclasses so that plans can be
fingerprinted for the compiled-plan cache (paper §4 "caching") and compared
structurally by the optimizer's CSE pass.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "AggFunc",
    "Agg",
    "BinOp",
    "Cast",
    "Col",
    "Expr",
    "Func",
    "Lit",
    "WindowSpec",
    "walk",
    "children",
    "replace_children",
    "collect_columns",
    "collect_aggs",
]


class AggFunc(enum.Enum):
    """Window aggregate functions supported by the engine."""

    SUM = "sum"
    COUNT = "count"
    AVG = "avg"
    MIN = "min"
    MAX = "max"
    STD = "std"
    VAR = "var"
    FIRST = "first"   # oldest event in window
    LAST = "last"     # newest event in window

    @property
    def decomposable(self) -> bool:
        """True if expressible via moment aggregates (pre-agg friendly)."""
        return self in (AggFunc.SUM, AggFunc.COUNT, AggFunc.AVG, AggFunc.STD,
                        AggFunc.VAR)

    @property
    def invertible(self) -> bool:
        """True if ``F(t) - F(t-W)`` subtraction applies (paper Eq. 2)."""
        return self in (AggFunc.SUM, AggFunc.COUNT)


@dataclass(frozen=True)
class Expr:
    """Base class for all expressions."""

    def fingerprint(self) -> str:
        return repr(self)


@dataclass(frozen=True)
class Col(Expr):
    name: str

    def __repr__(self) -> str:  # stable fingerprints
        return f"Col({self.name})"


@dataclass(frozen=True)
class Lit(Expr):
    value: float

    def __repr__(self) -> str:
        return f"Lit({self.value!r})"


@dataclass(frozen=True)
class BinOp(Expr):
    op: str  # + - * / // % > >= < <= == != and or
    lhs: Expr
    rhs: Expr

    def __repr__(self) -> str:
        return f"BinOp({self.op},{self.lhs!r},{self.rhs!r})"


@dataclass(frozen=True)
class Func(Expr):
    """Scalar function call: log, log1p, abs, sqrt, exp, neg, min2, max2,
    sigmoid, relu, clip(lo,hi) …"""

    name: str
    args: Tuple[Expr, ...]

    def __repr__(self) -> str:
        return f"Func({self.name},{list(self.args)!r})"


@dataclass(frozen=True)
class Cast(Expr):
    to: str  # "f32" | "i32" | "bool"
    arg: Expr

    def __repr__(self) -> str:
        return f"Cast({self.to},{self.arg!r})"


@dataclass(frozen=True)
class WindowSpec:
    """``WINDOW w AS (PARTITION BY key ORDER BY ts {ROWS|RANGE} BETWEEN
    <n> PRECEDING AND CURRENT ROW)``.

    ``rows_preceding`` — count-based window of the most recent N events.
    ``range_preceding`` — time-based window covering ``[t - range, t]``.
    Exactly one of the two must be set.
    """

    name: str
    partition_by: str
    order_by: str
    rows_preceding: Optional[int] = None
    range_preceding: Optional[float] = None

    def __post_init__(self) -> None:
        if (self.rows_preceding is None) == (self.range_preceding is None):
            raise ValueError(
                f"window {self.name!r}: exactly one of rows_preceding / "
                f"range_preceding must be given")

    @property
    def is_rows(self) -> bool:
        return self.rows_preceding is not None

    def frame_fingerprint(self) -> str:
        """Fingerprint of the frame only (ignores the window's name) —
        used by the window-merge optimizer pass."""
        return (f"W(p={self.partition_by},o={self.order_by},"
                f"rows={self.rows_preceding},range={self.range_preceding})")

    def __repr__(self) -> str:
        return f"{self.frame_fingerprint()}#{self.name}"


@dataclass(frozen=True)
class Agg(Expr):
    """Aggregate of a scalar expression over a named window."""

    func: AggFunc
    arg: Expr                  # Lit(1.0) for COUNT(*)
    window: str                # window name, resolved against the plan's specs

    def __repr__(self) -> str:
        return f"Agg({self.func.value},{self.arg!r},{self.window})"


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------

def children(e: Expr) -> Tuple[Expr, ...]:
    if isinstance(e, BinOp):
        return (e.lhs, e.rhs)
    if isinstance(e, Func):
        return e.args
    if isinstance(e, Cast):
        return (e.arg,)
    if isinstance(e, Agg):
        return (e.arg,)
    return ()


def replace_children(e: Expr, new: Tuple[Expr, ...]) -> Expr:
    if isinstance(e, BinOp):
        return dataclasses.replace(e, lhs=new[0], rhs=new[1])
    if isinstance(e, Func):
        return dataclasses.replace(e, args=tuple(new))
    if isinstance(e, Cast):
        return dataclasses.replace(e, arg=new[0])
    if isinstance(e, Agg):
        return dataclasses.replace(e, arg=new[0])
    assert not new
    return e


def walk(e: Expr) -> Iterable[Expr]:
    """Pre-order traversal."""
    yield e
    for c in children(e):
        yield from walk(c)


def collect_columns(e: Expr) -> Tuple[str, ...]:
    seen: Dict[str, None] = {}
    for node in walk(e):
        if isinstance(node, Col):
            seen.setdefault(node.name)
    return tuple(seen)


def collect_aggs(e: Expr) -> Tuple[Agg, ...]:
    return tuple(n for n in walk(e) if isinstance(n, Agg))


# ---------------------------------------------------------------------------
# Scalar evaluation over a dict of arrays (row-major, broadcastable)
# ---------------------------------------------------------------------------

_BINOPS: Dict[str, Callable[[Any, Any], Any]] = {
    "+": lambda a, b: a + b,
    "-": lambda a, b: a - b,
    "*": lambda a, b: a * b,
    "/": lambda a, b: a / b,
    "//": lambda a, b: a // b,
    "%": lambda a, b: a % b,
    ">": lambda a, b: a > b,
    ">=": lambda a, b: a >= b,
    "<": lambda a, b: a < b,
    "<=": lambda a, b: a <= b,
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    "and": lambda a, b: jnp.logical_and(a, b),
    "or": lambda a, b: jnp.logical_or(a, b),
}

_FUNCS: Dict[str, Callable[..., Any]] = {
    "log": jnp.log,
    "log1p": jnp.log1p,
    "abs": jnp.abs,
    "sqrt": jnp.sqrt,
    "exp": jnp.exp,
    "neg": lambda x: -x,
    "not": jnp.logical_not,
    "min2": jnp.minimum,
    "max2": jnp.maximum,
    "sigmoid": lambda x: 1.0 / (1.0 + jnp.exp(-x)),
    "relu": lambda x: jnp.maximum(x, 0.0),
    "floor": jnp.floor,
    "ceil": jnp.ceil,
    "clip": lambda x, lo, hi: jnp.clip(x, lo, hi),
    "if": jnp.where,          # if(cond, a, b)
    # Aggregate-decomposition helpers (optimizer pass O1): guarded against
    # empty windows (count == 0 -> 0, matching engine empty-window policy).
    "safe_div": lambda a, b: jnp.where(b > 0, a / jnp.maximum(b, 1e-30), 0.0),
    "safe_var": lambda sq, s, c: jnp.where(
        c > 0,
        jnp.maximum(sq / jnp.maximum(c, 1.0)
                    - (s / jnp.maximum(c, 1.0)) ** 2, 0.0),
        0.0),
    "safe_std": lambda sq, s, c: jnp.sqrt(jnp.where(
        c > 0,
        jnp.maximum(sq / jnp.maximum(c, 1.0)
                    - (s / jnp.maximum(c, 1.0)) ** 2, 0.0),
        0.0)),
}

_CASTS = {"f32": jnp.float32, "i32": jnp.int32, "bool": jnp.bool_}


def eval_scalar(e: Expr, env: Dict[str, Any]):
    """Evaluate a scalar expression against ``env`` (column name -> array).

    ``Agg`` nodes must have been replaced with ``Col`` references to
    materialised aggregate outputs before calling this (the physical planner
    guarantees that).
    """
    if isinstance(e, Col):
        if e.name not in env:
            raise KeyError(f"unknown column {e.name!r}; have {sorted(env)}")
        return env[e.name]
    if isinstance(e, Lit):
        return jnp.asarray(e.value, dtype=jnp.float32)
    if isinstance(e, BinOp):
        fn = _BINOPS.get(e.op)
        if fn is None:
            raise ValueError(f"unknown binop {e.op!r}")
        return fn(eval_scalar(e.lhs, env), eval_scalar(e.rhs, env))
    if isinstance(e, Func):
        fn = _FUNCS.get(e.name)
        if fn is None:
            raise ValueError(f"unknown function {e.name!r}")
        return fn(*(eval_scalar(a, env) for a in e.args))
    if isinstance(e, Cast):
        return eval_scalar(e.arg, env).astype(_CASTS[e.to])
    if isinstance(e, Agg):
        raise TypeError("Agg node reached scalar evaluation — physical "
                        "planner must materialise aggregates first")
    raise TypeError(f"unknown expr node {type(e).__name__}")


def scalar_func_names() -> Tuple[str, ...]:
    return tuple(_FUNCS)
