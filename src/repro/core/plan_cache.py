"""Compiled-execution-plan cache — the paper's O2 "caching" layer.

OpenMLDB caches LLVM-JIT'd plans per deployed query; we cache XLA-compiled
executables keyed by ``(plan fingerprint, request-batch bucket, flags)``.
Entries are LRU-evicted under a bounded count (resource management, O5).

Deployment lifecycle hooks (DESIGN.md §6):

* ``invalidate(prefix)`` drops every entry whose plan fingerprint starts
  with ``prefix`` — called on hot-swap redeploys so a retired version's
  executables don't squat in the LRU until eviction;
* ``tag=`` on ``get_or_compile`` attributes hits/misses/compile-time to a
  deployment version (``name@vN``), so per-deployment cache behaviour is
  observable (``tag_stats``). Handle-owned first-level lookups report
  through ``record_hit`` so the hit-rate bookkeeping stays truthful.

The cache also keeps the latency bookkeeping the paper's Eq. 3 decomposes:
``L = L_parse + L_plan + L_exec`` — compile time is charged to L_plan on
miss and amortised to ~0 on hit.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, Optional, Tuple

__all__ = ["PlanCache", "CacheStats", "TagStats", "bucket_batch"]

_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_batch(n: int) -> int:
    """Round a request-batch size up to a power-of-two bucket so compiled
    executables are reused across nearby batch sizes (shape bucketing)."""
    for b in _BUCKETS:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    invalidations: int = 0
    compile_seconds: float = 0.0

    _FIELDS = ("hits", "misses", "evictions", "invalidations",
               "compile_seconds")

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict:
        """Monotonic-counters copy (plus the derived hit_rate) — what the
        control plane's collector diffs across sampling intervals."""
        out = {f: getattr(self, f) for f in self._FIELDS}
        out["hit_rate"] = self.hit_rate
        return out


@dataclass
class TagStats:
    """Per-deployment-version slice of the cache counters."""

    hits: int = 0
    misses: int = 0
    compile_seconds: float = 0.0


@dataclass
class _Entry:
    fn: Callable
    compile_seconds: float
    hits: int = 0


class PlanCache:
    def __init__(self, max_entries: int = 128, enabled: bool = True):
        self.max_entries = max_entries
        self.enabled = enabled
        self._entries: "collections.OrderedDict[Hashable, _Entry]" = (
            collections.OrderedDict())
        self.stats = CacheStats()
        self._by_tag: Dict[str, TagStats] = {}
        # serving threads look up / insert while deploy threads
        # invalidate — every _entries mutation happens under this lock
        # (compiles themselves run outside it)
        self._mu = threading.Lock()

    def _tag(self, tag: Optional[str]) -> Optional[TagStats]:
        if tag is None:
            return None
        ts = self._by_tag.get(tag)
        if ts is None:
            ts = self._by_tag[tag] = TagStats()
        return ts

    def get_or_compile(self, key: Hashable, make: Callable[[], Callable],
                       tag: Optional[str] = None) -> Tuple[Callable, float]:
        """Return (compiled_fn, plan_seconds). ``make`` must return an
        already-compiled callable (e.g. a jitted fn after warm-up lower)."""
        with self._mu:
            tstats = self._tag(tag)
            if self.enabled:
                ent = self._entries.get(key)
                if ent is not None:
                    self._entries.move_to_end(key)
                    ent.hits += 1
                    self.stats.hits += 1
                    if tstats is not None:
                        tstats.hits += 1
                    return ent.fn, 0.0
        t0 = time.perf_counter()
        fn = make()               # compile outside the lock: a slow XLA
        dt = time.perf_counter() - t0   # lower must not block lookups
        with self._mu:
            self.stats.misses += 1
            self.stats.compile_seconds += dt
            if tstats is not None:
                tstats.misses += 1
                tstats.compile_seconds += dt
            if self.enabled:
                self._entries[key] = _Entry(fn=fn, compile_seconds=dt)
                self._entries.move_to_end(key)
                while len(self._entries) > self.max_entries:
                    self._entries.popitem(last=False)
                    self.stats.evictions += 1
        return fn, dt

    def record_hit(self, tag: Optional[str] = None) -> None:
        """Count a hit served from a first-level (handle-owned) table.

        Deployment handles memoise their own executables; without this the
        cache's hit-rate would undercount every warmed-path request."""
        with self._mu:
            self.stats.hits += 1
            tstats = self._tag(tag)
            if tstats is not None:
                tstats.hits += 1

    def invalidate(self, prefix: str) -> int:
        """Drop every entry whose plan-fingerprint component (the first
        element of a tuple key, or a plain string key) starts with
        ``prefix``. Returns the number of entries removed."""
        removed = 0
        with self._mu:
            for key in list(self._entries):
                fp = key[0] if isinstance(key, tuple) and key else key
                if isinstance(fp, str) and fp.startswith(prefix):
                    del self._entries[key]
                    removed += 1
            self.stats.invalidations += removed
        return removed

    def tag_stats(self, tag: str) -> TagStats:
        """Counters attributed to one deployment version (empty if unseen)."""
        with self._mu:
            return self._by_tag.get(tag, TagStats())

    def clear(self) -> None:
        with self._mu:
            self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)
