"""Compiled-execution-plan cache — the paper's O2 "caching" layer.

OpenMLDB caches LLVM-JIT'd plans per deployed query; we cache XLA-compiled
executables keyed by ``(plan fingerprint, request-batch bucket, flags)``.
Entries are LRU-evicted under a bounded count (resource management, O5).

The cache also keeps the latency bookkeeping the paper's Eq. 3 decomposes:
``L = L_parse + L_plan + L_exec`` — compile time is charged to L_plan on
miss and amortised to ~0 on hit.
"""
from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Hashable, Optional, Tuple

__all__ = ["PlanCache", "CacheStats", "bucket_batch"]

_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def bucket_batch(n: int) -> int:
    """Round a request-batch size up to a power-of-two bucket so compiled
    executables are reused across nearby batch sizes (shape bucketing)."""
    for b in _BUCKETS:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    compile_seconds: float = 0.0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclass
class _Entry:
    fn: Callable
    compile_seconds: float
    hits: int = 0


class PlanCache:
    def __init__(self, max_entries: int = 128, enabled: bool = True):
        self.max_entries = max_entries
        self.enabled = enabled
        self._entries: "collections.OrderedDict[Hashable, _Entry]" = (
            collections.OrderedDict())
        self.stats = CacheStats()

    def get_or_compile(self, key: Hashable,
                       make: Callable[[], Callable]) -> Tuple[Callable, float]:
        """Return (compiled_fn, plan_seconds). ``make`` must return an
        already-compiled callable (e.g. a jitted fn after warm-up lower)."""
        if self.enabled:
            ent = self._entries.get(key)
            if ent is not None:
                self._entries.move_to_end(key)
                ent.hits += 1
                self.stats.hits += 1
                return ent.fn, 0.0
        t0 = time.perf_counter()
        fn = make()
        dt = time.perf_counter() - t0
        self.stats.misses += 1
        self.stats.compile_seconds += dt
        if self.enabled:
            self._entries[key] = _Entry(fn=fn, compile_seconds=dt)
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
        return fn, dt

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)
