"""Physical planning: LogicalPlan → pure JAX executable.

The physical plan materialises every *unique* aggregate once (CSE), groups
aggregates by window so each window runs ONE fused scan (window merge), and
lowers each window group through either the naive fused-scan kernel or the
pre-aggregation kernel as chosen by the optimizer (``plan.window_impl``).

The emitted executor is a pure function

    executor(state, preagg, key_idx, req_ts, req_row, model_params)
        -> {output_name: (B,) or (B, k) array}

suitable for ``jax.jit`` (the plan cache owns compilation) and for
``shard_map``/``pjit`` batch sharding in the offline path.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import expr as E
from repro.core.logical import LogicalPlan
from repro.core.optimizer import OptFlags
from repro.featurestore.table import PreAggState, TableSchema, TableState
from repro.kernels import ops

__all__ = ["PhysicalPlan", "compile_plan", "AggSlot", "WindowGroup"]

# Aggregate function -> raw moment fields required from the window kernel.
_FIELD_OF = {
    E.AggFunc.SUM: "sum",
    E.AggFunc.COUNT: "count",
    E.AggFunc.MIN: "min",
    E.AggFunc.MAX: "max",
    E.AggFunc.FIRST: "first",
    E.AggFunc.LAST: "last",
    # AVG/STD/VAR survive only if decompose_aggregates was disabled; the
    # physical layer then derives them from moments itself.
    E.AggFunc.AVG: "avg",
    E.AggFunc.STD: "std",
    E.AggFunc.VAR: "var",
}

_DERIVED = {E.AggFunc.AVG, E.AggFunc.STD, E.AggFunc.VAR}
_MOMENTS_FOR = {
    E.AggFunc.AVG: ("sum", "count"),
    E.AggFunc.STD: ("sum", "sumsq", "count"),
    E.AggFunc.VAR: ("sum", "sumsq", "count"),
}


@dataclass(frozen=True)
class AggSlot:
    internal: str          # env name of the materialised aggregate
    func: E.AggFunc
    arg: E.Expr
    window: str
    col_pos: int           # position in the window group's stacked columns
    field: str = ""        # kernel output field this slot reads


@dataclass(frozen=True)
class WindowGroup:
    name: str
    spec: E.WindowSpec
    impl: str                         # "naive" | "preagg"
    plain_cols: Tuple[int, ...]       # storage column indices gathered
    derived_args: Tuple[E.Expr, ...]  # virtual columns (naive impl only)
    slots: Tuple[AggSlot, ...]
    fields: Tuple[str, ...]           # kernel fields to materialise


@dataclass
class PhysicalPlan:
    plan: LogicalPlan
    groups: Tuple[WindowGroup, ...]
    outputs: Tuple[Tuple[str, E.Expr], ...]   # aggs replaced by Col refs
    executor: Callable
    feature_names: Tuple[str, ...]
    # assume_latest is a *request-time* property (online fast path vs
    # point-in-time offline), so the executor is built per mode
    executor_factory: Optional[Callable] = None

    def executor_for(self, assume_latest: bool) -> Callable:
        if self.executor_factory is None:
            return self.executor
        return self.executor_factory(assume_latest)

    def fingerprint(self) -> str:
        return self.plan.fingerprint()


def _internal_name(agg: E.Agg) -> str:
    import hashlib
    h = hashlib.md5(agg.fingerprint().encode()).hexdigest()[:10]
    return f"__agg_{h}"


def compile_plan(plan: LogicalPlan, schema: TableSchema, *,
                 flags: OptFlags = OptFlags(),
                 bucket_size: int,
                 model_fns: Optional[Dict[str, Callable]] = None
                 ) -> PhysicalPlan:
    """Lower an optimized logical plan to an executor function."""
    model_fns = model_fns or {}
    impl_map = dict(plan.window_impl)
    wmap = plan.project.window_map()

    # ---- 1. unique aggregates (CSE) -------------------------------------
    uniq: Dict[str, E.Agg] = {}
    for _, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            uniq.setdefault(agg.fingerprint(), agg)

    # ---- 2. group by window; assign stacked-column positions ------------
    groups: List[WindowGroup] = []
    slot_by_fp: Dict[str, AggSlot] = {}
    for wname, spec in plan.project.windows:
        waggs = [a for a in uniq.values() if a.window == wname]
        if not waggs:
            continue
        impl = impl_map.get(wname, "naive")
        plain: List[int] = []
        plain_seen: Dict[int, int] = {}
        derived: List[E.Expr] = []
        derived_seen: Dict[str, int] = {}
        slots: List[AggSlot] = []
        fields: List[str] = []
        from repro.core.optimizer import sumsq_col
        for agg in sorted(waggs, key=lambda a: a.fingerprint()):
            field = _FIELD_OF[agg.func]
            sq_col = (sumsq_col(agg.arg)
                      if agg.func == E.AggFunc.SUM else None)
            if isinstance(agg.arg, E.Col) or (sq_col is not None
                                              and impl == "preagg"):
                # plain storage column — SUM(x*x) reads the sumsq tier
                cname = sq_col if sq_col is not None else agg.arg.name
                if sq_col is not None:
                    field = "sumsq"
                ci = schema.col_index(cname)
                if ci not in plain_seen:
                    plain_seen[ci] = len(plain)
                    plain.append(ci)
                pos = plain_seen[ci]
            elif isinstance(agg.arg, E.Lit) and agg.func == E.AggFunc.COUNT:
                pos = -1   # COUNT(*) — no column needed
            else:
                if impl == "preagg":
                    raise AssertionError(
                        f"optimizer chose preagg for window {wname!r} with "
                        f"derived aggregate argument {agg.arg!r}")
                fp = agg.arg.fingerprint()
                if fp not in derived_seen:
                    derived_seen[fp] = len(derived)
                    derived.append(agg.arg)
                pos = len(plain_seen) + derived_seen[fp]  # provisional
            if agg.func in _DERIVED:
                for m in _MOMENTS_FOR[agg.func]:
                    if m not in fields:
                        fields.append(m)
            elif field not in fields:
                fields.append(field)
            slot = AggSlot(internal=_internal_name(agg), func=agg.func,
                           arg=agg.arg, window=wname, col_pos=pos,
                           field=field)
            slots.append(slot)
            slot_by_fp[agg.fingerprint()] = slot
        # fix provisional derived positions now that plain count is final
        n_plain = len(plain)
        fixed = []
        for s in slots:
            if (not isinstance(s.arg, E.Col) and s.col_pos >= 0
                    and s.arg.fingerprint() in derived_seen):
                # recompute: derived columns come after all plain ones
                fp = s.arg.fingerprint()
                pos = n_plain + derived_seen[fp]
                s = AggSlot(s.internal, s.func, s.arg, s.window, pos,
                            s.field)
            fixed.append(s)
        groups.append(WindowGroup(
            name=wname, spec=spec, impl=impl, plain_cols=tuple(plain),
            derived_args=tuple(derived), slots=tuple(fixed),
            fields=tuple(fields)))

    # ---- 3. rewrite outputs: Agg -> Col(internal) ------------------------
    def sub(e: E.Expr) -> E.Expr:
        if isinstance(e, E.Agg):
            return E.Col(slot_by_fp[e.fingerprint()].internal)
        kids = tuple(sub(c) for c in E.children(e))
        return E.replace_children(e, kids)

    outputs = tuple((n, sub(e)) for n, e in plan.project.outputs)
    feature_names = tuple(n for n, _ in outputs)
    filter_pred = plan.filter.pred
    scan_cols = plan.scan.columns
    predict = plan.predict
    ts_col = schema.ts_col
    groups_t = tuple(groups)

    # ---- 4. the executor --------------------------------------------------
    # assume_latest is request-time (online fast path vs point-in-time
    # offline materialisation), so the executor is a factory over it.
    @functools.lru_cache(maxsize=2)
    def make_executor(assume_latest: bool) -> Callable:
     def executor(state: TableState, preagg: Optional[PreAggState],
                 key_idx: jax.Array, req_ts: jax.Array,
                 req_row: jax.Array,
                 model_params: Optional[Dict] = None
                 ) -> Dict[str, jax.Array]:
        # event-level environment for WHERE / derived aggregate args
        def event_env():
            env = {c: state.values[:, :, schema.col_index(c)]
                   for c in scan_cols if c in schema.value_cols}
            env[ts_col] = state.ts
            return env

        evt_mask = None
        if filter_pred is not None:
            evt_mask = E.eval_scalar(filter_pred, event_env())
            evt_mask = evt_mask.astype(jnp.bool_)

        env: Dict[str, jax.Array] = {}
        # request-row columns + request timestamp
        for j, c in enumerate(schema.value_cols):
            env[c] = req_row[:, j]
        env[ts_col] = req_ts

        for grp in groups_t:
            spec = grp.spec
            kw = dict(rows_preceding=spec.rows_preceding,
                      range_preceding=spec.range_preceding,
                      assume_latest=assume_latest)
            if grp.impl == "preagg":
                assert preagg is not None
                idx = jnp.asarray(grp.plain_cols, jnp.int32)
                raw = ops.preagg_window(
                    state.values[:, :, idx], state.ts, state.total,
                    preagg.sum[:, :, idx], preagg.sumsq[:, :, idx],
                    preagg.min[:, :, idx], preagg.max[:, :, idx],
                    preagg.count, key_idx, req_ts,
                    bucket_size=bucket_size,
                    fields=grp.fields, **kw)
            else:
                cols = [state.values[:, :, ci] for ci in grp.plain_cols]
                if grp.derived_args:
                    ev = event_env()
                    cols += [E.eval_scalar(a, ev).astype(jnp.float32)
                             for a in grp.derived_args]
                v = (jnp.stack(cols, axis=-1) if cols
                     else state.values[:, :, :0])
                raw = ops.window_agg(
                    v, state.ts, state.total, key_idx, req_ts,
                    evt_mask=evt_mask, fields=grp.fields, **kw)
            cnt = raw.get("count")
            nonempty = (cnt > 0) if cnt is not None else None
            for s in grp.slots:
                if s.func == E.AggFunc.COUNT:
                    env[s.internal] = raw["count"]
                    continue
                if s.func in _DERIVED:
                    c = jnp.maximum(raw["count"], 1.0)
                    mean = raw["sum"][:, s.col_pos] / c
                    if s.func == E.AggFunc.AVG:
                        val = mean
                    else:
                        var = jnp.maximum(
                            raw["sumsq"][:, s.col_pos] / c - mean * mean, 0.0)
                        val = var if s.func == E.AggFunc.VAR else jnp.sqrt(var)
                    env[s.internal] = jnp.where(nonempty, val, 0.0)
                    continue
                val = raw[s.field or _FIELD_OF[s.func]][:, s.col_pos]
                if s.func in (E.AggFunc.MIN, E.AggFunc.MAX,
                              E.AggFunc.FIRST, E.AggFunc.LAST):
                    val = jnp.where(nonempty, val, 0.0)
                env[s.internal] = val

        out = {n: E.eval_scalar(e, env) for n, e in outputs}
        if predict is not None:
            feats = jnp.stack([out[f] for f in predict.features], axis=-1)
            fn = model_fns.get(predict.model)
            if fn is None:
                raise KeyError(f"model {predict.model!r} not registered")
            out[predict.output] = fn(model_params, feats.astype(jnp.float32))
        return out

     return executor

    return PhysicalPlan(plan=plan, groups=groups_t, outputs=outputs,
                        executor=make_executor(flags.assume_latest),
                        executor_factory=make_executor,
                        feature_names=feature_names)
