"""Physical planning: LogicalPlan → pure JAX executable.

The physical plan materialises every *unique* aggregate once (CSE), groups
aggregates by window so each window runs ONE fused scan (window merge),
and lowers the window groups through three execution paths chosen by the
optimizer (``plan.window_impl``):

* ``fused``  — every group in this set executes in ONE multi-window kernel
  launch (``ops.fused_window``): a per-deployment spec table (per-group
  ROWS/RANGE bounds + field masks) over the UNION of the groups' columns,
  scanned once. Column positions are remapped group→union at compile time
  (``_FusedScan.posmaps``) so slot reads stay O(1) indexing.
* ``naive``  — per-group single-window scan (``ops.window_agg``); only
  reached when a plan has exactly one raw-scan group or fusion is off.
* ``preagg`` — bucketed pre-aggregate lookup (``ops.preagg_window``).

The emitted executor is a pure function

    executor(state, preagg, key_idx, req_ts, req_row, model_params,
             join_inputs)
        -> {output_name: (B,) or (B, k) array}

``join_inputs`` carries one ``(right_state, right_kidx, found)`` triple
per LAST JOIN in plan order (empty tuple for single-table plans); each
join costs exactly one extra kernel launch (``ops.last_join``) and its
columns enter the scalar env as ``"table.col"`` request-level values.

suitable for ``jax.jit`` (the plan cache owns compilation) and for
``shard_map``/``pjit`` batch sharding in the offline path. Column-gather
index arrays are precomputed at compile time, and
``PhysicalPlan.n_kernel_launches`` exposes how many window-kernel
invocations one batch costs (surfaced by ``Engine.latency_decomposition``
as the ``kernel_launches`` counter).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import expr as E
from repro.core.logical import LogicalPlan
from repro.core.optimizer import OptFlags
from repro.featurestore.table import PreAggState, TableSchema, TableState
from repro.kernels import ops

__all__ = ["PhysicalPlan", "compile_plan", "AggSlot", "WindowGroup"]

# Aggregate function -> raw moment fields required from the window kernel.
_FIELD_OF = {
    E.AggFunc.SUM: "sum",
    E.AggFunc.COUNT: "count",
    E.AggFunc.MIN: "min",
    E.AggFunc.MAX: "max",
    E.AggFunc.FIRST: "first",
    E.AggFunc.LAST: "last",
    # AVG/STD/VAR survive only if decompose_aggregates was disabled; the
    # physical layer then derives them from moments itself.
    E.AggFunc.AVG: "avg",
    E.AggFunc.STD: "std",
    E.AggFunc.VAR: "var",
}

_DERIVED = {E.AggFunc.AVG, E.AggFunc.STD, E.AggFunc.VAR}
_MOMENTS_FOR = {
    E.AggFunc.AVG: ("sum", "count"),
    E.AggFunc.STD: ("sum", "sumsq", "count"),
    E.AggFunc.VAR: ("sum", "sumsq", "count"),
}


@dataclass(frozen=True)
class AggSlot:
    internal: str          # env name of the materialised aggregate
    func: E.AggFunc
    arg: E.Expr
    window: str
    col_pos: int           # position in the window group's stacked columns
    field: str = ""        # kernel output field this slot reads


@dataclass(frozen=True)
class WindowGroup:
    name: str
    spec: E.WindowSpec
    impl: str                         # "naive" | "preagg" | "fused"
    plain_cols: Tuple[int, ...]       # storage column indices gathered
    derived_args: Tuple[E.Expr, ...]  # virtual columns (raw-scan impls only)
    slots: Tuple[AggSlot, ...]
    fields: Tuple[str, ...]           # kernel fields to materialise


@dataclass(frozen=True)
class _FusedScan:
    """Compile-time layout of the single-scan multi-window launch.

    ``idx`` are group indices (into ``PhysicalPlan.groups``) in spec-table
    order; the union column stack is [plain storage columns][derived
    virtual columns], and ``posmaps[gi]`` maps a member group's local
    stacked-column position to its union position.
    """

    idx: Tuple[int, ...]
    union_plain: Tuple[int, ...]          # storage column indices
    union_derived: Tuple[E.Expr, ...]     # virtual columns (WHERE-side env)
    spec_rows: Tuple[Optional[int], ...]
    spec_ranges: Tuple[Optional[float], ...]
    spec_fields: Tuple[Tuple[str, ...], ...]
    posmaps: Tuple[Tuple[int, ...], ...]  # parallel to ``idx``


@dataclass
class PhysicalPlan:
    plan: LogicalPlan
    groups: Tuple[WindowGroup, ...]
    outputs: Tuple[Tuple[str, E.Expr], ...]   # aggs replaced by Col refs
    executor: Callable
    feature_names: Tuple[str, ...]
    # assume_latest is a *request-time* property (online fast path vs
    # point-in-time offline), so the executor is built per mode
    executor_factory: Optional[Callable] = None
    # window-kernel invocations per batch: all fused groups count as ONE
    n_kernel_launches: int = 0

    def executor_for(self, assume_latest: bool) -> Callable:
        if self.executor_factory is None:
            return self.executor
        return self.executor_factory(assume_latest)

    def fingerprint(self) -> str:
        return self.plan.fingerprint()


def _internal_name(agg: E.Agg) -> str:
    import hashlib
    h = hashlib.md5(agg.fingerprint().encode()).hexdigest()[:10]
    return f"__agg_{h}"


def _fill_slots(env: Dict[str, jax.Array], grp: WindowGroup,
                get: Callable[[str, int], jax.Array]) -> None:
    """Materialise a group's aggregate slots into the scalar env.

    ``get(field, pos)`` reads one (B,)-shaped kernel output column for
    this group — the indirection is what lets fused groups (indexed
    ``[:, spec, union_pos]``) and per-group launches (``[:, pos]``) share
    the empty-window masking and derived-moment math below.
    """
    cnt = get("count", -1) if "count" in grp.fields else None
    nonempty = (cnt > 0) if cnt is not None else None
    for s in grp.slots:
        if s.func == E.AggFunc.COUNT:
            env[s.internal] = cnt
            continue
        if s.func in _DERIVED:
            c = jnp.maximum(cnt, 1.0)
            mean = get("sum", s.col_pos) / c
            if s.func == E.AggFunc.AVG:
                val = mean
            else:
                var = jnp.maximum(
                    get("sumsq", s.col_pos) / c - mean * mean, 0.0)
                val = var if s.func == E.AggFunc.VAR else jnp.sqrt(var)
            env[s.internal] = jnp.where(nonempty, val, 0.0)
            continue
        val = get(s.field or _FIELD_OF[s.func], s.col_pos)
        if s.func in (E.AggFunc.MIN, E.AggFunc.MAX,
                      E.AggFunc.FIRST, E.AggFunc.LAST):
            val = jnp.where(nonempty, val, 0.0)
        env[s.internal] = val


def compile_plan(plan: LogicalPlan, schema: TableSchema, *,
                 flags: OptFlags = OptFlags(),
                 bucket_size: int,
                 model_fns: Optional[Dict[str, Callable]] = None,
                 join_schemas: Optional[Dict[str, TableSchema]] = None
                 ) -> PhysicalPlan:
    """Lower an optimized logical plan to an executor function."""
    model_fns = model_fns or {}
    join_schemas = join_schemas or {}
    impl_map = dict(plan.window_impl)
    wmap = plan.project.window_map()

    # ---- 0. LAST JOIN layout: per join, the right columns to gather and
    # the slot-env names they land under (one kernel launch per join) ----
    join_layout: List[Tuple[str, Tuple[int, ...], Tuple[str, ...]]] = []
    for j in plan.joins:
        rs = join_schemas.get(j.table)
        if rs is None:
            raise KeyError(
                f"compile_plan: no schema supplied for joined table "
                f"{j.table!r} (join_schemas has {sorted(join_schemas)})")
        cols = j.columns or rs.value_cols
        gather = tuple(rs.col_index(c) for c in cols)
        names = tuple(f"{j.table}.{c}" for c in cols)
        join_layout.append((j.table, gather, names))
    join_layout_t = tuple(join_layout)

    # ---- 1. unique aggregates (CSE) -------------------------------------
    uniq: Dict[str, E.Agg] = {}
    for _, e in plan.project.outputs:
        for agg in E.collect_aggs(e):
            uniq.setdefault(agg.fingerprint(), agg)

    # ---- 2. group by window; assign stacked-column positions ------------
    groups: List[WindowGroup] = []
    slot_by_fp: Dict[str, AggSlot] = {}
    for wname, spec in plan.project.windows:
        waggs = [a for a in uniq.values() if a.window == wname]
        if not waggs:
            continue
        impl = impl_map.get(wname, "naive")
        plain: List[int] = []
        plain_seen: Dict[int, int] = {}
        derived: List[E.Expr] = []
        derived_seen: Dict[str, int] = {}
        slots: List[AggSlot] = []
        fields: List[str] = []
        from repro.core.optimizer import sumsq_col
        for agg in sorted(waggs, key=lambda a: a.fingerprint()):
            field = _FIELD_OF[agg.func]
            sq_col = (sumsq_col(agg.arg)
                      if agg.func == E.AggFunc.SUM else None)
            if isinstance(agg.arg, E.Col) or (sq_col is not None
                                              and impl == "preagg"):
                # plain storage column — SUM(x*x) reads the sumsq tier
                cname = sq_col if sq_col is not None else agg.arg.name
                if sq_col is not None:
                    field = "sumsq"
                ci = schema.col_index(cname)
                if ci not in plain_seen:
                    plain_seen[ci] = len(plain)
                    plain.append(ci)
                pos = plain_seen[ci]
            elif isinstance(agg.arg, E.Lit) and agg.func == E.AggFunc.COUNT:
                pos = -1   # COUNT(*) — no column needed
            else:
                if impl == "preagg":
                    raise AssertionError(
                        f"optimizer chose preagg for window {wname!r} with "
                        f"derived aggregate argument {agg.arg!r}")
                fp = agg.arg.fingerprint()
                if fp not in derived_seen:
                    derived_seen[fp] = len(derived)
                    derived.append(agg.arg)
                pos = len(plain_seen) + derived_seen[fp]  # provisional
            if agg.func in _DERIVED:
                for m in _MOMENTS_FOR[agg.func]:
                    if m not in fields:
                        fields.append(m)
            elif field not in fields:
                fields.append(field)
            slot = AggSlot(internal=_internal_name(agg), func=agg.func,
                           arg=agg.arg, window=wname, col_pos=pos,
                           field=field)
            slots.append(slot)
            slot_by_fp[agg.fingerprint()] = slot
        # MIN/MAX/FIRST/LAST zero-fill empty windows via the count field
        if ("count" not in fields
                and any(s.func in (E.AggFunc.MIN, E.AggFunc.MAX,
                                   E.AggFunc.FIRST, E.AggFunc.LAST)
                        for s in slots)):
            fields.append("count")
        # fix provisional derived positions now that plain count is final
        n_plain = len(plain)
        fixed = []
        for s in slots:
            if (not isinstance(s.arg, E.Col) and s.col_pos >= 0
                    and s.arg.fingerprint() in derived_seen):
                # recompute: derived columns come after all plain ones
                fp = s.arg.fingerprint()
                pos = n_plain + derived_seen[fp]
                s = AggSlot(s.internal, s.func, s.arg, s.window, pos,
                            s.field)
            fixed.append(s)
        groups.append(WindowGroup(
            name=wname, spec=spec, impl=impl, plain_cols=tuple(plain),
            derived_args=tuple(derived), slots=tuple(fixed),
            fields=tuple(fields)))

    # ---- 3. rewrite outputs: Agg -> Col(internal) ------------------------
    def sub(e: E.Expr) -> E.Expr:
        if isinstance(e, E.Agg):
            return E.Col(slot_by_fp[e.fingerprint()].internal)
        kids = tuple(sub(c) for c in E.children(e))
        return E.replace_children(e, kids)

    outputs = tuple((n, sub(e)) for n, e in plan.project.outputs)
    feature_names = tuple(n for n, _ in outputs)
    filter_pred = plan.filter.pred
    scan_cols = plan.scan.columns
    predict = plan.predict
    ts_col = schema.ts_col
    groups_t = tuple(groups)

    # ---- 3b. fused-scan layout: union columns + group→union remaps ------
    fused_idx = tuple(i for i, g in enumerate(groups_t)
                      if g.impl == "fused")
    fused: Optional[_FusedScan] = None
    if fused_idx:
        union_plain: List[int] = []
        plain_upos: Dict[int, int] = {}
        union_derived: List[E.Expr] = []
        derived_upos: Dict[str, int] = {}
        for i in fused_idx:
            g = groups_t[i]
            for ci in g.plain_cols:
                if ci not in plain_upos:
                    plain_upos[ci] = len(union_plain)
                    union_plain.append(ci)
            for a in g.derived_args:
                fp = a.fingerprint()
                if fp not in derived_upos:
                    derived_upos[fp] = len(union_derived)
                    union_derived.append(a)
        n_up = len(union_plain)
        posmaps = []
        for i in fused_idx:
            g = groups_t[i]
            pm = [plain_upos[ci] for ci in g.plain_cols]
            pm += [n_up + derived_upos[a.fingerprint()]
                   for a in g.derived_args]
            posmaps.append(tuple(pm))
        fused = _FusedScan(
            idx=fused_idx,
            union_plain=tuple(union_plain),
            union_derived=tuple(union_derived),
            spec_rows=tuple(groups_t[i].spec.rows_preceding
                            for i in fused_idx),
            spec_ranges=tuple(groups_t[i].spec.range_preceding
                              for i in fused_idx),
            spec_fields=tuple(groups_t[i].fields for i in fused_idx),
            posmaps=tuple(posmaps))
    n_launches = (1 if fused_idx else 0) + sum(
        1 for g in groups_t if g.impl != "fused") + len(join_layout_t)

    # ---- 3c. precomputed column-gather indices (once, not per trace) ----
    scan_col_idx = tuple((c, schema.col_index(c)) for c in scan_cols
                         if c in schema.value_cols)
    fused_gather = (jnp.asarray(fused.union_plain, jnp.int32)
                    if fused is not None else None)
    group_gather = {i: jnp.asarray(g.plain_cols, jnp.int32)
                    for i, g in enumerate(groups_t) if g.impl != "fused"}

    # ---- 4. the executor --------------------------------------------------
    # assume_latest is request-time (online fast path vs point-in-time
    # offline materialisation), so the executor is a factory over it.
    @functools.lru_cache(maxsize=2)
    def make_executor(assume_latest: bool) -> Callable:
     def executor(state: TableState, preagg: Optional[PreAggState],
                 key_idx: jax.Array, req_ts: jax.Array,
                 req_row: jax.Array,
                 model_params: Optional[Dict] = None,
                 join_inputs: Tuple = ()
                 ) -> Dict[str, jax.Array]:
        # event-level environment for WHERE / derived aggregate args
        # (column indices resolved once at compile time)
        def event_env():
            env = {c: state.values[:, :, ci] for c, ci in scan_col_idx}
            env[ts_col] = state.ts
            return env

        evt_mask = None
        if filter_pred is not None:
            evt_mask = E.eval_scalar(filter_pred, event_env())
            evt_mask = evt_mask.astype(jnp.bool_)

        env: Dict[str, jax.Array] = {}
        # request-row columns + request timestamp
        for j, c in enumerate(schema.value_cols):
            env[c] = req_row[:, j]
        env[ts_col] = req_ts

        # LAST JOINs: one kernel launch per joined table resolves the
        # latest right row as of req_ts; joined columns land in the slot
        # env exactly like request-row columns (zeroed when the probe key
        # is unknown or no right row qualifies — the empty-window policy).
        # The selected row's ts rides along as hidden ``__join_*`` outputs
        # (stripped by the engine into per-deployment staleness metrics).
        join_extras: Dict[str, jax.Array] = {}
        for ji, (_jt, jgather, jnames) in enumerate(join_layout_t):
            jstate, jkidx, jfound = join_inputs[ji]
            jrow, jmatched, jsel_ts = ops.last_join(
                jstate.values, jstate.ts, jstate.total, jkidx, req_ts,
                col_idx=jgather, assume_latest=assume_latest, with_ts=True)
            okf = (jfound & jmatched).astype(jnp.float32)
            for t_i, nm in enumerate(jnames):
                env[nm] = jrow[:, t_i] * okf
            join_extras[f"__join_match_{_jt}"] = okf
            join_extras[f"__join_age_{_jt}"] = (req_ts - jsel_ts) * okf

        def stack_cols(gather, derived):
            cols = (state.values[:, :, gather] if gather is not None
                    else state.values[:, :, :0])
            if derived:
                ev = event_env()
                dv = jnp.stack([E.eval_scalar(a, ev).astype(jnp.float32)
                                for a in derived], axis=-1)
                cols = jnp.concatenate([cols, dv], axis=-1)
            return cols

        # ONE launch for the whole fused set: every plain window spec of
        # the deployment is answered from a single scan of the union
        # columns (the multi-window optimization this plan layer is for).
        fused_raw = None
        if fused is not None:
            fused_raw = ops.fused_window(
                stack_cols(fused_gather, fused.union_derived),
                state.ts, state.total, key_idx, req_ts,
                spec_rows=fused.spec_rows,
                spec_ranges=fused.spec_ranges,
                spec_fields=fused.spec_fields,
                evt_mask=evt_mask, assume_latest=assume_latest)

        for gi, grp in enumerate(groups_t):
            spec = grp.spec
            if grp.impl == "fused":
                si = fused.idx.index(gi)
                pm = fused.posmaps[si]
                def get(field, pos, _si=si, _pm=pm):
                    if field == "count":
                        return fused_raw["count"][:, _si]
                    return fused_raw[field][:, _si, _pm[pos]]
            else:
                kw = dict(rows_preceding=spec.rows_preceding,
                          range_preceding=spec.range_preceding,
                          assume_latest=assume_latest)
                if grp.impl == "preagg":
                    assert preagg is not None
                    idx = group_gather[gi]
                    raw = ops.preagg_window(
                        state.values[:, :, idx], state.ts, state.total,
                        preagg.sum[:, :, idx], preagg.sumsq[:, :, idx],
                        preagg.min[:, :, idx], preagg.max[:, :, idx],
                        preagg.count, key_idx, req_ts,
                        bucket_size=bucket_size,
                        fields=grp.fields, **kw)
                else:
                    raw = ops.window_agg(
                        stack_cols(group_gather.get(gi), grp.derived_args),
                        state.ts, state.total, key_idx, req_ts,
                        evt_mask=evt_mask, fields=grp.fields, **kw)
                def get(field, pos, _raw=raw):
                    if field == "count":
                        return _raw["count"]
                    return _raw[field][:, pos]
            _fill_slots(env, grp, get)

        out = {n: E.eval_scalar(e, env) for n, e in outputs}
        out.update(join_extras)
        if predict is not None:
            feats = jnp.stack([out[f] for f in predict.features], axis=-1)
            fn = model_fns.get(predict.model)
            if fn is None:
                raise KeyError(f"model {predict.model!r} not registered")
            out[predict.output] = fn(model_params, feats.astype(jnp.float32))
        return out

     return executor

    return PhysicalPlan(plan=plan, groups=groups_t, outputs=outputs,
                        executor=make_executor(flags.assume_latest),
                        executor_factory=make_executor,
                        feature_names=feature_names,
                        n_kernel_launches=n_launches)
