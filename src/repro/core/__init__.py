"""Core SQL+ML feature-query engine (the paper's contribution).

Public API:

    from repro.core import Engine, OptFlags, parse_sql, QueryBuilder
"""
from repro.core.dsl import (QueryBuilder, parse_sql, col, lit, sum_, count_,
                            avg_, min_, max_, std_, var_, first_, last_)
from repro.core.engine import (Engine, Deployment, DeploymentHandle,
                               EngineStats, HandleMetrics)
from repro.core.optimizer import OptFlags, TableMeta, optimize
from repro.core.logical import Query, LogicalPlan
from repro.core.plan_cache import PlanCache, CacheStats, TagStats, bucket_batch
from repro.core.results import (FeatureFrame, RequestContext,
                                DeadlineExceeded, STATUS_OK,
                                STATUS_UNKNOWN_KEY)

__all__ = [
    "Engine", "Deployment", "DeploymentHandle", "EngineStats",
    "HandleMetrics", "OptFlags", "TableMeta", "optimize", "Query",
    "LogicalPlan", "PlanCache", "CacheStats", "TagStats", "bucket_batch",
    "FeatureFrame", "RequestContext", "DeadlineExceeded", "STATUS_OK",
    "STATUS_UNKNOWN_KEY",
    "QueryBuilder", "parse_sql", "col", "lit", "sum_", "count_", "avg_",
    "min_", "max_", "std_", "var_", "first_", "last_",
]
