"""Baseline execution models for the paper's system comparison (Table 1).

We cannot ship PostgreSQL/MySQL/SparkSQL/ClickHouse, and the paper's point
is not those vendors but their *execution models*. Each baseline below is
the same feature query executed under a different model, on the same data,
in the same process — isolating exactly the optimizations the paper
attributes (DESIGN.md §8.2):

* ``row_interpreter``  (PostgreSQL/MySQL class): per-request, per-row
  interpreted evaluation over host memory; B-tree-style key lookup is a
  host dict (same as ours), no compilation, no vectorisation, no pre-agg.
* ``microbatch``       (SparkSQL/Flink class): vectorised columnar compute
  but requests are processed in fixed micro-batches with a host⇄device
  round-trip and fresh task dispatch per micro-batch; no pre-aggregation,
  no request-level shape bucketing.
* ``columnar_scan``    (ClickHouse class): vectorised, plan-cached columnar
  execution WITHOUT a per-key time-series index: every request scans all
  keys' storage and masks on the partition key; no pre-agg.
* ``openmldb``         our full stack (plan opt + cache + pre-agg +
  vectorised batch execution).

``make_engine(profile)`` builds a configured engine; ``serve_batch`` runs
one request batch under the profile's execution model.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expr as E
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags

__all__ = ["PROFILES", "BaselineRunner", "make_engine"]

PROFILES = {
    "openmldb": dict(kind="engine",
                     flags=OptFlags(query_opt=True, preagg=True,
                                    plan_cache=True, vectorized=True,
                                    assume_latest=True)),
    "row_interpreter": dict(kind="row"),
    "microbatch": dict(kind="microbatch", micro=32,
                       flags=OptFlags(query_opt=True, preagg=False,
                                      plan_cache=True, vectorized=True,
                                      assume_latest=False)),
    # ClickHouse-style: vectorised columnar execution, but no ML-aware
    # pre-aggregation tier and no online fast path. (A strict no-index
    # full-scan model also exists — kind="columnar" — but on this 1-core
    # container it measures the container, not the execution model.)
    "columnar_scan": dict(kind="engine",
                          flags=OptFlags(query_opt=True, preagg=False,
                                         plan_cache=True, vectorized=True,
                                         assume_latest=False)),
    "columnar_fullscan": dict(kind="columnar",
                              flags=OptFlags(query_opt=True, preagg=False,
                                             plan_cache=True,
                                             vectorized=True,
                                             assume_latest=False)),
}

# Paper Table 1 reference points (queries/sec, latency ms) for reporting.
PAPER_TABLE1 = {
    "PostgreSQL": (1800, (85, 120)),
    "MySQL": (2100, (60, 95)),
    "SparkSQL": (3500, (50, 80)),
    "ClickHouse": (8200, (25, 60)),
    "FlinkSQL": (4200, (20, 40)),
    "OpenMLDB(paper)": (12500, (1, 5)),
}


def make_engine(profile: str, **engine_kw) -> Engine:
    p = PROFILES[profile]
    flags = p.get("flags", OptFlags())
    return Engine(flags, **engine_kw)


@dataclass
class _RowQuery:
    """Pre-resolved interpretation state for the row interpreter."""

    outputs: Tuple[Tuple[str, E.Expr], ...]
    windows: Dict[str, E.WindowSpec]
    where: Optional[E.Expr]


class BaselineRunner:
    """Runs one deployed query under a baseline execution model."""

    def __init__(self, engine: Engine, deployment: str, profile: str):
        self.engine = engine
        self.dep = engine.deployments[deployment]
        self.profile = profile
        self.kind = PROFILES[profile]["kind"]
        self.micro = PROFILES[profile].get("micro", 100)
        q = self.dep.query
        self._rowq = _RowQuery(outputs=q.outputs,
                               windows=dict(q.windows), where=q.where)
        self._host_cache: Optional[Tuple[np.ndarray, ...]] = None

    # ------------------------------------------------------------- dispatch
    def serve_batch(self, keys: Sequence, ts: Sequence[float],
                    rows: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
        if self.kind == "engine":
            return self.engine.request(self.dep.name, keys, ts, rows)
        if self.kind == "microbatch":
            return self._serve_microbatch(keys, ts, rows)
        if self.kind == "row":
            return self._serve_rowwise(keys, ts, rows)
        if self.kind == "columnar":
            return self._serve_columnar(keys, ts, rows)
        raise ValueError(self.kind)

    # ------------------------------------------------- microbatch (SparkSQL)
    def _serve_microbatch(self, keys, ts, rows) -> Dict[str, np.ndarray]:
        outs: List[Dict[str, np.ndarray]] = []
        n = len(keys)
        for s in range(0, n, self.micro):
            sl = slice(s, min(s + self.micro, n))
            # host->device->host round-trip per micro-batch task, exactly
            # batch-at-a-time task dispatch with no shape bucketing reuse
            outs.append(self.engine.request(
                self.dep.name, list(keys[sl]), list(np.asarray(ts)[sl]),
                None if rows is None else rows[sl]))
        return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}

    # ------------------------------------------- row interpreter (MySQL/PG)
    def _host_arrays(self):
        # Interpreters read host memory; refresh per batch (tables are
        # quiescent during the benchmark window).
        t = self.dep.table
        return (np.asarray(t.state.values), np.asarray(t.state.ts),
                np.asarray(t.state.total))

    def _serve_rowwise(self, keys, ts, rows) -> Dict[str, np.ndarray]:
        table = self.dep.table
        values, tsbuf, total = self._host_arrays()
        C = table.capacity
        schema = table.schema
        out: Dict[str, List[float]] = {n: [] for n, _ in self._rowq.outputs}
        for i, key in enumerate(keys):
            kx = table.key_index(key)
            # storage timestamps are f32 — compare in the same precision
            t_req = float(np.float32(ts[i]))
            tot = int(total[kx])
            n_ret = min(tot, C)
            # reconstruct events in position order (index scan)
            evs: List[Tuple[float, np.ndarray]] = []
            for p in range(tot - n_ret, tot):
                s = p % C
                te = float(tsbuf[kx, s])
                if te <= t_req:
                    evs.append((te, values[kx, s]))
            env_cache: Dict[str, float] = {}
            for name, ex in self._rowq.outputs:
                val = self._interp(ex, evs, t_req, schema,
                                   rows[i] if rows is not None else None)
                out[name].append(val)
        return {n: np.asarray(v, np.float32) for n, v in out.items()}

    def _interp(self, e: E.Expr, evs, t_req, schema, req_row) -> float:
        """Row-at-a-time interpretation (no vectorisation on purpose)."""
        if isinstance(e, E.Lit):
            return float(e.value)
        if isinstance(e, E.Col):
            if req_row is not None and e.name in schema.value_cols:
                return float(req_row[schema.col_index(e.name)])
            if e.name == schema.ts_col:
                return t_req
            return 0.0
        if isinstance(e, E.BinOp):
            a = self._interp(e.lhs, evs, t_req, schema, req_row)
            b = self._interp(e.rhs, evs, t_req, schema, req_row)
            return float({
                "+": a + b, "-": a - b, "*": a * b,
                "/": a / b if b else 0.0,
                ">": a > b, ">=": a >= b, "<": a < b, "<=": a <= b,
                "==": a == b, "!=": a != b,
                "and": bool(a) and bool(b), "or": bool(a) or bool(b),
            }[e.op])
        if isinstance(e, E.Func):
            args = [self._interp(a, evs, t_req, schema, req_row)
                    for a in e.args]
            fn = {"log": math.log, "log1p": math.log1p, "abs": abs,
                  "sqrt": math.sqrt, "exp": math.exp,
                  "neg": lambda x: -x,
                  "sigmoid": lambda x: 1 / (1 + math.exp(-x)),
                  "relu": lambda x: max(x, 0.0),
                  "safe_div": lambda a, b: a / b if b > 0 else 0.0,
                  }.get(e.name)
            if fn is None:
                raise NotImplementedError(f"row interp func {e.name}")
            return float(fn(*args))
        if isinstance(e, E.Agg):
            spec = self._rowq.windows[e.window]
            if spec.is_rows:
                win = evs[-spec.rows_preceding:]
            else:
                lo = t_req - spec.range_preceding
                win = [ev for ev in evs if ev[0] >= lo]
            acc: List[float] = []
            for te, row in win:
                if isinstance(e.arg, E.Col):
                    acc.append(float(row[
                        self._rowq_schema_idx(e.arg.name)]))
                elif isinstance(e.arg, E.Lit):
                    acc.append(float(e.arg.value))
                else:
                    acc.append(self._interp_evt(e.arg, te, row))
            if e.func == E.AggFunc.COUNT:
                return float(len(acc))
            if not acc:
                return 0.0
            if e.func == E.AggFunc.SUM:
                s = 0.0
                for x in acc:    # row-at-a-time on purpose
                    s += x
                return s
            if e.func == E.AggFunc.AVG:
                return sum(acc) / len(acc)
            if e.func == E.AggFunc.MIN:
                return min(acc)
            if e.func == E.AggFunc.MAX:
                return max(acc)
            if e.func in (E.AggFunc.STD, E.AggFunc.VAR):
                m = sum(acc) / len(acc)
                v = sum((x - m) ** 2 for x in acc) / len(acc)
                return math.sqrt(v) if e.func == E.AggFunc.STD else v
            if e.func == E.AggFunc.FIRST:
                return acc[0]
            if e.func == E.AggFunc.LAST:
                return acc[-1]
        raise NotImplementedError(type(e).__name__)

    def _rowq_schema_idx(self, name: str) -> int:
        return self.dep.table.schema.col_index(name)

    def _interp_evt(self, e: E.Expr, te: float, row: np.ndarray) -> float:
        schema = self.dep.table.schema
        if isinstance(e, E.Col):
            if e.name == schema.ts_col:
                return te
            return float(row[schema.col_index(e.name)])
        if isinstance(e, E.Lit):
            return float(e.value)
        if isinstance(e, E.BinOp):
            a = self._interp_evt(e.lhs, te, row)
            b = self._interp_evt(e.rhs, te, row)
            return float({"+": a + b, "-": a - b, "*": a * b,
                          "/": a / b if b else 0.0}[e.op])
        raise NotImplementedError

    # ------------------------------------------- columnar scan (ClickHouse)
    def _serve_columnar(self, keys, ts, rows) -> Dict[str, np.ndarray]:
        """Vectorised full-storage scan: no per-key index, so every request
        masks over all keys' slots (K·C work instead of C). Requests run in
        chunks of 16 — a scan engine pipelines queries, it does not
        materialise one K·C mask per concurrent request."""
        table = self.dep.table
        kidx_all = table.key_indices(keys)
        ts_all = np.asarray(ts, np.float32)
        fn = self._columnar_fn()
        outs: List[Dict[str, np.ndarray]] = []
        CH = 16
        for s in range(0, len(kidx_all), CH):
            pad = 0
            kidx = kidx_all[s:s + CH]
            ts_arr = ts_all[s:s + CH]
            if len(kidx) < CH:                 # pad to the compiled shape
                pad = CH - len(kidx)
                kidx = np.pad(kidx, (0, pad))
                ts_arr = np.pad(ts_arr, (0, pad))
            out = fn(table.state.values, table.state.ts, table.state.total,
                     jnp.asarray(kidx), jnp.asarray(ts_arr))
            out = jax.block_until_ready(out)
            outs.append({k: np.asarray(v)[:CH - pad] for k, v in out.items()})
        return {k: np.concatenate([o[k] for o in outs]) for k in outs[0]}

    def _columnar_fn(self):
        if getattr(self, "_col_fn", None) is not None:
            return self._col_fn
        rowq = self._rowq
        schema = self.dep.table.schema

        @jax.jit
        def fn(values, tsbuf, total, kidx, req_ts):
            K, C, V = values.shape
            flat_v = values.reshape(K * C, V)
            flat_t = tsbuf.reshape(K * C)
            flat_k = jnp.repeat(jnp.arange(K, dtype=jnp.int32), C)
            slot = jnp.tile(jnp.arange(C, dtype=jnp.int32), K)
            head = (total % C)[flat_k]
            rel = (slot - head) % C
            p = total[flat_k] - C + rel
            valid = (p >= 0) & (p < total[flat_k])

            def one(e: E.Expr, kx, t_req):
                if isinstance(e, E.Lit):
                    return jnp.float32(e.value)
                if isinstance(e, E.Col):
                    return jnp.float32(0.0)
                if isinstance(e, E.BinOp):
                    a, b = one(e.lhs, kx, t_req), one(e.rhs, kx, t_req)
                    return E._BINOPS[e.op](a, b)
                if isinstance(e, E.Func):
                    args = [one(a, kx, t_req) for a in e.args]
                    return E._FUNCS[e.name](*args)
                if isinstance(e, E.Agg):
                    spec = rowq.windows[e.window]
                    m = valid & (flat_k == kx) & (flat_t <= t_req)
                    if spec.is_rows:
                        # keep rows with p >= p1 - W (ring positions are
                        # per-key monotone, so this is the rows window)
                        p1 = jnp.max(jnp.where(m, p, -1)) + 1
                        m = m & (p >= p1 - spec.rows_preceding)
                    else:
                        m = m & (flat_t >= t_req - spec.range_preceding)
                    if isinstance(e.arg, E.Col):
                        x = flat_v[:, schema.col_index(e.arg.name)]
                    else:
                        x = jnp.ones_like(flat_t)
                    mf = m.astype(jnp.float32)
                    if e.func == E.AggFunc.COUNT:
                        return jnp.sum(mf)
                    if e.func == E.AggFunc.SUM:
                        return jnp.sum(x * mf)
                    if e.func == E.AggFunc.AVG:
                        c = jnp.maximum(jnp.sum(mf), 1.0)
                        return jnp.sum(x * mf) / c
                    if e.func == E.AggFunc.MIN:
                        return jnp.min(jnp.where(m, x, 3e38))
                    if e.func == E.AggFunc.MAX:
                        return jnp.max(jnp.where(m, x, -3e38))
                    if e.func in (E.AggFunc.STD, E.AggFunc.VAR):
                        c = jnp.maximum(jnp.sum(mf), 1.0)
                        mu = jnp.sum(x * mf) / c
                        var = jnp.maximum(
                            jnp.sum(x * x * mf) / c - mu * mu, 0.0)
                        return (jnp.sqrt(var)
                                if e.func == E.AggFunc.STD else var)
                    if e.func in (E.AggFunc.FIRST, E.AggFunc.LAST):
                        if e.func == E.AggFunc.LAST:
                            psel = jnp.max(jnp.where(m, p, -1))
                        else:
                            psel = jnp.min(jnp.where(m, p, 2 ** 30))
                        sel = (m & (p == psel)).astype(jnp.float32)
                        return jnp.sum(x * sel)
                raise NotImplementedError(type(e).__name__)

            def per_req(kx, t_req):
                return {n: one(ex, kx, t_req) for n, ex in rowq.outputs}

            return jax.vmap(per_req)(kidx, req_ts)

        self._col_fn = fn
        return fn
