"""Engine facade: deploy SQL+ML feature queries, serve them online, run them
offline — one definition, two execution modes (the paper's core promise).

Hot path anatomy (paper Eq. 3: ``L = L_parse + L_plan + L_exec``):

* ``deploy``  — parse (L_parse) + optimize + lower (L_plan, amortised by the
  plan cache across deployments and batch buckets);
* ``request`` — key lookup (host dict), pad to a shape bucket, run the
  compiled executable (L_exec), unpad.

"Parallel processing" (paper O4) has two forms here: vectorised batch
execution (TPU-native; default) and a worker-pool mode
(``flags.parallel_workers > 1``) that reproduces the paper's thread-level
ablation semantics on CPU.
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsl
from repro.core.logical import LogicalPlan, Query
from repro.core.optimizer import OptFlags, TableMeta, optimize
from repro.core.physical import PhysicalPlan, compile_plan
from repro.core.plan_cache import PlanCache, bucket_batch
from repro.featurestore.registry import FeatureRegistry, FeatureSet
from repro.featurestore.table import Table, TableSchema

__all__ = ["Engine", "Deployment", "EngineStats"]


@dataclass
class EngineStats:
    """Cumulative latency decomposition (seconds) + counters."""

    parse_s: float = 0.0
    plan_s: float = 0.0
    exec_s: float = 0.0
    n_requests: int = 0
    n_batches: int = 0

    def snapshot(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


@dataclass
class Deployment:
    name: str
    query: Query
    plan: LogicalPlan
    phys: PhysicalPlan
    opt_log: List[str]
    table: Table


class Engine:
    def __init__(self, flags: OptFlags = OptFlags(), *,
                 max_cache_entries: int = 128):
        self.flags = flags
        self.tables: Dict[str, Table] = {}
        self.models: Dict[str, Callable] = {}
        self.model_params: Dict[str, object] = {}
        self.deployments: Dict[str, Deployment] = {}
        self.registry = FeatureRegistry()
        self.cache = PlanCache(max_entries=max_cache_entries,
                               enabled=flags.plan_cache)
        self.streams: Dict[str, object] = {}   # table -> IngestPipeline
        self.stats = EngineStats()
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        if flags.parallel_workers > 1:
            self._pool = cf.ThreadPoolExecutor(flags.parallel_workers)

    # ------------------------------------------------------------------ DDL
    def create_table(self, schema: TableSchema, *, max_keys: int = 1024,
                     capacity: int = 1024, bucket_size: int = 64) -> Table:
        if schema.name in self.tables:
            raise ValueError(f"table {schema.name!r} exists")
        t = Table(schema, max_keys=max_keys, capacity=capacity,
                  bucket_size=bucket_size, enable_preagg=self.flags.preagg)
        self.tables[schema.name] = t
        self.registry.register_schema(schema)
        return t

    def insert(self, table: str, keys: Sequence, ts: Sequence[float],
               rows: np.ndarray) -> None:
        """Synchronous bulk insert (offline/backfill path). Routes through
        an attached stream when one exists — a table with a live pipeline
        has a single writer, so direct donation-mode insert would race the
        flusher.

        Atomic: if any event is unrepairably late (beyond the stream's
        released frontier), nothing is staged and ValueError is raised —
        matching the direct path's validate-before-ingest contract. Note
        the flush acts as a stream **barrier**: everything staged becomes
        immediately queryable, which forfeits the reorder window for
        events at or below the barrier (a later live push older than the
        barrier is dropped as late — by then its ring neighborhood is
        final)."""
        stream = self.streams.get(table)
        if stream is not None:
            keys = list(keys)
            n = stream.push_batch(keys, np.asarray(ts, np.float32),
                                  np.asarray(rows, np.float32),
                                  all_or_nothing=True)
            if n < len(keys):
                raise ValueError(
                    f"insert on table {table!r} rejected atomically: the "
                    f"batch contains event(s) beyond the stream's "
                    f"released frontier (unrepairably late) or with "
                    f"non-finite timestamps; nothing was staged")
            errs_before = stream.stats["errors"]
            stream.flush()
            # raise only for failures that left events undelivered: a
            # transient background-flusher error that the flush retried
            # successfully (nothing staged after the flush_all barrier)
            # is not THIS insert's failure
            if (stream.stats["errors"] > errs_before
                    and stream.buffer.n_staged > 0):
                raise stream.last_error
            return
        self.tables[table].insert(keys, ts, rows)

    # ------------------------------------------------------------ streaming
    def attach_stream(self, table: str, cfg=None, **cfg_kw):
        """Attach a streaming ingest pipeline to an existing table.

        ``cfg`` is a ``streaming.PipelineConfig`` (or pass its fields as
        keywords: ``lateness=..., flush_interval_s=..., retention=...``).
        Returns the ``IngestPipeline``; from now on events should arrive
        via ``pipeline.push`` / ``Engine.insert`` (which routes to it).
        """
        from repro.streaming.pipeline import IngestPipeline, PipelineConfig
        if table not in self.tables:
            raise KeyError(f"unknown table {table!r}; create_table first")
        if table in self.streams:
            raise ValueError(f"table {table!r} already has a stream")
        if cfg is None:
            cfg = PipelineConfig(**cfg_kw)
        elif cfg_kw:
            raise ValueError("pass cfg or keywords, not both")
        pipe = IngestPipeline(self.tables[table], cfg)
        self.streams[table] = pipe
        return pipe

    def create_stream(self, schema: TableSchema, *, max_keys: int = 1024,
                      capacity: int = 1024, bucket_size: int = 64,
                      **cfg_kw):
        """``create_table`` + ``attach_stream`` in one call.

        Returns ``(table, pipeline)``."""
        t = self.create_table(schema, max_keys=max_keys, capacity=capacity,
                              bucket_size=bucket_size)
        return t, self.attach_stream(schema.name, **cfg_kw)

    def register_model(self, name: str, fn: Callable,
                       params: object = None) -> None:
        """``fn(params, features (B, F) f32) -> (B,) or (B, k)``."""
        self.models[name] = fn
        self.model_params[name] = params

    # --------------------------------------------------------------- deploy
    def deploy(self, name: str, query: Union[str, Query, dsl.QueryBuilder],
               ) -> Deployment:
        t0 = time.perf_counter()
        if isinstance(query, str):
            q = dsl.parse_sql(query)
        elif isinstance(query, dsl.QueryBuilder):
            q = query.build()
        else:
            q = query
        parse_dt = time.perf_counter() - t0
        self.stats.parse_s += parse_dt

        table = self.tables.get(q.table)
        if table is None:
            raise KeyError(f"unknown table {q.table!r}; create_table first")
        t1 = time.perf_counter()
        meta = TableMeta(capacity=table.capacity,
                         bucket_size=table.bucket_size,
                         n_value_cols=len(table.schema.value_cols),
                         has_preagg=table.preagg is not None)
        plan, log = optimize(q.to_logical(), meta, self.flags)
        phys = compile_plan(plan, table.schema, flags=self.flags,
                            bucket_size=table.bucket_size,
                            model_fns=self.models)
        self.stats.plan_s += time.perf_counter() - t1

        dep = Deployment(name=name, query=q, plan=plan, phys=phys,
                         opt_log=log, table=table)
        self.deployments[name] = dep
        self.registry.register(FeatureSet(name=name, query=q))
        return dep

    def explain(self, name: str) -> str:
        dep = self.deployments[name]
        lines = [f"deployment {name!r} on table {dep.table.schema.name!r}"]
        lines += [f"  plan: {dep.plan.fingerprint()[:160]}"]
        lines += [f"  opt : {l}" for l in dep.opt_log]
        for g in dep.phys.groups:
            lines.append(f"  window {g.name}: impl={g.impl} "
                         f"cols={g.plain_cols} fields={g.fields} "
                         f"aggs={len(g.slots)}")
        return "\n".join(lines)

    # ------------------------------------------------------ compiled lookup
    def _compiled(self, dep: Deployment, bucket: int) -> Callable:
        key = (dep.phys.fingerprint(), bucket, self.flags.assume_latest,
               dep.name if dep.plan.predict else "")
        table = dep.table

        def make() -> Callable:
            executor = dep.phys.executor_for(
                self.flags.assume_latest)
            jit_fn = jax.jit(executor)
            # Warm up: compile for this bucket's shapes now (charged to
            # L_plan, as the paper charges planning+JIT on first execution).
            V = len(table.schema.value_cols)
            snap = table.snapshot()
            dummy = jit_fn(
                snap.state, snap.preagg,
                jnp.zeros((bucket,), jnp.int32),
                jnp.zeros((bucket,), jnp.float32),
                jnp.zeros((bucket, V), jnp.float32),
                self._predict_params(dep))
            jax.block_until_ready(dummy)
            return jit_fn

        fn, plan_dt = self.cache.get_or_compile(key, make)
        self.stats.plan_s += plan_dt
        return fn

    def _predict_params(self, dep: Deployment):
        if dep.plan.predict is None:
            return None
        return self.model_params.get(dep.plan.predict.model)

    # --------------------------------------------------------------- online
    def request(self, name: str, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None
                ) -> Dict[str, np.ndarray]:
        """Serve a batch of online feature requests."""
        dep = self.deployments[name]
        table = dep.table
        B = len(keys)
        if B == 0:
            return {n: np.zeros((0,), np.float32)
                    for n in dep.phys.feature_names}
        kidx = table.key_indices(keys, create=False)
        ts_arr = np.asarray(ts, np.float32)
        V = len(table.schema.value_cols)
        row_arr = (np.asarray(rows, np.float32) if rows is not None
                   else np.zeros((B, V), np.float32))

        # one snapshot per request regardless of execution strategy: a
        # pooled/rowwise request must not mix table versions mid-response
        snap = dep.table.snapshot()
        if self.flags.parallel_workers > 1 and self._pool is not None:
            return self._request_pooled(dep, kidx, ts_arr, row_arr, snap)
        if not self.flags.vectorized:
            return self._request_rowwise(dep, kidx, ts_arr, row_arr, snap)
        return self._request_batched(dep, kidx, ts_arr, row_arr, snap=snap)

    def _request_batched(self, dep: Deployment, kidx, ts_arr, row_arr,
                         snap=None) -> Dict[str, np.ndarray]:
        B = len(kidx)
        bucket = bucket_batch(B)
        fn = self._compiled(dep, bucket)
        pad = bucket - B
        if pad:
            kidx = np.pad(kidx, (0, pad))
            ts_arr = np.pad(ts_arr, (0, pad))
            row_arr = np.pad(row_arr, ((0, pad), (0, 0)))
        # One snapshot for the whole batch: a concurrent stream flush must
        # not swap the table out from under an in-flight query. Callers
        # that span several batches (query_offline) pass their own.
        if snap is None:
            snap = dep.table.snapshot()
        t0 = time.perf_counter()
        out = fn(snap.state, snap.preagg, jnp.asarray(kidx),
                 jnp.asarray(ts_arr), jnp.asarray(row_arr),
                 self._predict_params(dep))
        out = jax.block_until_ready(out)
        self.stats.exec_s += time.perf_counter() - t0
        self.stats.n_requests += B
        self.stats.n_batches += 1
        return {n: np.asarray(a)[:B] for n, a in out.items()}

    def _request_rowwise(self, dep: Deployment, kidx, ts_arr, row_arr,
                         snap=None) -> Dict[str, np.ndarray]:
        """Paper-faithful per-request execution (ablation: vectorized off)."""
        outs: List[Dict[str, np.ndarray]] = []
        for i in range(len(kidx)):
            outs.append(self._request_batched(
                dep, kidx[i:i + 1], ts_arr[i:i + 1], row_arr[i:i + 1],
                snap=snap))
        return {n: np.concatenate([o[n] for o in outs]) for n in outs[0]}

    def _request_pooled(self, dep: Deployment, kidx, ts_arr, row_arr,
                        snap=None) -> Dict[str, np.ndarray]:
        """Worker-pool fan-out (paper O4 'parallel processing')."""
        W = self.flags.parallel_workers
        n = len(kidx)
        shard = max(1, (n + W - 1) // W)
        futs = []
        for s in range(0, n, shard):
            sl = slice(s, min(s + shard, n))
            if self.flags.vectorized:
                futs.append(self._pool.submit(
                    self._request_batched, dep, kidx[sl], ts_arr[sl],
                    row_arr[sl], snap=snap))
            else:
                futs.append(self._pool.submit(
                    self._request_rowwise, dep, kidx[sl], ts_arr[sl],
                    row_arr[sl], snap=snap))
        outs = [f.result() for f in futs]
        return {nme: np.concatenate([o[nme] for o in outs])
                for nme in outs[0]}

    # -------------------------------------------------------------- offline
    def query_offline(self, name: str, *, batch_size: int = 1024,
                      point_in_time: bool = True
                      ) -> Dict[str, np.ndarray]:
        """Run the deployed query over EVERY retained event (training-set
        materialisation). Point-in-time: each event sees only history up to
        its own timestamp — exactly the online semantics, which is the
        training-serving-skew guarantee."""
        dep = self.deployments[name]
        table = dep.table
        # one snapshot for BOTH enumeration and execution: concurrent
        # stream flushes must not shift the table between building the
        # (key, ts) list and computing its features (point-in-time
        # guarantee under live ingest)
        offline_snap = table.snapshot()
        st = offline_snap.state
        totals = np.asarray(st.total)
        C = table.capacity
        req_keys: List[int] = []
        req_slots: List[int] = []
        for k in range(table.n_keys):
            tot = int(totals[k])
            n = min(tot, C)
            for p in range(tot - n, tot):
                req_keys.append(k)
                req_slots.append(p % C)
        if not req_keys:
            return {n: np.zeros((0,), np.float32)
                    for n in dep.phys.feature_names}
        kidx = np.asarray(req_keys, np.int32)
        slots = np.asarray(req_slots, np.int32)
        ts_all = np.asarray(st.ts)[kidx, slots]
        rows_all = np.asarray(st.values)[kidx, slots]

        saved = self.flags
        if point_in_time and self.flags.assume_latest:
            # offline must not assume request-ts is newest
            self.flags = dataclasses.replace(self.flags, assume_latest=False)
        try:
            outs: List[Dict[str, np.ndarray]] = []
            for s in range(0, len(kidx), batch_size):
                sl = slice(s, s + batch_size)
                outs.append(self._request_batched(
                    dep, kidx[sl], ts_all[sl], rows_all[sl],
                    snap=offline_snap))
        finally:
            self.flags = saved
        res = {n: np.concatenate([o[n] for o in outs]) for n in outs[0]}
        res["__key"] = kidx
        res["__ts"] = ts_all
        return res

    # ---------------------------------------------------------------- stats
    def latency_decomposition(self) -> Dict[str, float]:
        s = self.stats
        return {"parse_s": s.parse_s, "plan_s": s.plan_s, "exec_s": s.exec_s,
                "n_requests": s.n_requests,
                "cache_hit_rate": self.cache.stats.hit_rate}

    def close(self) -> None:
        for pipe in self.streams.values():
            pipe.close()
        self.streams.clear()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
