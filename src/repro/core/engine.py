"""Engine facade: deploy SQL+ML feature queries, serve them online, run them
offline — one definition, two execution modes (the paper's core promise).

Hot path anatomy (paper Eq. 3: ``L = L_parse + L_plan + L_exec``):

* ``deploy``  — parse (L_parse) + optimize + lower (L_plan, amortised by the
  plan cache across deployments and batch buckets);
* ``request`` — key lookup (device-resident hash directory for integer key
  batches, host dict otherwise), pad to a shape bucket, run the compiled
  executable (L_exec; per-request input buffers are donated), unpad.

``deploy`` returns a first-class :class:`DeploymentHandle` — a versioned
serving endpoint that OWNS its compiled per-bucket executables. Redeploying
an existing name is a **hot swap**: version N+1 is built and pre-warmed
(every configured shape bucket compiled) before an atomic publish, the
retired version's plan-cache entries are invalidated by fingerprint, and
``rollback`` restores the prior version instantly (retired handles keep
their executables). See DESIGN.md §6 for the lifecycle contract.

"Parallel processing" (paper O4) has two forms here: vectorised batch
execution (TPU-native; default) and a worker-pool mode
(``flags.parallel_workers > 1``) that reproduces the paper's thread-level
ablation semantics on CPU.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import dataclasses
import os
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsl
from repro.core.logical import LogicalPlan, Query
from repro.core.optimizer import CostModel, OptFlags, TableMeta, optimize
from repro.core.physical import PhysicalPlan, compile_plan
from repro.core.plan_cache import PlanCache, bucket_batch
from repro.core.results import (STATUS_OK, STATUS_UNKNOWN_KEY,
                                DeadlineExceeded, FeatureFrame,
                                RequestContext)
from repro.featurestore.registry import FeatureRegistry, FeatureSet
from repro.featurestore.table import Table, TableSchema, TableSnapshot
from repro.obs.flight import FlightRecorder
from repro.obs.freshness import FreshnessTracker
from repro.obs.sketch import DriftMonitor, QuantileSketch, RollingSketch
from repro.relational.catalog import Catalog

__all__ = ["Engine", "Deployment", "DeploymentHandle", "HandleMetrics",
           "EngineStats"]


@dataclass
class EngineStats:
    """Cumulative latency decomposition (seconds) + counters.

    Every field is a monotonic counter — it only ever grows — so two
    ``snapshot()`` dicts taken at different instants can be subtracted
    (``delta``) to get an interval's worth of work without racing the
    serving threads that mutate the live fields. That interval diff is
    what the adaptive control plane's :class:`~repro.control.telemetry.
    MetricsCollector` samples (DESIGN.md §10)."""

    parse_s: float = 0.0
    plan_s: float = 0.0
    exec_s: float = 0.0
    # host-side serve residual: keydir resolve, padding, unknown-key
    # masking — serve wall minus exec minus the batch's compile charge
    host_s: float = 0.0
    # total serve wall time; the decomposition identity the obs tier
    # tests enforce is serve_s ≈ Σ STAGES over any serve-only interval
    serve_s: float = 0.0
    n_requests: int = 0
    n_batches: int = 0
    # window-kernel invocations dispatched (fused multi-window plans count
    # ONE per batch for their whole plain-window set)
    kernel_launches: int = 0

    _FIELDS = ("parse_s", "plan_s", "exec_s", "host_s", "serve_s",
               "n_requests", "n_batches", "kernel_launches")
    # the per-request latency STAGES (paper Eq. 3 + the host residual).
    # Declared explicitly so the decomposition self-consistency test can
    # fail when someone adds a new ``*_s`` stage without deciding whether
    # it is inside serve_s: every timing field must be a stage, serve_s
    # itself, or parse_s (deploy-time, outside the serve wall)
    STAGES = ("plan_s", "exec_s", "host_s")

    def snapshot(self) -> Dict[str, float]:
        """Cheap point-in-time copy of the monotonic counters (plain
        field reads — no dataclass reflection, safe to call from any
        thread at serving rates)."""
        return {f: getattr(self, f) for f in self._FIELDS}

    def delta(self, prev: Dict[str, float]) -> Dict[str, float]:
        """Interval counters since ``prev`` (an earlier ``snapshot()``).
        Clamped at 0 so a counter reset (fresh engine) never yields
        negative work."""
        now = self.snapshot()
        return {f: max(now[f] - prev.get(f, 0), 0) for f in self._FIELDS}


@dataclass
class HandleMetrics:
    """Per-deployment-version serving counters."""

    requests: int = 0
    batches: int = 0
    serve_s: float = 0.0
    unknown_keys: int = 0
    canary_batches: int = 0
    canary_max_abs_diff: float = 0.0
    # LAST JOIN observability (per right table): how many probes found a
    # right row, online only — offline materialisation doesn't count
    join_probes: Dict[str, int] = dataclasses.field(default_factory=dict)
    join_matches: Dict[str, int] = dataclasses.field(default_factory=dict)
    # rolling sketch of recent per-batch serve latencies (seconds) —
    # what the control plane's replan health check computes p99 over.
    # Replaces the old 512-sample deque reservoir (DESIGN.md §14):
    # bounded memory regardless of traffic, displaced by TIME instead of
    # sample count, and cross-shard merges are exact instead of
    # worst-shard-max. ``len(latency_s)`` stays the monotonic batch
    # count (what the replan health gate counts).
    latency_s: RollingSketch = dataclasses.field(
        default_factory=lambda: RollingSketch(
            window_s=HandleMetrics.LATENCY_WINDOW_S))

    LATENCY_WINDOW_S = 5.0

    def observe_latency(self, seconds: float) -> None:
        self.latency_s.observe(float(seconds))

    def latency_percentile(self, pct: float) -> float:
        """Percentile (e.g. 99) over the rolling latency window;
        NaN with no samples (an empty window has no tail)."""
        return self.latency_s.percentile(pct)

    def snapshot(self) -> Dict[str, float]:
        """JSON-serializable copy. The latency sketch rides along under
        ``latency_sketch`` (a few dozen buckets) so the sharded rollup
        merges percentiles EXACTLY instead of maxing per-shard p99s."""
        sk = self.latency_s.sketch()
        return {
            "requests": self.requests, "batches": self.batches,
            "serve_s": self.serve_s, "unknown_keys": self.unknown_keys,
            "canary_batches": self.canary_batches,
            "canary_max_abs_diff": self.canary_max_abs_diff,
            "join_probes": dict(self.join_probes),
            "join_matches": dict(self.join_matches),
            "latency_samples": len(self.latency_s),
            "latency_p50_s": sk.percentile(50),
            "latency_p99_s": sk.percentile(99),
            "latency_sketch": sk.to_dict(),
        }


class DeploymentHandle:
    """One versioned deployment of a query: the serving endpoint.

    Lifecycle: ``building -> warming -> live -> retired`` (a redeploy with
    a canary fraction parks the new version in ``canary`` between warming
    and live). The handle owns its compiled per-bucket executables in
    ``_fns`` — the first-level lookup on the hot path — so plan-cache
    invalidation of a retired version can never stall an in-flight batch,
    and ``rollback`` re-lives a retired version without recompiling.
    """

    BUILDING = "building"
    WARMING = "warming"
    CANARY = "canary"
    LIVE = "live"
    RETIRED = "retired"

    def __init__(self, engine: "Engine", name: str, version: int,
                 query: Query, plan: LogicalPlan, phys: PhysicalPlan,
                 opt_log: List[str], table: Table):
        self.engine = engine
        self.name = name
        self.version = version
        self.query = query
        self.plan = plan
        self.phys = phys
        self.opt_log = opt_log
        self.table = table
        # right tables of the plan's LAST JOINs, in probe order (the
        # optimizer ordered them); resolved once so the hot path never
        # touches the catalog
        self.join_tables: Tuple[Table, ...] = tuple(
            engine.catalog.get(j.table).table for j in plan.joins)
        self.state = self.BUILDING
        self.metrics = HandleMetrics()
        self.buckets_seen: Set[int] = set()
        self._fns: Dict[Tuple[int, bool], Callable] = {}
        self._canary: Optional[Tuple["DeploymentHandle", float]] = None
        self._canary_counter = 0
        self._lock = threading.Lock()
        # right-row ages (req_ts − joined row ts, in event-time units)
        # per joined table: a quantile sketch per right table — bounded
        # buckets instead of the old 4096-sample deque, and the sharded
        # rollup merges staleness percentiles exactly (DESIGN.md §14)
        self._join_ages: Dict[str, QuantileSketch] = {
            j.table: QuantileSketch() for j in plan.joins}

    # ------------------------------------------------------------ identity
    @property
    def tag(self) -> str:
        """Plan-cache attribution tag for this version."""
        return f"{self.name}@v{self.version}"

    @property
    def live(self) -> bool:
        return self.state == self.LIVE

    def __repr__(self) -> str:
        return (f"DeploymentHandle({self.name!r} v{self.version} "
                f"[{self.state}] on {self.table.schema.name!r})")

    # ------------------------------------------------------ compiled lookup
    def _compiled(self, bucket: int, record: bool = True) -> Callable:
        eng = self.engine
        assume_latest = eng.flags.assume_latest
        # buckets_seen drives redeploy pre-warming: only ONLINE-served
        # buckets belong in it (warm() and query_offline would otherwise
        # propagate their shapes into every future swap forever)
        if record and bucket not in self.buckets_seen:
            with self._lock:  # deploy/rollback snapshot this set mid-swap
                self.buckets_seen.add(bucket)
        fn = self._fns.get((bucket, assume_latest))
        if fn is not None:
            # first-level hit: still a plan-cache hit for Eq. 3 accounting
            eng.cache.record_hit(self.tag)
            return fn
        key = (self.phys.fingerprint(), bucket, assume_latest,
               self.name if self.plan.predict else "")
        table = self.table

        def make() -> Callable:
            executor = self.phys.executor_for(assume_latest)
            # the per-request f32 arrays (req ts, req row) are transient —
            # donating them lets XLA reuse their buffers for outputs on
            # every dispatch (table state/preagg are shared, NOT donated;
            # the int32 key index can't alias f32 outputs, so donating it
            # would only produce unusable-buffer warnings)
            jit_fn = jax.jit(executor, donate_argnums=(3, 4))
            # Warm up: compile for this bucket's shapes now (charged to
            # L_plan, as the paper charges planning+JIT on first execution).
            # Dummy inputs go through table.put so their placement (and
            # therefore the jit cache signature) matches what the request
            # path will pass — a device-pinned shard table must not pay a
            # surprise recompile on its first real batch.
            V = len(table.schema.value_cols)
            snap = table.snapshot()
            dummy = jit_fn(
                snap.state, snap.preagg,
                table.put(np.zeros((bucket,), np.int32)),
                table.put(np.zeros((bucket,), np.float32)),
                table.put(np.zeros((bucket, V), np.float32)),
                eng._predict_params(self),
                tuple((jt.snapshot().state,
                       table.put(np.zeros((bucket,), np.int32)),
                       table.put(np.zeros((bucket,), np.bool_)))
                      for jt in self.join_tables))
            jax.block_until_ready(dummy)
            return jit_fn

        fn, plan_dt = eng.cache.get_or_compile(key, make, tag=self.tag)
        eng.stats.plan_s += plan_dt
        if eng.cache.enabled:
            # the handle owns its executables; disabled-cache ablations
            # must keep paying the recompile, so no memo there
            self._fns[(bucket, assume_latest)] = fn
        return fn

    def warm(self, buckets: Sequence[int]) -> int:
        """Compile every listed shape bucket now (off the serving path).
        Sizes are rounded through ``bucket_batch`` — the only shapes the
        request path can ever ask for — and deduplicated. Returns the
        number of buckets compiled or refreshed."""
        rounded = sorted({bucket_batch(int(b)) for b in buckets})
        for b in rounded:
            self._compiled(b, record=False)
        return len(rounded)

    def release(self) -> None:
        """Drop owned executables (memory reclamation for old versions)."""
        self._fns.clear()

    # ---------------------------------------------------------------- joins
    def _record_join_stats(self, res: Dict[str, np.ndarray], B: int,
                           record: bool = True) -> None:
        """Strip the executor's hidden ``__join_*`` outputs from ``res``
        and (online only) fold them into the staleness metrics: per-table
        match counts and a bounded reservoir of matched right-row ages."""
        for j in self.plan.joins:
            m = res.pop(f"__join_match_{j.table}", None)
            age = res.pop(f"__join_age_{j.table}", None)
            if not record or m is None:
                continue
            matched = np.asarray(m) > 0.5
            n_match = int(matched.sum())
            with self._lock:
                mt = self.metrics
                mt.join_probes[j.table] = (
                    mt.join_probes.get(j.table, 0) + B)
                mt.join_matches[j.table] = (
                    mt.join_matches.get(j.table, 0) + n_match)
                if age is not None and n_match:
                    self._join_ages[j.table].observe_many(
                        np.asarray(age)[matched])

    def join_staleness(self) -> Dict[str, Dict[str, float]]:
        """Per joined table: probe match-rate and right-row age
        percentiles (event-time units) over the recent-age reservoir —
        the serving-observability view of how stale each LAST JOIN's
        right rows are (ROADMAP: right-table ring staleness metrics)."""
        out: Dict[str, Dict[str, float]] = {}
        with self._lock:
            for j in self.plan.joins:
                probes = self.metrics.join_probes.get(j.table, 0)
                matches = self.metrics.join_matches.get(j.table, 0)
                sk = self._join_ages[j.table]
                out[j.table] = {
                    "probes": probes,
                    "matches": matches,
                    "match_rate": matches / probes if probes else 0.0,
                    "age_p50": sk.percentile(50),
                    "age_p99": sk.percentile(99),
                    "age_samples": sk.count,
                    # exact cross-shard merging (repro.shard rollup)
                    "age_sketch": sk.to_dict(),
                }
        return out

    def join_snapshots(self) -> Tuple[TableSnapshot, ...]:
        """One consistent snapshot per joined table (probe order). A batch
        (or a whole offline materialisation) must join against a single
        version of each right table regardless of concurrent ingest."""
        return tuple(jt.snapshot() for jt in self.join_tables)

    def _resolve_join_keys(self, row_arr: np.ndarray) -> List[Tuple]:
        """Per join: ``(right_key_index (B,) i32, found (B,) bool)``.

        Probe values come from the request rows' ``on`` column; integer
        key batches resolve through the right table's device-resident
        key directory (one jitted probe), anything else falls back to
        the host dict — the same contract as the main-table lookup.
        Unknown keys come back ``found=False`` and are masked to zero
        joined columns by the executor.
        """
        out: List[Tuple] = []
        for j, jt in zip(self.plan.joins, self.join_tables):
            ci = self.table.schema.col_index(j.on)
            vals = np.asarray(row_arr[:, ci], np.float64)
            ki = np.rint(vals).astype(np.int64)
            integral = np.abs(vals - ki) < 1e-6
            kd = jt.keydir
            if bool(integral.all()) and kd.covers(ki):
                kidx, found = kd.lookup(ki)
            else:
                B = len(ki)
                kidx = np.zeros(B, np.int32)
                found = np.zeros(B, np.bool_)
                k2i = jt.key_to_idx
                for i in range(B):
                    if integral[i]:
                        idx = k2i.get(int(ki[i]))
                        if idx is not None:
                            kidx[i] = idx
                            found[i] = True
            out.append((kidx, found))
        return out

    # --------------------------------------------------------------- serve
    def request(self, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None,
                ctx: Optional[RequestContext] = None,
                n_live: Optional[int] = None) -> FeatureFrame:
        """Serve a batch of online feature requests on THIS version.

        ``n_live`` marks how many leading rows are REAL when the caller
        edge-padded the batch to a shape bucket (the shard lane repeats
        the last row): pad rows are served but excluded from freshness /
        drift observation, so equal request multisets produce equal
        sketches on every backend."""
        if ctx is not None and ctx.expired:
            raise DeadlineExceeded(
                f"deadline expired before serving {self.tag}")
        cand = None
        # pinned traffic asked for THIS version: never reroute it to a
        # canary (it would both violate the pin and pollute the
        # candidate's promote-decision metrics)
        pinned = ctx is not None and ctx.version_pin is not None
        canary = None if pinned else self._canary   # read once:
        if canary is not None:      # promote/rollback/deploy clear it
            cand_handle, frac = canary
            with self._lock:
                self._canary_counter += 1
                n = self._canary_counter
            if int(n * frac) > int((n - 1) * frac):
                cand = cand_handle
        if cand is None:
            return self._serve(keys, ts, rows, ctx, n_live=n_live)
        # canary slice: the new version serves the batch; the incumbent
        # computes the same batch as reference and the divergence is
        # recorded on the candidate (promote/rollback evidence).
        base = self._serve(keys, ts, rows, ctx, n_live=n_live)
        new = cand._serve(keys, ts, rows, ctx, n_live=n_live)
        diff = 0.0
        for nme, v in new.columns.items():
            ref = base.columns.get(nme)
            if ref is not None and np.size(v):
                diff = max(diff, float(np.max(np.abs(
                    np.asarray(v, np.float64) - np.asarray(ref, np.float64)))))
        with cand._lock:
            cand.metrics.canary_batches += 1
            cand.metrics.canary_max_abs_diff = max(
                cand.metrics.canary_max_abs_diff, diff)
        return new

    def request_async(self, keys: Sequence, ts: Sequence[float],
                      rows: Optional[np.ndarray] = None,
                      ctx: Optional[RequestContext] = None) -> cf.Future:
        """``request`` on a background thread; returns a Future[FeatureFrame]."""
        return self.engine._ensure_async_pool().submit(
            self.request, keys, ts, rows, ctx)

    def _serve(self, keys: Sequence, ts: Sequence[float],
               rows: Optional[np.ndarray],
               ctx: Optional[RequestContext],
               n_live: Optional[int] = None) -> FeatureFrame:
        eng = self.engine
        table = self.table
        B = len(keys)
        nl = B if n_live is None else max(0, min(int(n_live), B))
        trace = ctx.trace_id if ctx is not None else None
        if B == 0:
            return FeatureFrame(
                {n: np.zeros((0,), np.float32)
                 for n in self.phys.feature_names},
                status=np.zeros((0,), np.int8), deployment=self.name,
                version=self.version, table_version=table.version,
                trace_id=trace)
        t_start = time.perf_counter()
        span = eng.tracer.start(
            "engine.serve", trace,
            parent_id=ctx.parent_span if ctx is not None else None,
            tags={"deployment": self.tag, "rows": B})
        # unknown keys are masked (index 0, empty history) instead of
        # raising: the caller gets per-request status, the rest of the
        # batch is unaffected. Integer key batches resolve through the
        # device-resident directory (one jitted probe; kidx never leaves
        # the device and the found-mask is materialised only AFTER the
        # executor dispatch, so the probe round-trip overlaps feature
        # computation); anything else falls back to the host dict loop.
        karr = np.asarray(keys)
        kd = table.keydir
        found = None
        if karr.dtype.kind in "iu" and kd.covers(karr):
            kidx, found = kd.lookup(karr)
        else:
            kidx = np.zeros(B, np.int32)
            status = np.zeros(B, np.int8)
            k2i = table.key_to_idx
            for i, k in enumerate(keys):
                idx = k2i.get(k)
                if idx is None:
                    status[i] = STATUS_UNKNOWN_KEY
                else:
                    kidx[i] = idx
        ts_arr = np.asarray(ts, np.float32)
        V = len(table.schema.value_cols)
        if rows is None and self.plan.joins:
            # the no-row zero default would silently probe right-table
            # key 0 for every request — plausible-but-wrong joined
            # features, so joined deployments require the request row
            raise ValueError(
                f"deployment {self.name!r} has {len(self.plan.joins)} "
                f"LAST JOIN(s); online requests must pass rows= — the "
                f"join probes read the request row's "
                f"{sorted({j.on for j in self.plan.joins})} column(s), "
                f"and the zero-row default would probe key 0 instead")
        row_arr = (np.asarray(rows, np.float32) if rows is not None
                   else np.zeros((B, V), np.float32))
        plan_before = eng.cache.tag_stats(self.tag).compile_seconds
        # one snapshot per request regardless of execution strategy: a
        # pooled/rowwise request must not mix table versions mid-response
        # (join snapshots included — every joined table is pinned too)
        snap = table.snapshot()
        jsnaps = self.join_snapshots()
        if eng.flags.parallel_workers > 1 and eng._pool is not None:
            out = eng._request_pooled(self, kidx, ts_arr, row_arr, snap,
                                      join_snaps=jsnaps)
        elif not eng.flags.vectorized:
            out = eng._request_rowwise(self, kidx, ts_arr, row_arr, snap,
                                       join_snaps=jsnaps)
        else:
            out = eng._request_batched(self, kidx, ts_arr, row_arr,
                                       snap=snap, join_snaps=jsnaps)
        # hidden per-dispatch exec clock (popped before the frame is
        # built): a dict key rather than thread-local state because the
        # pooled path executes on pool threads, and rather than the
        # global stats delta because concurrent serves would cross-read
        exec_dt = float(out.pop("__exec_s", 0.0))
        if found is not None:
            status = np.where(np.asarray(found), STATUS_OK,
                              STATUS_UNKNOWN_KEY).astype(np.int8)
        unknown = status == STATUS_UNKNOWN_KEY
        n_unknown = int(unknown.sum())
        if n_unknown:
            out = {n: np.asarray(v).copy() for n, v in out.items()}
            for v in out.values():
                v[unknown] = 0.0
        wall = time.perf_counter() - t_start
        plan_dt = max(eng.cache.tag_stats(self.tag).compile_seconds
                      - plan_before, 0.0)
        # decomposition identity (obs tier): serve = plan + exec + host,
        # with host the measured residual — clamped so a clock glitch
        # can never push a stage negative
        host_dt = max(wall - exec_dt - plan_dt, 0.0)
        with self._lock:
            m = self.metrics
            m.requests += B
            m.batches += 1
            m.serve_s += wall
            m.unknown_keys += n_unknown
            m.observe_latency(wall)
        eng.stats.serve_s += wall
        eng.stats.host_s += host_dt
        # data-plane observability (DESIGN.md §14): per-row feature age
        # against the served snapshot's watermark, live feature
        # distributions for drift, and a flight-recorder breadcrumb.
        # Only the first ``nl`` rows are real — pad rows are excluded so
        # sketches agree bit-for-bit across backends.
        batch_age = float("nan")
        wm = snap.watermark
        if nl and np.isfinite(wm):
            ages = np.asarray(ts_arr[:nl], np.float64) - wm
            batch_age = float(ages.max())
            eng.freshness.observe_age(table.schema.name, ages)
        if nl:
            eng.drift.observe(out, n=nl)
        eng.flight.record(
            "serve", trace=trace, deployment=self.tag, rows=nl,
            unknown=n_unknown, table_version=snap.version,
            watermark=wm if np.isfinite(wm) else None,
            feature_age=batch_age if np.isfinite(batch_age) else None,
            serve_ms=wall * 1e3)
        attributed = eng.profiler.record(
            self, B, exec_s=exec_dt, host_s=host_dt, plan_s=plan_dt,
            serve_s=wall, model=eng.cost_model)
        if span is not None:
            # per-kernel-launch children are ATTRIBUTED, not clocked —
            # the jitted dispatch is one block_until_ready, so each
            # operator's share of the measured exec window is laid out
            # sequentially across it (DESIGN.md §13)
            t_op = t_start + wall - exec_dt
            for r in attributed:
                if r["seconds"] <= 0:
                    continue
                eng.tracer.record(
                    f"kernel.{r['op']}", trace, span.span_id,
                    t_op, t_op + r["seconds"],
                    tags={"attributed": True,
                          "share": round(r["share"], 4)})
                t_op += r["seconds"]
            eng.tracer.finish(span, tags={
                "exec_s": exec_dt, "host_s": host_dt, "plan_s": plan_dt})
        return FeatureFrame(
            out, status=status, deployment=self.name, version=self.version,
            table_version=snap.version,
            latency={"serve_s": wall, "plan_s": plan_dt},
            trace_id=trace,
            watermark=float(wm) if np.isfinite(wm) else None,
            feature_age=batch_age if np.isfinite(batch_age) else None)

    # ----------------------------------------------------------- lifecycle
    def rollback(self) -> "DeploymentHandle":
        """Restore the previous version of this deployment name."""
        return self.engine.rollback(self.name)


# Backwards-compatible alias: the old thin record grew into the handle.
Deployment = DeploymentHandle


class Engine:
    def __init__(self, flags: OptFlags = OptFlags(), *,
                 max_cache_entries: int = 128,
                 warm_buckets: Sequence[int] = (),
                 max_retained_versions: int = 2,
                 cost_model: Optional[CostModel] = None):
        self.flags = flags
        # calibratable optimizer constants: every build_version plans
        # against the CURRENT model, so swapping it (set_cost_model) plus
        # a redeploy is how the control plane re-plans a deployment
        self.cost_model = cost_model if cost_model is not None \
            else CostModel()
        self.tables: Dict[str, Table] = {}
        self.catalog = Catalog()        # relational tier (DESIGN.md §8)
        self.models: Dict[str, Callable] = {}
        self.model_params: Dict[str, object] = {}
        self.deployments: Dict[str, DeploymentHandle] = {}
        self.registry = FeatureRegistry()
        self.cache = PlanCache(max_entries=max_cache_entries,
                               enabled=flags.plan_cache)
        self.streams: Dict[str, object] = {}   # table -> IngestPipeline
        self.stats = EngineStats()
        # observability tier (DESIGN.md §13). The tracer defaults to
        # sampling OFF — FeatureServer / ShardedEngine / tests turn it on
        # via set_sample_rate; the profiler always accumulates (it rides
        # timings the stats path already takes, no extra clock reads)
        from repro.obs.profile import OperatorProfiler
        from repro.obs.trace import Tracer
        self.tracer = Tracer(sample_rate=float(
            os.environ.get("REPRO_TRACE_SAMPLE", "0") or 0))
        self.profiler = OperatorProfiler()
        # data-plane observability (DESIGN.md §14): feature freshness,
        # serving-distribution drift, and the flight recorder — all
        # mergeable across shards (ShardedEngine folds per-worker
        # snapshots via the freshness_snapshot RPC)
        self.freshness = FreshnessTracker()
        self.drift = DriftMonitor()
        self.flight = FlightRecorder()
        # shape buckets every new deployment version pre-compiles before
        # going live (redeploys additionally warm the buckets the retired
        # version actually served)
        self.warm_buckets: Tuple[int, ...] = tuple(warm_buckets)
        self.max_retained_versions = max_retained_versions
        self._versions: Dict[str, Dict[int, DeploymentHandle]] = {}
        self._next_version: Dict[str, int] = {}   # monotonic even after
        self._history: Dict[str, List[DeploymentHandle]] = {}  # pruning
        self._deploy_lock = threading.RLock()
        self._async_lock = threading.Lock()
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._async_pool: Optional[cf.ThreadPoolExecutor] = None
        self._closed = False
        if flags.parallel_workers > 1:
            self._pool = cf.ThreadPoolExecutor(flags.parallel_workers)

    # ------------------------------------------------------------------ DDL
    def create_table(self, schema: TableSchema, *, max_keys: int = 1024,
                     capacity: int = 1024, bucket_size: int = 64,
                     join_keys: Sequence[str] = (),
                     device=None) -> Table:
        """Create a table and register it in the relational catalog.

        ``join_keys`` declares which columns LAST JOIN may probe; the
        partition key is always declared (it is what the device key
        directory indexes) and is currently the only supported choice.
        ``device`` pins the table's state (and its key directory mirror)
        to one jax device — the sharded runtime places one shard per
        device so shard executions ride separate device streams.
        """
        if schema.name in self.tables:
            raise ValueError(f"table {schema.name!r} exists")
        t = Table(schema, max_keys=max_keys, capacity=capacity,
                  bucket_size=bucket_size, enable_preagg=self.flags.preagg,
                  device=device)
        self.catalog.register(t, join_keys=join_keys)
        self.tables[schema.name] = t
        self.registry.register_schema(schema)
        return t

    def insert(self, table: str, keys: Sequence, ts: Sequence[float],
               rows: np.ndarray, *, donate: bool = True) -> None:
        """Synchronous bulk insert (offline/backfill path). Routes through
        an attached stream when one exists — a table with a live pipeline
        has a single writer, so direct donation-mode insert would race the
        flusher.

        ``donate=False`` takes the copy-on-write ingest (old device
        buffers stay live) — required whenever another thread may hold a
        snapshot of this table mid-serve, e.g. sharded-engine writes and
        key migration landing on an engine whose lane is executing.

        Atomic: if any event is unrepairably late (beyond the stream's
        released frontier), nothing is staged and ValueError is raised —
        matching the direct path's validate-before-ingest contract. Note
        the flush acts as a stream **barrier**: everything staged becomes
        immediately queryable, which forfeits the reorder window for
        events at or below the barrier (a later live push older than the
        barrier is dropped as late — by then its ring neighborhood is
        final)."""
        stream = self.streams.get(table)
        if stream is not None:
            keys = list(keys)
            n = stream.push_batch(keys, np.asarray(ts, np.float32),
                                  np.asarray(rows, np.float32),
                                  all_or_nothing=True)
            if n < len(keys):
                raise ValueError(
                    f"insert on table {table!r} rejected atomically: the "
                    f"batch contains event(s) beyond the stream's "
                    f"released frontier (unrepairably late) or with "
                    f"non-finite timestamps; nothing was staged")
            errs_before = stream.stats["errors"]
            stream.flush()
            # raise only for failures that left events undelivered: a
            # transient background-flusher error that the flush retried
            # successfully (nothing staged after the flush_all barrier)
            # is not THIS insert's failure
            if (stream.stats["errors"] > errs_before
                    and stream.buffer.n_staged > 0):
                raise stream.last_error
            return
        self.tables[table].insert(keys, ts, rows, donate=donate)

    # ------------------------------------------------------------ streaming
    def attach_stream(self, table: str, cfg=None, **cfg_kw):
        """Attach a streaming ingest pipeline to an existing table.

        ``cfg`` is a ``streaming.PipelineConfig`` (or pass its fields as
        keywords: ``lateness=..., flush_interval_s=..., retention=...``).
        Returns the ``IngestPipeline``; from now on events should arrive
        via ``pipeline.push`` / ``Engine.insert`` (which routes to it).
        """
        from repro.streaming.pipeline import IngestPipeline, PipelineConfig
        if table not in self.tables:
            raise KeyError(f"unknown table {table!r}; create_table first")
        if table in self.streams:
            raise ValueError(f"table {table!r} already has a stream")
        if cfg is None:
            cfg = PipelineConfig(**cfg_kw)
        elif cfg_kw:
            raise ValueError("pass cfg or keywords, not both")
        pipe = IngestPipeline(self.tables[table], cfg,
                              freshness=self.freshness)
        self.streams[table] = pipe
        return pipe

    def create_stream(self, schema: TableSchema, *, max_keys: int = 1024,
                      capacity: int = 1024, bucket_size: int = 64,
                      **cfg_kw):
        """``create_table`` + ``attach_stream`` in one call.

        Returns ``(table, pipeline)``."""
        t = self.create_table(schema, max_keys=max_keys, capacity=capacity,
                              bucket_size=bucket_size)
        return t, self.attach_stream(schema.name, **cfg_kw)

    def register_model(self, name: str, fn: Callable,
                       params: object = None) -> None:
        """``fn(params, features (B, F) f32) -> (B,) or (B, k)``."""
        self.models[name] = fn
        self.model_params[name] = params

    def set_cost_model(self, model: CostModel) -> CostModel:
        """Install calibrated optimizer constants. Takes effect on the
        NEXT ``build_version`` — live handles keep the plan they were
        built with (re-planning them is the Replanner's job, through the
        normal build → warm → publish hot-swap path). Returns the
        previous model so a failed replan can restore it."""
        with self._deploy_lock:
            prev = self.cost_model
            self.cost_model = model
            return prev

    # --------------------------------------------------------------- deploy
    def build_version(self, name: str,
                      query: Union[str, Query, dsl.QueryBuilder], *,
                      warm_buckets: Optional[Sequence[int]] = None
                      ) -> DeploymentHandle:
        """Parse, optimize, lower and pre-warm a NEW version of ``name``
        WITHOUT publishing it — the handle comes back in the ``warming``
        state and serves only direct calls until ``publish_version`` flips
        it live. This is the build half of ``deploy``; the sharded runtime
        uses it to compile one version per shard and then publish the
        whole set atomically (repro.shard.engine)."""
        t0 = time.perf_counter()
        if isinstance(query, str):
            q = dsl.parse_sql(query)
        elif isinstance(query, dsl.QueryBuilder):
            q = query.build()
        else:
            q = query
        self.stats.parse_s += time.perf_counter() - t0

        table = self.tables.get(q.table)
        if table is None:
            raise KeyError(f"unknown table {q.table!r}; create_table first")
        with self._deploy_lock:
            t1 = time.perf_counter()
            meta = TableMeta(capacity=table.capacity,
                             bucket_size=table.bucket_size,
                             n_value_cols=len(table.schema.value_cols),
                             has_preagg=table.preagg is not None)
            plan, log = optimize(q.to_logical(), meta, self.flags,
                                 catalog=self.catalog,
                                 cost_model=self.cost_model)
            phys = compile_plan(plan, table.schema, flags=self.flags,
                                bucket_size=table.bucket_size,
                                model_fns=self.models,
                                join_schemas={j.table:
                                              self.catalog.schema(j.table)
                                              for j in plan.joins})
            self.stats.plan_s += time.perf_counter() - t1

            prev = self.deployments.get(name)
            versions = self._versions.setdefault(name, {})
            version = self._next_version.get(name, 0) + 1
            self._next_version[name] = version
            h = DeploymentHandle(self, name, version, q, plan, phys, log,
                                 table)
            h.state = DeploymentHandle.WARMING
            if self.cache.enabled:
                # with the plan cache ablated nothing retains a warmed
                # executable, so warming would be N discarded compiles
                warm = set(self.warm_buckets)
                if warm_buckets is not None:
                    warm |= {int(b) for b in warm_buckets}
                if prev is not None:
                    with prev._lock:   # serving threads add concurrently
                        warm |= prev.buckets_seen
                h.warm(sorted(warm))
            versions[version] = h
            self.registry.register(FeatureSet(name=name, query=q,
                                              version=version))
            return h

    def publish_version(self, handle: DeploymentHandle
                        ) -> DeploymentHandle:
        """Atomically make a built (or previously retired) version the
        live one. Re-warms a version whose executables were released, off
        the serving path — the publish itself is one dict store."""
        with self._deploy_lock:
            prev = self.deployments.get(handle.name)
            if prev is handle:
                return handle
            hist = self._history.get(handle.name)
            if hist and handle in hist:
                hist.remove(handle)
            if not handle._fns and self.cache.enabled:
                with handle._lock:
                    buckets = sorted(handle.buckets_seen)
                handle.warm(buckets)
            self._swap(handle.name, handle, prev)
            return handle

    def discard_version(self, handle: DeploymentHandle) -> None:
        """Retire a built-but-never-published version (e.g. an aborted
        cross-shard canary): invalidate its cache entries unless shared
        with a live version, and drop it from the version map."""
        with self._deploy_lock:
            if self.deployments.get(handle.name) is handle:
                raise ValueError(
                    f"{handle.tag} is the live version; use rollback")
            handle.state = DeploymentHandle.RETIRED
            self._invalidate_if_unused(handle)
            self._versions.get(handle.name, {}).pop(handle.version, None)

    def deploy(self, name: str, query: Union[str, Query, dsl.QueryBuilder],
               *, warm_buckets: Optional[Sequence[int]] = None,
               canary: float = 0.0) -> DeploymentHandle:
        """Deploy (or hot-swap redeploy) a query as a versioned handle.

        Redeploying an existing name builds version N+1, pre-warms every
        configured shape bucket (``warm_buckets`` ∪ engine defaults ∪ the
        retired version's observed buckets), then atomically publishes the
        new version — no request ever pays a JIT compile on the new
        version, and in-flight batches finish on the old one. With
        ``canary > 0`` the new version instead serves that fraction of
        batches (outputs compared against the incumbent) until
        ``promote``/``rollback`` decides.
        """
        if canary:
            if not (0.0 < canary <= 1.0):
                raise ValueError(f"canary fraction must be in (0, 1], "
                                 f"got {canary}")
            if name not in self.deployments:
                # fail BEFORE the plan build: compiling a whole physical
                # plan for a guaranteed error wastes seconds under load
                raise ValueError(
                    f"canary deploy of {name!r} requires an existing live "
                    f"deployment to compare against; deploy without "
                    f"canary= first")
        with self._deploy_lock:
            prev = self.deployments.get(name)
            if canary > 0.0 and prev is None:
                raise ValueError(
                    f"canary deploy of {name!r} requires an existing live "
                    f"deployment to compare against; deploy without "
                    f"canary= first")
            h = self.build_version(name, query, warm_buckets=warm_buckets)
            if canary > 0.0:
                # attach the new canary BEFORE retiring a displaced one:
                # _invalidate_if_unused must see h as a live user of a
                # shared fingerprint, or it would evict the entries h was
                # just warmed from
                displaced = prev._canary[0] if prev._canary else None
                h.state = DeploymentHandle.CANARY
                prev._canary = (h, float(canary))
                if displaced is not None:
                    displaced.state = DeploymentHandle.RETIRED
                    self._invalidate_if_unused(displaced)
                    self._versions.get(name, {}).pop(
                        displaced.version, None)
            else:
                self._swap(name, h, prev)
            return h

    def _retire_canary(self, holder: Optional[DeploymentHandle]) -> None:
        """Detach and retire ``holder``'s active canary (aborted or
        displaced): drop its executables and cache entries so it cannot
        become the stale-executable leak redeploys are meant to fix."""
        if holder is None or holder._canary is None:
            return
        cand, _ = holder._canary
        holder._canary = None
        cand.state = DeploymentHandle.RETIRED
        self._invalidate_if_unused(cand)
        # never-promoted candidates don't join the rollback history, so
        # prune them from the version map (no unbounded accretion). Their
        # handle-owned executables are NOT released: an in-flight batch
        # that already chose the canary finishes compile-free, and once
        # the last reference drops the whole handle is garbage anyway.
        self._versions.get(cand.name, {}).pop(cand.version, None)

    def _swap(self, name: str, new: DeploymentHandle,
              prev: Optional[DeploymentHandle]) -> None:
        """Atomic publish: one dict store flips the live version."""
        new._canary = None
        new.state = DeploymentHandle.LIVE
        self.deployments[name] = new
        self.registry.set_active(name, new.version)
        if prev is not None:
            if prev._canary is not None and prev._canary[0] is not new:
                self._retire_canary(prev)     # displaced, never promoted
            prev._canary = None
            prev.state = DeploymentHandle.RETIRED
            hist = self._history.setdefault(name, [])
            hist.append(prev)
            self._invalidate_if_unused(prev)
            while len(hist) > self.max_retained_versions:
                # beyond the retention window a version is gone for good:
                # executables dropped AND the handle unpinnable, so a
                # redeploy-heavy engine doesn't accrete retired plans
                dropped = hist.pop(0)
                dropped.release()
                self._versions.get(name, {}).pop(dropped.version, None)

    def _invalidate_if_unused(self, retired: DeploymentHandle) -> None:
        """Drop a retired version's plan-cache entries unless a live or
        canary deployment shares the same plan fingerprint (a same-query
        redeploy must not nuke the entries it was just warmed from)."""
        fp = retired.phys.fingerprint()
        for h in self.deployments.values():
            if h.phys.fingerprint() == fp:
                return
            if h._canary is not None and \
                    h._canary[0].phys.fingerprint() == fp:
                return
        self.cache.invalidate(fp)

    def handle(self, name: str, version: Optional[int] = None
               ) -> DeploymentHandle:
        """The live handle for ``name``, or a specific pinned version."""
        if version is None:
            dep = self.deployments.get(name)
            if dep is None:
                raise KeyError(f"unknown deployment {name!r}; deployed: "
                               f"{sorted(self.deployments)}")
            return dep
        try:
            return self._versions[name][version]
        except KeyError:
            raise KeyError(
                f"deployment {name!r} has no version {version}; known: "
                f"{sorted(self._versions.get(name, {}))}") from None

    def promote(self, name: str) -> DeploymentHandle:
        """Make the canary version the live one (atomic swap)."""
        with self._deploy_lock:
            live = self.handle(name)
            if live._canary is None:
                raise ValueError(f"deployment {name!r} has no active canary")
            cand, _ = live._canary
            live._canary = None
            self._swap(name, cand, live)
            return cand

    def rollback(self, name: str) -> DeploymentHandle:
        """Undo: abort an active canary, or restore the previous version.

        Retired handles keep their compiled executables, so restoring one
        is swap-only — no recompile on the serving path (a handle whose
        executables were released under ``max_retained_versions`` is
        re-warmed here, off the hot path, before the swap)."""
        with self._deploy_lock:
            live = self.deployments.get(name)
            if live is not None and live._canary is not None:
                self._retire_canary(live)
                return live
            hist = self._history.get(name)
            if not hist:
                raise ValueError(
                    f"no prior version of {name!r} to roll back to")
            prev = hist.pop()
            if not prev._fns and self.cache.enabled:
                with prev._lock:       # pinned traffic may still add
                    buckets = sorted(prev.buckets_seen)
                prev.warm(buckets)
            self._swap(name, prev, live)
            return prev

    def explain(self, name: str) -> str:
        dep = self.handle(name)
        lines = [f"deployment {name!r} v{dep.version} [{dep.state}] "
                 f"on table {dep.table.schema.name!r}"]
        lines += [f"  plan: {dep.plan.fingerprint()[:160]}"]
        lines += [f"  opt : {l}" for l in dep.opt_log]
        if dep.plan.joins:
            lines.append(f"  join probe order: "
                         f"{' -> '.join(j.table for j in dep.plan.joins)}")
            stale = dep.join_staleness()
            for j, jt in zip(dep.plan.joins, dep.join_tables):
                kd = ("device-keydir" if jt.keydir.active
                      else "host-dict(fallback)")
                kept = j.columns or jt.schema.value_cols
                pruned = [c for c in jt.schema.value_cols if c not in kept]
                lines.append(
                    f"  join {j.table}: LAST JOIN on={j.on} "
                    f"order_by={j.order_by} cols={list(kept)} "
                    f"pruned={pruned} keydir={kd}")
                st = stale.get(j.table, {})
                if st.get("probes"):
                    lines.append(
                        f"  join {j.table} staleness: "
                        f"match_rate={st['match_rate']:.3f} "
                        f"age_p50={st['age_p50']:.3f} "
                        f"age_p99={st['age_p99']:.3f} "
                        f"({st['age_samples']} age samples)")
                else:
                    lines.append(
                        f"  join {j.table} staleness: no online traffic")
        for g in dep.phys.groups:
            lines.append(f"  window {g.name}: impl={g.impl} "
                         f"cols={g.plain_cols} fields={g.fields} "
                         f"aggs={len(g.slots)}")
        fused = [g.name for g in dep.phys.groups if g.impl == "fused"]
        if fused:
            lines.append(f"  fused scan: {len(fused)} window(s) in ONE "
                         f"launch ({', '.join(fused)})")
        elif not self.flags.fuse_windows:
            lines.append("  fused scan: disabled (fuse_windows=False)")
        lines.append(f"  kernel launches/batch: "
                     f"{dep.phys.n_kernel_launches}")
        return "\n".join(lines)

    def explain_analyze(self, target: str) -> str:
        """Measured-runtime EXPLAIN (DESIGN.md §13): render the operator
        profiler's accumulated attribution for a deployment. ``target``
        is a deployment name or a full ``EXPLAIN ANALYZE SELECT ...``
        statement — the SQL form is matched against the deployed
        queries (parse equality, not text equality)."""
        from repro.obs.profile import OperatorProfiler
        name = self._resolve_analyze_target(target)
        dep = self.handle(name)
        return OperatorProfiler.render(name, dep.version,
                                       self.profiler.snapshot(name))

    def _resolve_analyze_target(self, target: str) -> str:
        sql = dsl.strip_explain_analyze(target)
        if sql is None:
            return target                  # plain deployment name
        q = dsl.parse_sql(sql)
        for nm, dep in self.deployments.items():
            if dep.query == q:
                return nm
        raise KeyError(
            f"EXPLAIN ANALYZE: no live deployment serves this query "
            f"(deploy it first); deployed: {sorted(self.deployments)}")

    def drain_profile_observations(self, name: str) -> List[Dict]:
        """Measured-per-operator calibrator feed (control plane) — see
        ``OperatorProfiler.drain_observations``."""
        return self.profiler.drain_observations(name)

    def _predict_params(self, dep: DeploymentHandle):
        if dep.plan.predict is None:
            return None
        return self.model_params.get(dep.plan.predict.model)

    def _ensure_async_pool(self) -> cf.ThreadPoolExecutor:
        # dedicated lock: piggybacking on _deploy_lock would stall the
        # first request_async behind an in-flight deploy's build+warm
        if self._async_pool is None:
            with self._async_lock:
                if self._closed:
                    raise RuntimeError("engine is closed")
                if self._async_pool is None:
                    self._async_pool = cf.ThreadPoolExecutor(
                        2, thread_name_prefix="req-async")
        return self._async_pool

    # --------------------------------------------------------------- online
    def request(self, name: str, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None,
                ctx: Optional[RequestContext] = None) -> FeatureFrame:
        """Serve a batch of online feature requests (delegating shim).

        Kept for the string-keyed callers; the hot path lives on the
        handle. Honors ``ctx.version_pin`` like the server path does.
        The returned :class:`FeatureFrame` is dict-compatible."""
        pin = ctx.version_pin if ctx is not None else None
        return self.handle(name, pin).request(keys, ts, rows, ctx=ctx)

    def _request_batched(self, dep: DeploymentHandle, kidx, ts_arr, row_arr,
                         snap=None, record_bucket: bool = True,
                         join_snaps=None,
                         record_joins: bool = True) -> Dict[str, np.ndarray]:
        B = len(kidx)
        bucket = bucket_batch(B)
        fn = dep._compiled(bucket, record=record_bucket)
        # resolve join probe keys BEFORE padding (from the live B rows);
        # per-join snapshots default here so direct callers are covered,
        # while _serve/query_offline pass one consistent set per request
        jin = ()
        if dep.join_tables:
            if join_snaps is None:
                join_snaps = dep.join_snapshots()
            resolved = dep._resolve_join_keys(row_arr)
        pad = bucket - B
        if pad:
            # kidx may already live on device (keydir fast path)
            pad_fn = jnp.pad if isinstance(kidx, jax.Array) else np.pad
            kidx = pad_fn(kidx, (0, pad))
            ts_arr = np.pad(ts_arr, (0, pad))
            row_arr = np.pad(row_arr, ((0, pad), (0, 0)))
        put = dep.table.put
        if dep.join_tables:
            jlist = []
            for (jk, jf), jsnap in zip(resolved, join_snaps):
                if pad:
                    jk_pad = jnp.pad if isinstance(jk, jax.Array) else np.pad
                    jf_pad = jnp.pad if isinstance(jf, jax.Array) else np.pad
                    jk = jk_pad(jk, (0, pad))      # pad rows probe key 0,
                    jf = jf_pad(jf, (0, pad))      # masked found=False
                jlist.append((jsnap.state, put(jk), put(jf)))
            jin = tuple(jlist)
        # One snapshot for the whole batch: a concurrent stream flush must
        # not swap the table out from under an in-flight query. Callers
        # that span several batches (query_offline) pass their own.
        if snap is None:
            snap = dep.table.snapshot()
        t0 = time.perf_counter()
        out = fn(snap.state, snap.preagg, put(kidx),
                 put(ts_arr), put(row_arr),
                 self._predict_params(dep), jin)
        out = jax.block_until_ready(out)
        exec_dt = time.perf_counter() - t0
        self.stats.exec_s += exec_dt
        self.stats.n_requests += B
        self.stats.n_batches += 1
        self.stats.kernel_launches += dep.phys.n_kernel_launches
        res = {n: np.asarray(a)[:B] for n, a in out.items()}
        if dep.join_tables:
            dep._record_join_stats(res, B, record=record_joins)
        # hidden per-dispatch exec clock for the profiler/tracer —
        # callers that merge batches pop+sum it; _serve pops it before
        # the FeatureFrame is built
        res["__exec_s"] = exec_dt
        return res

    def _request_rowwise(self, dep: DeploymentHandle, kidx, ts_arr, row_arr,
                         snap=None, join_snaps=None) -> Dict[str, np.ndarray]:
        """Paper-faithful per-request execution (ablation: vectorized off)."""
        outs: List[Dict[str, np.ndarray]] = []
        for i in range(len(kidx)):
            outs.append(self._request_batched(
                dep, kidx[i:i + 1], ts_arr[i:i + 1], row_arr[i:i + 1],
                snap=snap, join_snaps=join_snaps))
        exec_s = sum(o.pop("__exec_s", 0.0) for o in outs)
        res = {n: np.concatenate([o[n] for o in outs]) for n in outs[0]}
        res["__exec_s"] = exec_s
        return res

    def _request_pooled(self, dep: DeploymentHandle, kidx, ts_arr, row_arr,
                        snap=None, join_snaps=None) -> Dict[str, np.ndarray]:
        """Worker-pool fan-out (paper O4 'parallel processing')."""
        W = self.flags.parallel_workers
        n = len(kidx)
        shard = max(1, (n + W - 1) // W)
        futs = []
        for s in range(0, n, shard):
            sl = slice(s, min(s + shard, n))
            if self.flags.vectorized:
                futs.append(self._pool.submit(
                    self._request_batched, dep, kidx[sl], ts_arr[sl],
                    row_arr[sl], snap=snap, join_snaps=join_snaps))
            else:
                futs.append(self._pool.submit(
                    self._request_rowwise, dep, kidx[sl], ts_arr[sl],
                    row_arr[sl], snap=snap, join_snaps=join_snaps))
        outs = [f.result() for f in futs]
        exec_s = sum(o.pop("__exec_s", 0.0) for o in outs)
        res = {nme: np.concatenate([o[nme] for o in outs])
               for nme in outs[0]}
        res["__exec_s"] = exec_s
        return res

    # -------------------------------------------------------------- offline
    def query_offline(self, name: str, *, batch_size: int = 1024,
                      point_in_time: bool = True
                      ) -> Dict[str, np.ndarray]:
        """Run the deployed query over EVERY retained event (training-set
        materialisation). Point-in-time: each event sees only history up to
        its own timestamp — exactly the online semantics, which is the
        training-serving-skew guarantee."""
        dep = self.handle(name)
        table = dep.table
        # one snapshot for BOTH enumeration and execution: concurrent
        # stream flushes must not shift the table between building the
        # (key, ts) list and computing its features (point-in-time
        # guarantee under live ingest). Joined tables are pinned the same
        # way — every offline row joins against ONE right-table version.
        offline_snap = table.snapshot()
        offline_jsnaps = dep.join_snapshots()
        st = offline_snap.state
        totals = np.asarray(st.total)
        C = table.capacity
        req_keys: List[int] = []
        req_slots: List[int] = []
        for k in range(table.n_keys):
            tot = int(totals[k])
            n = min(tot, C)
            for p in range(tot - n, tot):
                req_keys.append(k)
                req_slots.append(p % C)
        if not req_keys:
            return {n: np.zeros((0,), np.float32)
                    for n in dep.phys.feature_names}
        kidx = np.asarray(req_keys, np.int32)
        slots = np.asarray(req_slots, np.int32)
        ts_all = np.asarray(st.ts)[kidx, slots]
        rows_all = np.asarray(st.values)[kidx, slots]

        saved = self.flags
        if point_in_time and self.flags.assume_latest:
            # offline must not assume request-ts is newest
            self.flags = dataclasses.replace(self.flags, assume_latest=False)
        try:
            outs: List[Dict[str, np.ndarray]] = []
            for s in range(0, len(kidx), batch_size):
                sl = slice(s, s + batch_size)
                outs.append(self._request_batched(
                    dep, kidx[sl], ts_all[sl], rows_all[sl],
                    snap=offline_snap, record_bucket=False,
                    join_snaps=offline_jsnaps, record_joins=False))
        finally:
            self.flags = saved
        for o in outs:
            o.pop("__exec_s", None)
        res = {n: np.concatenate([o[n] for o in outs]) for n in outs[0]}
        res["__key"] = kidx
        res["__ts"] = ts_all
        return res

    # ---------------------------------------------------------------- stats
    def latency_decomposition(self) -> Dict[str, float]:
        s = self.stats
        out = {"parse_s": s.parse_s, "plan_s": s.plan_s, "exec_s": s.exec_s,
               "host_s": s.host_s, "serve_s": s.serve_s,
               "n_requests": s.n_requests,
               "kernel_launches": s.kernel_launches,
               "cache_hit_rate": self.cache.stats.hit_rate}
        # join staleness rollup across live deployments (ROADMAP: right-
        # table ring staleness metrics): total probes/matches + the worst
        # per-table age p99 currently observed
        probes = matches = 0
        worst_p99 = float("nan")
        ages = []
        for dep in self.deployments.values():
            for st in dep.join_staleness().values():
                probes += st["probes"]
                matches += st["matches"]
                if st["age_samples"]:
                    ages.append(st["age_p99"])
        if probes:
            out["join_probes"] = probes
            out["join_match_rate"] = matches / probes
            out["join_age_p99"] = max(ages) if ages else worst_p99
        return out

    # ------------------------------------------------------------ freshness
    def freshness_snapshot(self) -> Dict[str, Dict[str, object]]:
        """Per-table freshness snapshot: serving sketches from the
        tracker plus LIVE stamps read straight off each table's current
        snapshot (watermark, publish time, version) and its ingest-side
        distribution sketches. Picklable — this is what the proc worker
        ships over the ``freshness_snapshot`` RPC and what
        ``FreshnessTracker.merge`` folds across shards."""
        snap = self.freshness.snapshot()
        for name, t in self.tables.items():
            ent = snap.get(name)
            if ent is None:
                ent = snap[name] = dict(FreshnessTracker.blank_entry())
            ts = t.snapshot()
            ent["watermark"] = float(ts.watermark)
            ent["published_at"] = float(ts.published_at)
            ent["table_version"] = int(ts.version)
            ent.update(t.ingest_stats())
        return snap

    def freshness_export(self) -> Dict[str, object]:
        """Flat ``freshness`` metrics group for the registry."""
        return FreshnessTracker.export(self.freshness_snapshot())

    def drift_report(self) -> Dict[str, Dict[str, float]]:
        """Per-column live-vs-reference PSI scores."""
        return self.drift.report()

    def drift_export(self) -> Dict[str, float]:
        """Flat ``drift`` metrics group for the registry."""
        return self.drift.export()

    def pin_drift_reference(self) -> List[str]:
        """Adopt the current live serving distribution as the drift
        reference (e.g. at model-deploy time); returns pinned columns."""
        return self.drift.pin_reference()

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        """Idempotent shutdown: streams, worker pool, async pool."""
        with self._async_lock:     # a racing request_async must not
            if self._closed:       # create the pool after this point
                return
            self._closed = True
            if self._async_pool is not None:
                self._async_pool.shutdown(wait=False)
                self._async_pool = None
        for pipe in self.streams.values():
            pipe.close()
        self.streams.clear()
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def __enter__(self) -> "Engine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False
