"""Background ingestion: arrival is decoupled from table mutation.

``push`` stages an event into the ``StreamBuffer`` (host memory, O(log n))
and returns immediately — the serving hot path never waits on device
ingest. A flusher thread drains watermark-released events into the jitted
``ingest`` in amortized batches, using the **copy-on-write double buffer**:

    flush:   snapshot v ──ingest_nodonate──▶ buffers v+1 ──publish──▶ v+1
    queries:       read snapshot v  (stays valid: nothing donated it)

``Table.publish`` swaps the (state, preagg, version) triple atomically, so
an in-flight query that captured version ``v`` computes against one
consistent table no matter how many flushes land meanwhile — the paper's
"batch and stream processing without interference", made concrete.

Retention (TTL) piggybacks on the flusher: every ``every_n_flushes``
cycles the expired prefix is compacted out and the preagg tier rebuilt
(`streaming.retention`), published through the same atomic swap.
"""
from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass
from typing import Deque, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.featurestore.table import Table
from repro.streaming.buffer import StreamBuffer
from repro.streaming.retention import RetentionPolicy, apply_retention
from repro.streaming.wal import WalConfig, WriteAheadLog

__all__ = ["IngestPipeline", "PipelineConfig"]


@dataclass(frozen=True)
class PipelineConfig:
    lateness: float = 1.0            # reorder window, event-time units
    flush_interval_s: float = 0.002  # max staging delay before a flush
    max_flush_batch: int = 1024      # amortization cap per ingest call
    max_staged: int = 65536          # buffer bound (backpressure)
    retention: RetentionPolicy = RetentionPolicy(ttl=0.0)
    # auto-abort prepared-but-uncommitted 2PC transactions after this
    # long (0 disables): a dead coordinator must not pin watermarks
    prepare_ttl_s: float = 0.0
    # write-ahead log config (None disables): accepted events are logged
    # before they become flushable; replaying the log reproduces the
    # table bit-identically (streaming.wal, DESIGN.md §12)
    wal: Optional[WalConfig] = None


class IngestPipeline:
    """Owns a ``Table``'s write path; queries keep reading snapshots.

    Single-writer discipline: while a pipeline is attached, all mutation
    goes through it (``push``/``push_batch``); direct ``Table.insert``
    would race the flusher and donate buffers out from under readers.
    """

    def __init__(self, table: Table, cfg: PipelineConfig = PipelineConfig(),
                 freshness=None):
        self.table = table
        self.cfg = cfg
        # ingest-to-visible tracking (DESIGN.md §14): FIFO of
        # (arrival_wall, count) cohorts, popped per flush — events leave
        # the buffer in (roughly) arrival order, so matching flushed
        # counts against arrival cohorts is exact to within one flush
        # interval. ``freshness`` is a FreshnessTracker (or None).
        self.freshness = freshness
        self._arrivals: Deque[Tuple[float, int]] = collections.deque()
        self._arr_lock = threading.Lock()
        self.wal = WriteAheadLog(cfg.wal) if cfg.wal is not None else None
        self.buffer = StreamBuffer(lateness=cfg.lateness,
                                   max_staged=cfg.max_staged,
                                   prepare_ttl_s=cfg.prepare_ttl_s,
                                   wal=self.wal)
        # attaching to a non-empty table: events older than the already-
        # written history are unrepairable and must be rejected at push
        self.buffer.seed_frontier(table.last_ts_by_key())
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._idle = threading.Condition(self._lock)
        self._flush_mu = threading.Lock()   # single-flusher guarantee
        self._stop = False
        self._flushing = False
        self._event_clock = float("-inf")   # max event-time released
        self.stats: Dict[str, float] = {
            "flushes": 0, "events_flushed": 0, "flush_s": 0.0,
            "ttl_compactions": 0, "ttl_dropped": 0, "errors": 0}
        self.last_error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._flush_loop,
                                        daemon=True,
                                        name=f"ingest-{table.schema.name}")
        self._thread.start()

    # ------------------------------------------------------------------ push
    def push(self, key, ts: float, row: np.ndarray) -> bool:
        """Stage one event; never blocks on device work. Returns False iff
        the event was beyond the watermark (dropped, counted)."""
        ok = self.buffer.push(key, ts, row)
        if ok:
            self._note_arrival(1)
        with self._work:
            self._work.notify()
        return ok

    def push_batch(self, keys: Sequence, ts: Sequence[float],
                   rows: np.ndarray, *, all_or_nothing: bool = False) -> int:
        n = self.buffer.push_batch(keys, ts, rows,
                                   all_or_nothing=all_or_nothing)
        if n:
            self._note_arrival(n)
        with self._work:
            self._work.notify()
        return n

    # ------------------------------------------------------------------- 2PC
    def prepare(self, keys: Sequence, ts: Sequence[float],
                rows: np.ndarray) -> Optional[int]:
        """Phase 1 of a cross-shard transactional ingest: validate and
        park the batch (see ``StreamBuffer.prepare``). No flusher wakeup —
        nothing is staged yet."""
        return self.buffer.prepare(keys, ts, rows)

    def commit_txn(self, txn: int) -> int:
        """Phase 2: stage the parked batch (guaranteed to succeed unless
        the prepare TTL auto-aborted it) and wake the flusher. The WAL —
        when attached — gets the whole batch as ONE record at commit
        time, so replay-after-crash has 2PC atomicity for free."""
        events = self.buffer.commit(txn)
        if events:
            self._note_arrival(len(events))
        with self._work:
            self._work.notify()
        return len(events)

    def abort_txn(self, txn: int) -> None:
        self.buffer.abort(txn)

    # ------------------------------------------------------------- freshness
    def _note_arrival(self, count: int) -> None:
        if self.freshness is None:
            return
        with self._arr_lock:
            self._arrivals.append((time.time(), count))

    def _note_visible(self, n: int) -> None:
        """``n`` events just PUBLISHED: pop arrival cohorts covering them
        and record arrival→visible wall latency per cohort."""
        if self.freshness is None or n <= 0:
            return
        now = time.time()
        name = self.table.schema.name
        cohorts = []
        with self._arr_lock:
            while n > 0 and self._arrivals:
                t0, c = self._arrivals[0]
                take = min(c, n)
                cohorts.append((t0, take))
                n -= take
                if take == c:
                    self._arrivals.popleft()
                else:
                    self._arrivals[0] = (t0, c - take)
        for t0, c in cohorts:
            self.freshness.observe_ingest_visibility(
                name, max(now - t0, 0.0), count=c)

    # ----------------------------------------------------------------- flush
    def _flush_once(self, *, flush_all: bool = False) -> int:
        with self._flush_mu:
            return self._flush_once_locked(flush_all=flush_all)

    def _flush_once_locked(self, *, flush_all: bool) -> int:
        keys, ts, rows = self.buffer.ready(flush_all=flush_all)
        if not keys:
            return 0
        n = len(keys)
        t0 = time.perf_counter()
        step = self.cfg.max_flush_batch
        done = 0
        try:
            for s in range(0, n, step):
                self.table.insert(keys[s:s + step], ts[s:s + step],
                                  rows[s:s + step], donate=False)
                done = min(s + step, n)
        except ValueError as e:
            # data error (per-key order violated by out-of-band table
            # writes, bad shapes): retrying the chunk can never succeed —
            # eject it, restage only the chunks after it
            self.last_error = e
            self.stats["errors"] += 1
            skip = min(done + step, n)
            self.buffer.restage(keys[skip:], ts[skip:], rows[skip:],
                                frontier=self.table.last_ts_by_key())
            n = done
            if n == 0:
                return 0
        except BaseException as e:           # keep the flusher alive
            self.last_error = e
            self.stats["errors"] += 1
            # transient failure: the undelivered tail goes back to staging
            # (globally ts-sorted, so per-key order survives the retry),
            # and the frontier rolls back to what the table actually holds
            self.buffer.restage(keys[done:], ts[done:], rows[done:],
                                frontier=self.table.last_ts_by_key())
            n = done
            if n == 0:
                return 0
        self._event_clock = max(self._event_clock, float(ts[n - 1]))
        self._note_visible(n)
        self.stats["flushes"] += 1
        self.stats["events_flushed"] += n
        self.stats["flush_s"] += time.perf_counter() - t0
        ret = self.cfg.retention
        if (ret.enabled and ret.every_n_flushes > 0
                and self.stats["flushes"] % ret.every_n_flushes == 0):
            self._compact()
        return n

    def _compact(self) -> None:
        if self._event_clock == float("-inf"):
            return
        dropped = apply_retention(self.table, self.cfg.retention,
                                  now=self._event_clock)
        if dropped:
            self.stats["ttl_compactions"] += 1
            self.stats["ttl_dropped"] += dropped
        if self.wal is not None and self.cfg.retention.enabled:
            # segments whose newest event fell behind the TTL horizon
            # hold only rows a replay would immediately compact away
            self.wal.truncate(self._event_clock - self.cfg.retention.ttl)

    def _flush_loop(self) -> None:
        while True:
            with self._work:
                if self._stop:
                    return
                if not self.buffer.has_ready():
                    # nothing releasable (empty, or all staged events are
                    # still inside the reorder window): park instead of
                    # spinning ready() scans
                    self._work.wait(timeout=0.05)
                if self._stop:
                    return
                self._flushing = True
            try:
                self._flush_once()
            except BaseException as e:     # the daemon thread must never
                self.last_error = e        # die silently mid-stream
                self.stats["errors"] += 1
                time.sleep(0.05)           # don't spin on a hard error
            finally:
                with self._idle:
                    self._flushing = False
                    self._idle.notify_all()
            # amortization window: let pushes accumulate so each jitted
            # ingest dispatch carries a worthwhile batch
            if self.cfg.flush_interval_s > 0:
                time.sleep(self.cfg.flush_interval_s)

    def flush(self, *, flush_all: bool = True) -> None:
        """Synchronously drain everything staged (ignores watermarks when
        ``flush_all`` — end-of-stream / checkpoint barrier)."""
        self.wait_idle()
        with self._flush_mu:
            self._flush_once_locked(flush_all=flush_all)

    def wait_idle(self, timeout: float = 30.0) -> bool:
        """Block until nothing releasable remains in flight. Events still
        inside the reorder window stay staged (use ``flush`` to force)."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._idle:
                busy = self._flushing
            has_ready = False
            if not busy:
                has_ready = self.buffer.has_ready()
            if not busy and not has_ready:
                return True
            time.sleep(0.001)
        return False

    # ------------------------------------------------------------ lifecycle
    def warm(self) -> int:
        """Pre-compile every ingest shape bucket the flusher can hit, so
        no compilation lands inside the serving window. Call once after
        setup (benchmarks/servers); returns buckets compiled."""
        return self.table.warm_ingest(max_batch=self.cfg.max_flush_batch)

    @property
    def version(self) -> int:
        return self.table.version

    def metrics(self) -> Dict[str, float]:
        out = dict(self.stats)
        out.update(self.buffer.stats.snapshot())
        out["staged"] = self.buffer.n_staged
        out["table_version"] = self.table.version
        if self.wal is not None:
            out.update({f"wal_{k}": v
                        for k, v in self.wal.metrics().items()})
        return out

    def close(self, *, drain: bool = True) -> None:
        """Idempotent: a second close (e.g. context-manager exit after an
        explicit close, or Engine.close after FeatureServer teardown) is a
        no-op instead of re-draining a stopped pipeline."""
        with self._work:
            already = self._stop
            self._stop = True
            self._work.notify_all()
        self._thread.join(timeout=5.0)
        if drain and not already:
            self._flush_once(flush_all=True)
        if self.wal is not None and not already:
            self.wal.close()

    def __enter__(self) -> "IngestPipeline":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False
