"""Streaming ingestion subsystem: watermarked reorder buffering, background
flushing into the jitted ring-buffer ingest, TTL retention, and trace
replay — continuous ingest without serving interference (DESIGN.md §4)."""
from repro.streaming.buffer import StreamBuffer, StreamBufferStats
from repro.streaming.pipeline import IngestPipeline, PipelineConfig
from repro.streaming.retention import (RetentionPolicy, apply_retention,
                                       compact_expired)
from repro.streaming.source import StreamSource, online_offline_consistency

__all__ = ["StreamBuffer", "StreamBufferStats", "IngestPipeline",
           "PipelineConfig", "RetentionPolicy", "apply_retention",
           "compact_expired", "StreamSource", "online_offline_consistency"]
