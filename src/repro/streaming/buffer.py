"""Watermarked reorder buffer: out-of-order event repair before ingest.

The storage tier (``featurestore.table``) requires per-key non-decreasing
timestamps — the ring-buffer position IS the time order. Real streams are
not that polite: network skew and retries deliver events late and out of
order. OpenMLDB absorbs this in its memory table's skiplist; our dense
rings cannot, so we absorb it *before* the table instead, with standard
stream-processing watermark semantics (cf. Flink / Beam):

* every key tracks a high-water mark ``hwm[k]`` = max event-time seen;
* the key's **watermark** is ``hwm[k] - lateness`` — the stream's promise
  that no event older than this will be accepted anymore;
* staged events sit in a per-key buffer until the watermark passes them,
  getting **sorted on release** — any disorder inside the lateness window
  is repaired exactly (features identical to a sorted stream);
* events older than the already-released frontier are **dropped** and
  counted (they cannot be repaired once their neighborhood reached the
  ring buffer).

The buffer is a host-side structure (pure numpy + dicts); the device only
ever sees clean, sorted batches.
"""
from __future__ import annotations

import bisect
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["StreamBuffer", "StreamBufferStats"]


@dataclass
class StreamBufferStats:
    """Counters over the buffer's lifetime."""

    accepted: int = 0          # staged successfully
    released: int = 0          # handed to the table
    dropped_late: int = 0      # beyond-watermark, unrepairable
    reordered: int = 0         # arrived out of order but repaired
    max_staged: int = 0        # high-water mark of staged events
    txn_auto_aborted: int = 0  # prepares dropped by the prepare TTL

    def snapshot(self) -> Dict[str, int]:
        return dict(accepted=self.accepted, released=self.released,
                    dropped_late=self.dropped_late,
                    reordered=self.reordered, max_staged=self.max_staged,
                    txn_auto_aborted=self.txn_auto_aborted)


class StreamBuffer:
    """Bounded per-key reorder window with watermark release.

    ``lateness`` is the event-time width of the reorder window: an event
    may arrive up to ``lateness`` time units behind the newest event of
    its key and still be placed correctly. ``max_staged`` bounds memory —
    when exceeded, the oldest staged events are force-released (watermark
    advance by backpressure, as in any bounded-state stream processor).
    """

    def __init__(self, *, lateness: float = 1.0,
                 max_staged: int = 65536,
                 prepare_ttl_s: float = 0.0, wal=None):
        if lateness < 0:
            raise ValueError("lateness must be >= 0")
        self.lateness = float(lateness)
        self.max_staged = int(max_staged)
        self.stats = StreamBufferStats()
        self._lock = threading.Lock()
        # per key: sorted list of (ts, insertion_seq, row) — seq breaks ts
        # ties so equal-ts events keep arrival order (stable repair)
        self._staged: Dict[object, List[Tuple[float, int, np.ndarray]]] = {}
        self._hwm: Dict[object, float] = {}       # max ts seen per key
        self._frontier: Dict[object, float] = {}  # max ts released per key
        self._n_staged = 0
        self._seq = 0
        # prepared-but-uncommitted cross-shard transactions: txn id ->
        # [(key, ts, row), ...]. While a txn is pending, ready() holds
        # each involved key's frontier at/below the txn's min ts for that
        # key, so a prepared txn can ALWAYS commit (frontier can never
        # advance past it) — the invariant 2PC ingest rests on.
        self._pending: Dict[int, List[Tuple[object, float, np.ndarray]]] = {}
        self._txn_seq = 0
        # prepare TTL: a coordinator that dies between prepare and commit
        # would otherwise hold the involved keys' watermarks FOREVER.
        # prepare_ttl_s > 0 stamps each txn with a wall deadline; expired
        # prepares are auto-aborted (frontier holds released) before any
        # release/prepare/commit decision.
        self.prepare_ttl_s = float(prepare_ttl_s)
        self._txn_deadline: Dict[int, float] = {}
        self._expired_txns: set = set()
        # write-ahead log (streaming.wal.WriteAheadLog or None): accepted
        # events are appended UNDER this lock, before ready() could ever
        # release them — nothing reaches the table without being logged
        self.wal = wal

    # ------------------------------------------------------------------ push
    def push(self, key, ts: float, row: np.ndarray) -> bool:
        """Stage one event. Returns False iff dropped (beyond watermark)."""
        with self._lock:
            ok = self._push_locked(key, float(ts), row)
            if ok and self.wal is not None:
                self.wal.append([key], np.asarray([ts], np.float32),
                                np.asarray(row, np.float32)[None])
            return ok

    def push_batch(self, keys: Sequence, ts: Sequence[float],
                   rows: np.ndarray, *, all_or_nothing: bool = False) -> int:
        """Stage a batch; returns how many were accepted.

        ``all_or_nothing`` pre-checks every event against the frontier
        under the same lock and stages none if any would be dropped —
        the synchronous insert path's atomicity guarantee."""
        rows = np.asarray(rows, np.float32)
        n_ok = 0
        with self._lock:
            if all_or_nothing:
                for i, k in enumerate(keys):
                    t = float(ts[i])
                    if (not np.isfinite(t)
                            or t < self._frontier.get(k, float("-inf"))):
                        return 0
            acc: List[int] = []
            try:
                for i, k in enumerate(keys):
                    if self._push_locked(k, float(ts[i]), rows[i]):
                        acc.append(i)
                        n_ok += 1
            finally:
                # one WAL record for the whole accepted slice — logged
                # even if a later event raised (those staged are real)
                if acc and self.wal is not None:
                    self.wal.append([keys[i] for i in acc],
                                    np.asarray([float(ts[i]) for i in acc],
                                               np.float32),
                                    rows[np.asarray(acc)])
        return n_ok

    # ------------------------------------------------------ 2PC (prepare)
    def prepare(self, keys: Sequence, ts: Sequence[float],
                rows: np.ndarray) -> Optional[int]:
        """Phase 1 of a cross-shard transactional ingest: validate every
        event against the frontier and park the batch WITHOUT staging it.
        Returns a txn id, or ``None`` if any event would be dropped (the
        whole batch is then rejected and nothing is held).

        Between ``prepare`` and ``commit``/``abort``, ``ready()`` caps
        each involved key's release at the txn's minimum pending ts, so
        the frontier cannot move past the parked events — ``commit`` is
        guaranteed to stage every event successfully."""
        rows = np.asarray(rows, np.float32)
        with self._lock:
            self._expire_txns_locked()
            for i, k in enumerate(keys):
                t = float(ts[i])
                if (not np.isfinite(t)
                        or t < self._frontier.get(k, float("-inf"))):
                    return None
            self._txn_seq += 1
            txn = self._txn_seq
            self._pending[txn] = [
                (k, float(ts[i]), np.asarray(rows[i], np.float32))
                for i, k in enumerate(keys)]
            if self.prepare_ttl_s > 0:
                self._txn_deadline[txn] = (time.monotonic()
                                           + self.prepare_ttl_s)
            return txn

    def commit(self, txn: int) -> List[Tuple[object, float, np.ndarray]]:
        """Phase 2: stage the parked batch. Cannot reject (see
        ``prepare``) unless the prepare TTL already auto-aborted it;
        returns the staged events (the pipeline logs them to the WAL as
        ONE atomic record — a crash between prepare and commit replays
        as an abort)."""
        with self._lock:
            self._expire_txns_locked()
            if txn in self._expired_txns:
                raise ValueError(
                    f"txn {txn} was auto-aborted: its prepare exceeded "
                    f"the {self.prepare_ttl_s}s prepare TTL (coordinator "
                    f"presumed dead); nothing was staged")
            events = self._pending.pop(txn)
            self._txn_deadline.pop(txn, None)
            for k, t, row in events:
                if not self._push_locked(k, t, row):
                    # unreachable by construction (frontier held); guard
                    # so a future invariant break is loud, not silent
                    raise AssertionError(
                        f"prepared event (key={k!r}, ts={t}) rejected at "
                        f"commit — frontier hold violated")
            if events and self.wal is not None:
                self.wal.append(
                    [k for k, _t, _r in events],
                    np.asarray([t for _k, t, _r in events], np.float32),
                    np.stack([r for _k, _t, r in events]))
            return events

    def abort(self, txn: int) -> None:
        """Drop a prepared batch and release its frontier holds."""
        with self._lock:
            self._pending.pop(txn, None)
            self._txn_deadline.pop(txn, None)

    def _expire_txns_locked(self) -> None:
        """Auto-abort prepares older than the TTL — a dead coordinator
        must not hold key watermarks forever (callers hold the lock)."""
        if not self._txn_deadline:
            return
        now = time.monotonic()
        for txn in [t for t, dl in self._txn_deadline.items()
                    if now > dl]:
            self._pending.pop(txn, None)
            self._txn_deadline.pop(txn, None)
            self._expired_txns.add(txn)
            self.stats.txn_auto_aborted += 1
        if len(self._expired_txns) > 4096:   # bounded tombstone set
            self._expired_txns.clear()

    def _txn_holds(self) -> Dict[object, float]:
        """Per-key minimum pending-txn ts (callers hold the lock)."""
        self._expire_txns_locked()
        holds: Dict[object, float] = {}
        for events in self._pending.values():
            for k, t, _row in events:
                if t < holds.get(k, float("inf")):
                    holds[k] = t
        return holds

    def _push_locked(self, key, ts: float, row: np.ndarray) -> bool:
        if not np.isfinite(ts):
            # NaN/inf never compares its way into a sorted buffer; a
            # garbage timestamp is a caller bug, not a late event
            raise ValueError(f"non-finite event timestamp {ts!r} for key "
                             f"{key!r}")
        frontier = self._frontier.get(key, float("-inf"))
        if ts < frontier:
            # its position in the ring is already occupied by newer events
            self.stats.dropped_late += 1
            return False
        hwm = self._hwm.get(key, float("-inf"))
        staged = self._staged.setdefault(key, [])
        if staged and ts < staged[-1][0]:
            self.stats.reordered += 1            # repaired by sorted insert
        bisect.insort(staged, (ts, self._seq, np.asarray(row, np.float32)))
        self._seq += 1
        if ts > hwm:
            self._hwm[key] = ts
        self.stats.accepted += 1
        self._n_staged += 1
        self.stats.max_staged = max(self.stats.max_staged, self._n_staged)
        return True

    # --------------------------------------------------------------- release
    def watermark(self, key) -> float:
        """Event-time below which ``key``'s events are final."""
        return self._hwm.get(key, float("-inf")) - self.lateness

    @property
    def n_staged(self) -> int:
        return self._n_staged

    def seed_frontier(self, frontiers: Dict[object, float]) -> None:
        """Raise per-key frontiers (and high-water marks) to match history
        already written to the table — called when a pipeline attaches to
        a non-empty table, so an event older than pre-attach history is
        rejected at push time instead of poisoning the flusher."""
        with self._lock:
            for k, t in frontiers.items():
                if t > self._frontier.get(k, float("-inf")):
                    self._frontier[k] = t
                if t > self._hwm.get(k, float("-inf")):
                    self._hwm[k] = t

    def restage(self, keys: Sequence, ts: Sequence[float],
                rows: np.ndarray, *,
                frontier: Optional[Dict[object, float]] = None) -> None:
        """Return events popped by ``ready`` to the staging area (flush
        failure recovery). Bypasses the late-drop check: these events were
        already accepted and their table-side neighborhood was never
        written, so re-releasing them later preserves per-key order.

        ``frontier`` (the table's ``last_ts_by_key``) rolls the release
        frontier back to what was actually delivered — ``ready`` advanced
        it optimistically, and leaving it inflated would wrongly drop
        still-repairable events as late."""
        with self._lock:
            for i, k in enumerate(keys):
                staged = self._staged.setdefault(k, [])
                bisect.insort(staged, (float(ts[i]), self._seq,
                                       np.asarray(rows[i], np.float32)))
                self._seq += 1
                self._n_staged += 1
            self.stats.released -= len(keys)
            if frontier is not None:
                for k in set(keys):
                    self._frontier[k] = frontier.get(k, float("-inf"))

    def has_ready(self) -> bool:
        """True iff some staged event is already past its watermark."""
        with self._lock:
            return any(
                staged and staged[0][0] <= (self._hwm.get(k, float("-inf"))
                                            - self.lateness)
                for k, staged in self._staged.items())

    def ready(self, *, flush_all: bool = False
              ) -> Tuple[list, np.ndarray, np.ndarray]:
        """Pop every event at/below its key's watermark, repaired (sorted
        by event time per key) and globally ts-ordered. ``flush_all``
        ignores watermarks (shutdown / end-of-stream drain).

        Returns ``(keys, ts (N,) f32, rows (N, V) f32)``; empty when
        nothing is releasable.
        """
        out: List[Tuple[float, int, object, np.ndarray]] = []
        with self._lock:
            over = (self._n_staged - self.max_staged
                    if self.max_staged else 0)
            holds = self._txn_holds() if self._pending else {}
            for key, staged in self._staged.items():
                if not staged:
                    continue
                hold = holds.get(key)
                if flush_all:
                    n = len(staged)
                    if hold is not None:
                        # even a full drain must not advance the frontier
                        # past a prepared txn's events (ts == hold may
                        # release: commit pushes ts >= frontier)
                        n = bisect.bisect_right(staged,
                                                (hold, self._seq, None))
                else:
                    wm = self._hwm[key] - self.lateness
                    if hold is not None:
                        wm = min(wm, hold)
                    n = bisect.bisect_right(staged,
                                            (wm, self._seq, None))
                    if over > 0 and n < len(staged) and hold is None:
                        # bounded state: force the oldest through (held
                        # keys exempt — 2PC windows are short)
                        extra = min(len(staged) - n, over)
                        n += extra
                        over -= extra
                if n == 0:
                    continue
                for ts, seq, row in staged[:n]:
                    out.append((ts, seq, key, row))
                del staged[:n]
                self._frontier[key] = max(
                    self._frontier.get(key, float("-inf")), out[-1][0])
                self._n_staged -= n
                self.stats.released += n
        if not out:
            return [], np.zeros((0,), np.float32), np.zeros((0, 0),
                                                            np.float32)
        # global ts order keeps cross-key batches roughly time-coherent
        # (only per-key order is required by the ring buffer)
        out.sort(key=lambda e: (e[0], e[1]))
        keys = [e[2] for e in out]
        ts = np.asarray([e[0] for e in out], np.float32)
        rows = np.stack([e[3] for e in out]).astype(np.float32)
        return keys, ts, rows
