"""TTL/retention: event-time expiry for the ring-buffer + preagg tiers.

The ring buffer evicts *positionally* (capacity C keeps the newest C
events per key) — OpenMLDB's ``ttl_type=latest``. Its ``ttl_type=absolute``
(drop events older than a time horizon) has no positional analogue, so we
implement it as periodic **compaction**: rewrite each key's live events
with the expired prefix removed, reset the per-key totals, and rebuild the
bucketed pre-aggregate tier from the compacted raw state via
``rebuild_preagg`` (the non-hot-path recovery primitive — compaction *is*
a controlled recovery).

Compaction produces fresh buffers (it never mutates in place), so it
composes with the streaming double-buffer protocol: build compacted state
off to the side, then ``Table.publish`` it atomically.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.featurestore.preagg import rebuild_preagg
from repro.featurestore.table import (PreAggState, Table, TableState,
                                      empty_state)

__all__ = ["RetentionPolicy", "compact_expired", "apply_retention"]


@dataclass(frozen=True)
class RetentionPolicy:
    """``ttl`` is in event-time units (same clock as event timestamps).

    ``every_n_flushes`` throttles how often the pipeline pays the
    compaction rebuild; 0 disables time-based retention entirely.
    """

    ttl: float = 0.0
    every_n_flushes: int = 50

    @property
    def enabled(self) -> bool:
        return self.ttl > 0


def compact_expired(state: TableState, *, cutoff: float,
                    bucket_size: int = 0, with_preagg: bool = True
                    ) -> Tuple[TableState, Optional[PreAggState], int]:
    """Drop every event with ``ts < cutoff``; repack survivors at global
    positions ``[0, n_kept)`` per key. Returns ``(state, preagg | None,
    n_dropped)``. Host-side gather + device-side preagg rebuild.

    Per-key time order is preserved (survivors keep their relative
    positions), so the compacted state satisfies the same invariants as a
    freshly ingested one.
    """
    K, C, V = state.values.shape
    values = np.asarray(state.values)
    ts = np.asarray(state.ts)
    total = np.asarray(state.total)

    out = empty_state(K, C, V)
    new_values = np.asarray(out.values).copy()
    new_ts = np.asarray(out.ts).copy()
    new_total = np.zeros((K,), np.int32)
    n_dropped = 0
    for k in range(K):
        tot = int(total[k])
        if tot == 0:
            continue
        n_live = min(tot, C)
        pos = np.arange(tot - n_live, tot)
        slots = pos % C
        keep = ts[k, slots] >= cutoff
        kept = slots[keep]
        n_kept = int(kept.size)
        n_dropped += n_live - n_kept
        if n_kept:
            new_values[k, :n_kept] = values[k, kept]
            new_ts[k, :n_kept] = ts[k, kept]
        new_total[k] = n_kept

    import jax.numpy as jnp
    new_state = TableState(values=jnp.asarray(new_values),
                           ts=jnp.asarray(new_ts),
                           total=jnp.asarray(new_total))
    preagg = None
    if with_preagg and bucket_size > 0:
        preagg = rebuild_preagg(new_state, bucket_size=bucket_size)
    return new_state, preagg, n_dropped


def apply_retention(table: Table, policy: RetentionPolicy, *,
                    now: float) -> int:
    """Compact ``table`` in place (atomic publish); returns events dropped.

    ``now`` is the stream's **global** event-time clock — the pipeline
    passes the maximum released event time. Keys whose own timeline lags
    that clock lose events older than ``now - ttl`` like everyone else:
    absolute-TTL semantics (OpenMLDB ``ttl_type=absolute``), deliberately
    not per-key. Repair is unaffected — the reorder buffer's frontier,
    not table contents, decides late-event acceptance.
    """
    if not policy.enabled:
        return 0
    cutoff = now - policy.ttl
    snap = table.snapshot()
    new_state, new_preagg, n_dropped = compact_expired(
        snap.state, cutoff=cutoff, bucket_size=table.bucket_size,
        with_preagg=snap.preagg is not None)
    if n_dropped == 0:
        return 0
    table.publish(new_state, new_preagg)
    return n_dropped
