"""Write-ahead ingest log: per-shard durability for streamed events.

The process-backed shard runtime (DESIGN.md §11) loses a shard's
partitioned table data when its worker dies — until PR 8, recovery
meant "wait for some external actor to re-ingest". The WAL closes that
hole at the ingest boundary: every event the :class:`StreamBuffer`
ACCEPTS is appended here *under the buffer lock, before it becomes
flushable* — so no event can reach the table (and therefore a served
feature) without first being in the log, and replaying the log through
the same accept path reproduces the table bit-identically.

Log discipline (DESIGN.md §12):

* **Accepted events only.** Logging at arrival would replay events that
  the original run dropped as late (a fresh buffer has no frontier);
  logging post-acceptance makes replay = re-acceptance.
* **Segmented.** Records append to ``wal-<n>.seg``; at
  ``segment_bytes`` the segment is sealed (fsynced) and a new one
  opened. TTL compaction truncates whole sealed segments whose newest
  event-time fell behind the retention horizon.
* **Group commit.** Every record is written straight to the fd
  (unbuffered), so a SIGKILL'd worker loses nothing the OS already has;
  ``fsync`` is batched on ``fsync_interval_s`` for host-crash
  durability without one fsync per event (OpenMLDB's binlog does the
  same).
* **Torn tails tolerated.** Each record carries ``[u32 len][u32 crc]``;
  replay stops a segment at the first short read or CRC mismatch — a
  half-written tail record (killed mid-append) is dropped, never
  garbage-decoded.
* **2PC atomicity.** A prepared transaction is NOT logged at prepare
  time; ``commit`` appends the whole batch as ONE record. A crash
  between prepare and commit therefore replays as an abort —
  exactly the prepare-TTL semantics the live path has.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import threading
import time
import pickle
import zlib
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["WalConfig", "WriteAheadLog", "read_dir", "read_segment",
           "resolve_shard"]

_REC = struct.Struct(">II")          # record header: payload len, crc32
_PROTO = pickle.HIGHEST_PROTOCOL
_SEG_FMT = "wal-{:08d}.seg"


@dataclass(frozen=True)
class WalConfig:
    """``dir`` may contain a ``{shard}`` placeholder; the sharded engine
    (or :func:`resolve_shard`) substitutes the owning shard id before
    the log is opened, so one config template serves the whole fleet
    (and survives the DDL replay onto respawned / newly added shards)."""

    dir: str
    segment_bytes: int = 4 << 20      # rotate at ~4 MiB
    fsync_interval_s: float = 0.05    # group-commit window; 0 = every rec
    sync: bool = True                 # False: never fsync (bench/tests)


def resolve_shard(cfg, shard: int):
    """Substitute ``{shard}`` into a PipelineConfig-like ``cfg``'s WAL
    dir. Returns ``cfg`` unchanged when it has no WAL (or no
    placeholder)."""
    wal = getattr(cfg, "wal", None) if cfg is not None else None
    if wal is None or "{shard}" not in wal.dir:
        return cfg
    return dataclasses.replace(
        cfg, wal=dataclasses.replace(
            wal, dir=wal.dir.replace("{shard}", str(shard))))


def _read_records(path: str) -> Iterator[Tuple[list, np.ndarray,
                                               np.ndarray]]:
    """Yield ``(keys, ts, rows)`` records from one segment, stopping at
    the first torn/corrupt record (raises nothing — a damaged tail is
    expected after a kill)."""
    with open(path, "rb") as f:
        while True:
            hdr = f.read(_REC.size)
            if len(hdr) < _REC.size:
                return
            length, crc = _REC.unpack(hdr)
            payload = f.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                return                        # torn tail / corruption
            try:
                keys, ts, rows = pickle.loads(payload)
            except Exception:
                return
            yield (list(keys), np.asarray(ts, np.float32),
                   np.asarray(rows, np.float32))


def read_segment(path: str) -> List[Tuple[list, np.ndarray, np.ndarray]]:
    return list(_read_records(path))


def read_dir(path: str) -> Iterator[Tuple[list, np.ndarray, np.ndarray]]:
    """Replay every record of every segment under ``path`` in append
    order. Missing dir yields nothing (a shard that never ingested)."""
    if not os.path.isdir(path):
        return
    for name in sorted(os.listdir(path)):
        if not (name.startswith("wal-") and name.endswith(".seg")):
            continue
        yield from _read_records(os.path.join(path, name))


class WriteAheadLog:
    """Segmented, CRC-framed, fsync-batched append log of event batches.

    Thread-safe: appends may come from any pusher thread (they already
    hold the stream-buffer lock, but ``truncate`` arrives from the
    flusher thread concurrently)."""

    def __init__(self, cfg: WalConfig):
        if "{" in cfg.dir:
            raise ValueError(
                f"WAL dir {cfg.dir!r} has an unresolved placeholder — "
                f"call resolve_shard() (the sharded engine does this "
                f"per shard) before opening the log")
        self.cfg = cfg
        self._lock = threading.Lock()
        os.makedirs(cfg.dir, exist_ok=True)
        # resume an existing dir (tests / in-place restart): every
        # pre-existing segment is sealed; pick up numbering after it
        self._sealed: List[Tuple[str, float]] = []   # (path, max_ts)
        seg_ids = []
        for name in sorted(os.listdir(cfg.dir)):
            if name.startswith("wal-") and name.endswith(".seg"):
                seg_ids.append(int(name[4:-4]))
                p = os.path.join(cfg.dir, name)
                mx = float("-inf")
                for _k, ts, _r in _read_records(p):
                    if len(ts):
                        mx = max(mx, float(np.max(ts)))
                self._sealed.append((p, mx))
        self._seg_id = (max(seg_ids) + 1) if seg_ids else 0
        self._f = self._open_segment()
        self._seg_bytes = 0
        self._seg_max_ts = float("-inf")
        self._last_sync = time.monotonic()
        self._closed = False
        self.stats: Dict[str, float] = {
            "records": 0, "events": 0, "bytes": 0, "rotations": 0,
            "fsyncs": 0, "truncated_segments": 0}

    # ------------------------------------------------------------ segments
    def _open_segment(self):
        path = os.path.join(self.cfg.dir, _SEG_FMT.format(self._seg_id))
        # buffering=0: every record write is a syscall, so data survives
        # SIGKILL the instant append() returns (page cache); fsync below
        # extends that to host-crash durability on its batched cadence
        return open(path, "ab", buffering=0)

    def _rotate_locked(self) -> None:
        self._sync_locked(force=True)
        self._f.close()
        self._sealed.append((self._f.name, self._seg_max_ts))
        self._seg_id += 1
        self._f = self._open_segment()
        self._seg_bytes = 0
        self._seg_max_ts = float("-inf")
        self.stats["rotations"] += 1

    def _sync_locked(self, *, force: bool = False) -> None:
        if not self.cfg.sync:
            return
        now = time.monotonic()
        if force or self.cfg.fsync_interval_s <= 0 \
                or now - self._last_sync >= self.cfg.fsync_interval_s:
            os.fsync(self._f.fileno())
            self._last_sync = now
            self.stats["fsyncs"] += 1

    # -------------------------------------------------------------- append
    def append(self, keys: Sequence, ts, rows) -> None:
        """Durably log one accepted batch as a single atomic record."""
        if not len(keys):
            return
        ts = np.asarray(ts, np.float32)
        rows = np.asarray(rows, np.float32)
        payload = pickle.dumps((list(keys), ts, rows), protocol=_PROTO)
        rec = _REC.pack(len(payload), zlib.crc32(payload)) + payload
        with self._lock:
            if self._closed:
                return
            self._f.write(rec)
            self._seg_bytes += len(rec)
            if len(ts):
                self._seg_max_ts = max(self._seg_max_ts,
                                       float(np.max(ts)))
            self.stats["records"] += 1
            self.stats["events"] += len(keys)
            self.stats["bytes"] += len(rec)
            if self._seg_bytes >= self.cfg.segment_bytes:
                self._rotate_locked()
            else:
                self._sync_locked()

    def sync(self) -> None:
        with self._lock:
            if not self._closed:
                self._sync_locked(force=True)

    # ------------------------------------------------------------ truncate
    def truncate(self, min_ts: float) -> int:
        """Delete sealed segments whose NEWEST event-time is below
        ``min_ts`` (the TTL horizon): everything in them has been
        compacted out of the table, so replay would only re-insert rows
        retention immediately drops again. Returns segments removed."""
        removed = 0
        with self._lock:
            keep: List[Tuple[str, float]] = []
            for path, mx in self._sealed:
                if np.isfinite(mx) and mx < min_ts:
                    try:
                        os.remove(path)
                        removed += 1
                    except OSError:
                        keep.append((path, mx))
                else:
                    keep.append((path, mx))
            self._sealed = keep
            self.stats["truncated_segments"] += removed
        return removed

    # ----------------------------------------------------------- lifecycle
    def replay(self) -> Iterator[Tuple[list, np.ndarray, np.ndarray]]:
        """Replay this log's own dir (sealed + active segments)."""
        self.sync()
        return read_dir(self.cfg.dir)

    @property
    def n_segments(self) -> int:
        with self._lock:
            return len(self._sealed) + 1

    def metrics(self) -> Dict[str, float]:
        with self._lock:
            out = dict(self.stats)
            out["segments"] = len(self._sealed) + 1
            out["active_segment_bytes"] = self._seg_bytes
        return out

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            try:
                self._sync_locked(force=True)
            except (OSError, ValueError):
                pass
            self._f.close()
