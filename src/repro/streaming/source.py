"""Stream replay over synthetic traces: one trace, three delivery modes.

* ``backfill(table)``     — offline path: sorted bulk insert, the batch
  half of the paper's "one definition, two execution modes";
* ``replay(pipeline)``    — online path: events pushed through the
  watermark buffer + background flusher, optionally paced (events/sec)
  and optionally with bounded arrival disorder (``with_disorder``) to
  exercise out-of-order repair;
* ``batches()``           — raw chunks for custom drivers.

``online_offline_consistency`` closes the loop: after a replayed stream
lands, ``Engine.query_offline`` over the stored events must equal online
point-in-time requests at the same ``(key, ts)`` — the training-serving
skew guarantee must survive streaming delivery, not just clean bulk loads.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.data.synthetic import EventStreamConfig, generate_events

__all__ = ["StreamSource", "online_offline_consistency"]


@dataclass(frozen=True)
class StreamSource:
    """A finite keyed event trace in *arrival* order.

    ``ts`` is event time (what windows are computed over); the array order
    is arrival order — equal to ts order for a clean trace, deliberately
    not for a disordered one.
    """

    keys: np.ndarray   # (N,) arbitrary key dtype
    ts: np.ndarray     # (N,) f32 event time
    rows: np.ndarray   # (N, V) f32

    @classmethod
    def from_config(cls, cfg: EventStreamConfig) -> "StreamSource":
        keys, ts, rows = generate_events(cfg)
        return cls(keys=keys, ts=ts, rows=rows)

    def __len__(self) -> int:
        return len(self.keys)

    # ------------------------------------------------------------- variants
    def with_disorder(self, *, jitter: float, seed: int = 0
                      ) -> "StreamSource":
        """Bounded out-of-order delivery: arrival order becomes the sort
        of ``ts + U(0, jitter)`` while event times stay untouched. An
        event can thus arrive at most ``jitter`` event-time units late —
        repairable by a reorder window with ``lateness >= jitter``."""
        rng = np.random.default_rng(seed)
        arrival = self.ts + rng.uniform(0, jitter,
                                        len(self.ts)).astype(np.float32)
        order = np.argsort(arrival, kind="stable")
        return StreamSource(keys=self.keys[order], ts=self.ts[order],
                            rows=self.rows[order])

    def batches(self, batch_size: int = 256
                ) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        for s in range(0, len(self.keys), batch_size):
            sl = slice(s, s + batch_size)
            yield self.keys[sl], self.ts[sl], self.rows[sl]

    # ------------------------------------------------------------- delivery
    def backfill(self, table) -> None:
        """Offline bulk load: sort by event time and insert directly (no
        buffer, no flusher) — the batch-mode ingest baseline."""
        order = np.argsort(self.ts, kind="stable")
        table.insert(self.keys[order].tolist(), self.ts[order].tolist(),
                     self.rows[order])

    def replay(self, pipeline, *, batch_size: int = 256,
               rate: Optional[float] = None,
               stop_event=None) -> int:
        """Push the trace through an ``IngestPipeline`` in arrival order.

        ``rate`` paces delivery in events per wall-clock second (None =
        as fast as possible — saturation mode). Returns events accepted.
        Respects ``stop_event`` (threading.Event) for bench teardown.
        """
        accepted = 0
        t0 = time.perf_counter()
        sent = 0
        for keys, ts, rows in self.batches(batch_size):
            if stop_event is not None and stop_event.is_set():
                break
            if rate is not None:
                target = sent / rate
                lag = target - (time.perf_counter() - t0)
                if lag > 0:
                    time.sleep(lag)
            accepted += pipeline.push_batch(keys.tolist(), ts, rows)
            sent += len(keys)
        return accepted


def online_offline_consistency(engine, deployment: str, *,
                               atol: float = 1e-4, rtol: float = 1e-5
                               ) -> Tuple[bool, Dict[str, float]]:
    """Verify point-in-time equality of the two execution modes.

    Materialises every stored event offline, then re-requests the same
    ``(key, ts)`` pairs online and compares feature-by-feature. Returns
    ``(ok, {feature: max_abs_err})``.
    """
    import dataclasses as _dc

    dep = engine.deployments[deployment]
    off = engine.query_offline(deployment)
    kidx = np.asarray(off["__key"])
    if kidx.size == 0:
        return True, {}
    rev = {v: k for k, v in dep.table.key_to_idx.items()}
    req_keys = [rev[int(k)] for k in kidx]

    saved = engine.flags
    if engine.flags.assume_latest:
        # online must replay historical ts, not assume "now"
        engine.flags = _dc.replace(engine.flags, assume_latest=False)
    try:
        on = engine.request(deployment, req_keys, off["__ts"].tolist())
    finally:
        engine.flags = saved

    errs: Dict[str, float] = {}
    ok = True
    for name, vals in on.items():
        e = float(np.max(np.abs(np.asarray(vals)
                                - np.asarray(off[name]))))
        errs[name] = e
        if e > atol + rtol * float(np.max(np.abs(off[name]))):
            ok = False
    return ok, errs
