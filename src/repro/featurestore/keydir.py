"""Device-resident key→dense-index directory (open-addressing hash).

``Table.key_to_idx`` (a host dict) stays authoritative for arbitrary key
types; this directory mirrors integer keys into a device-side linear-
probing hash table so the serving hot path resolves a WHOLE request batch
with one jitted probe (hash → gather → compare) instead of a per-key
Python dict loop (``engine.DeploymentHandle._serve``). Unknown keys come
back as ``found=False`` and index 0 — exactly the engine's masking
contract for ``STATUS_UNKNOWN_KEY``.

Scope: keys must fit int32 (user/account ids do; the sentinel INT32_MIN
is reserved). The first non-integer or out-of-range key permanently
deactivates the directory (``active = False``) and the engine falls back
to the dict loop — correctness never depends on this mirror.

Hashing: multiplicative (Knuth) on the low 32 bits. Device int32
multiplication wraps mod 2^32 exactly like the host-side
``(k & 0xFFFFFFFF) * MULT`` computation, so host inserts and device
probes agree bit-for-bit on slot sequences. Since multiplication by an
odd constant is a bijection mod the (power-of-two) table size, dense id
spaces probe in one step almost always; ``max_probe`` tracks the true
worst case and is a static arg of the jitted probe.

Concurrency: inserts (ingest path) and lookups (serving path) may race.
Values are written before keys, so a concurrent snapshot never maps a
key to an uninitialised index; a lookup racing an insert may simply not
see the brand-new key yet (one stale-miss, masked as unknown — the same
visibility a caller gets by requesting before ingesting).
"""
from __future__ import annotations

import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["KeyDirectory"]

_EMPTY = -(2 ** 31)                 # int32 sentinel; rejected as a user key
_MULT = 2654435761                  # Knuth multiplicative constant (odd)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@functools.partial(jax.jit, static_argnames=("probe", "mask"))
def _probe(tkeys: jax.Array, tvals: jax.Array, q: jax.Array, *,
           probe: int, mask: int) -> Tuple[jax.Array, jax.Array]:
    # int32 multiply wraps; & mask keeps the (positive) low bits
    h = (q * jnp.int32(np.int64(_MULT).astype(np.int32))) & jnp.int32(mask)
    offs = jnp.arange(probe, dtype=jnp.int32)[None, :]
    slots = (h[:, None] + offs) & jnp.int32(mask)       # (B, P)
    cand = tkeys[slots]
    match = cand == q[:, None]
    found = jnp.any(match, axis=1)
    j = jnp.argmax(match, axis=1)
    vals = jnp.take_along_axis(tvals[slots], j[:, None], axis=1)[:, 0]
    return jnp.where(found, vals, 0).astype(jnp.int32), found


class KeyDirectory:
    def __init__(self, max_keys: int, device=None):
        # ``device``: optional jax device the mirror (and therefore every
        # probe) is pinned to. Sharded tables pin their directory to the
        # shard's device so probes never serialize through device 0's
        # execution stream (repro.shard). None = default placement.
        self.device = device
        self.slots = _next_pow2(max(2 * max_keys, 16))
        self._mask = self.slots - 1
        self._hkeys = np.full(self.slots, _EMPTY, np.int64)
        self._hvals = np.zeros(self.slots, np.int32)
        self.max_probe = 1
        self.n = 0
        self.active = True
        # device mirror is built once, then patched incrementally: inserts
        # queue their slot index and lookup applies them as one small
        # scatter — O(new keys), never an O(slots) re-upload per dirty.
        # _mu orders concurrent patch/build: without it a lookup could
        # observe an emptied queue but a not-yet-swapped mirror and serve
        # stale misses for long-since-ingested keys
        self._pending: list = []
        self._dev: Optional[Tuple[jax.Array, jax.Array]] = None
        self._mu = threading.Lock()

    def insert(self, key, idx: int) -> None:
        """Mirror one (key, dense index) pair; deactivate on unsupported
        keys. Idempotent for re-inserts of the same (key, idx)."""
        if not self.active:
            return
        if isinstance(key, bool) or not isinstance(key, (int, np.integer)):
            self.active = False
            return
        k = int(key)
        if not (_EMPTY < k < 2 ** 31):
            self.active = False
            return
        h = ((k & 0xFFFFFFFF) * _MULT) & self._mask
        # whole commit under _mu: an append racing lookup's queue swap
        # would otherwise land on the orphaned list and never be patched
        # into the device mirror (a permanently invisible key)
        with self._mu:
            for i in range(self.slots):
                s = (h + i) & self._mask
                existing = self._hkeys[s]
                if existing != _EMPTY and existing != k:
                    continue
                if existing == k and self._hvals[s] == idx:
                    return                # true re-insert: nothing changed
                self._hvals[s] = idx      # value first: commit point is
                self._hkeys[s] = k        # the key becoming visible
                if existing == _EMPTY:
                    self.n += 1
                if i + 1 > self.max_probe:
                    self.max_probe = i + 1
                self._pending.append(s)
                return
            self.active = False           # table full (max_keys overflow)

    def covers(self, keys: np.ndarray) -> bool:
        """True if ``keys`` (an integer ndarray) can be probed exactly:
        every queried value fits the directory's int32 key domain."""
        if not self.active or keys.size == 0:
            return False
        lo, hi = int(keys.min()), int(keys.max())
        return _EMPTY < lo and hi < 2 ** 31

    def lookup(self, keys: np.ndarray) -> Tuple[jax.Array, jax.Array]:
        """Resolve a batch: (idx (B,) i32, found (B,) bool), on device.

        Caller must have checked :meth:`covers`."""
        with self._mu:
            if self._dev is None:
                self._pending = []        # full build supersedes patches
                if self.device is not None:
                    self._dev = (
                        jax.device_put(self._hkeys.astype(np.int32),
                                       self.device),
                        jax.device_put(self._hvals, self.device))
                else:
                    self._dev = (jnp.asarray(self._hkeys.astype(np.int32)),
                                 jnp.asarray(self._hvals))
            elif self._pending:
                # swap the queue out under the lock: an insert racing this
                # patch lands in the fresh list for a later lookup, and no
                # concurrent lookup can observe emptied-queue + old mirror
                pend, self._pending = self._pending, []
                s = np.asarray(pend, np.int32)
                tkeys, tvals = self._dev
                self._dev = (
                    tkeys.at[s].set(jnp.asarray(
                        self._hkeys[s].astype(np.int32))),
                    tvals.at[s].set(jnp.asarray(self._hvals[s])))
            tkeys, tvals = self._dev
        # pad to a power-of-two shape bucket (mirrors the query path's
        # plan_cache.bucket_batch; local rounding avoids an import cycle
        # through repro.core) so the jitted probe compiles once per
        # bucket, not once per distinct batch size. The probe length is
        # bucketed too: max_probe ratchets up one collision at a time,
        # and an exact static value would recompile on every step
        # (probing extra empty slots is free of false matches).
        qh = np.asarray(keys, np.int64).astype(np.int32)
        B = qh.shape[0]
        bucket = _next_pow2(max(B, 8))
        if bucket > B:
            # pad rows probe like any key and are sliced off below (the
            # engine re-pads kidx to its batch bucket — one small slice +
            # pad kept deliberately, so _request_batched's length-derived
            # accounting stays uniform across serve strategies)
            qh = np.pad(qh, (0, bucket - B))
        q = (jax.device_put(qh, self.device) if self.device is not None
             else jnp.asarray(qh))
        probe = min(_next_pow2(self.max_probe), self.slots)
        idx, found = _probe(tkeys, tvals, q, probe=probe,
                            mask=self._mask)
        return idx[:B], found[:B]
