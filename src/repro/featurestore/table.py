"""In-memory time-series event storage: per-key ring buffers + pre-aggregates.

OpenMLDB stores events in a per-key skiplist ordered by timestamp. On TPU we
adapt that to **dense preallocated ring buffers** (DESIGN.md §2): a table is

    values : (K, C, V) float32   — V value columns for K keys, capacity C
    ts     : (K, C)    float32   — event timestamps (ingest order == ts order)
    total  : (K,)      int32     — monotone count of events ever ingested

Event ``p`` (the p-th event of a key, 0-based, over all time) lives at slot
``p % C``; retained events are ``p ∈ [max(0, total-C), total)``. This gives
O(1) append, free eviction, contiguous window scans, and a fixed shape that
`jit`/`shard_map` can carry.

Pre-aggregation (paper Eq. 2) is a second tier of **bucketed partial
aggregates**: bucket ``b`` covers positions ``[b·B, (b+1)·B)`` and is stored
at slot ``b % NB`` where ``NB = C // B``. A window ``[p0, p1)`` is then
`sum(full buckets) + head partial + tail partial`, turning O(W) scans into
O(W/B + 2B) — the TPU-native form of OpenMLDB's ``F(t) − F(t−W)``.

All state is a pytree; ingest is a jitted pure function. The host-side
``Table`` wrapper owns the key→index dict (hash lookups stay on CPU in
OpenMLDB too) and re-dispatches into the jitted kernels.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.featurestore.keydir import KeyDirectory
from repro.obs.sketch import CardinalityEstimator, QuantileSketch

__all__ = ["TableSchema", "TableState", "PreAggState", "Table",
           "TableSnapshot", "empty_state", "empty_preagg", "ingest",
           "ingest_nodonate", "NEG_INF", "POS_INF"]

NEG_INF = jnp.float32(-3.0e38)
POS_INF = jnp.float32(3.0e38)


@dataclass(frozen=True)
class TableSchema:
    name: str
    key_col: str
    ts_col: str
    value_cols: Tuple[str, ...]

    def col_index(self, col: str) -> int:
        try:
            return self.value_cols.index(col)
        except ValueError:
            raise KeyError(
                f"table {self.name!r} has no value column {col!r}; "
                f"columns: {list(self.value_cols)}") from None


@jax.tree_util.register_dataclass
@dataclass
class TableState:
    """Device-resident ring-buffer storage (pytree)."""

    values: jax.Array  # (K, C, V) f32
    ts: jax.Array      # (K, C) f32
    total: jax.Array   # (K,) i32

    @property
    def capacity(self) -> int:
        return self.ts.shape[1]

    @property
    def max_keys(self) -> int:
        return self.ts.shape[0]


@jax.tree_util.register_dataclass
@dataclass
class PreAggState:
    """Bucketed partial aggregates (pytree). ``NB = C // bucket_size``."""

    sum: jax.Array     # (K, NB, V) f32
    sumsq: jax.Array   # (K, NB, V) f32
    min: jax.Array     # (K, NB, V) f32
    max: jax.Array     # (K, NB, V) f32
    count: jax.Array   # (K, NB)    f32  (filtered count support)

    @property
    def n_buckets(self) -> int:
        return self.count.shape[1]


def empty_state(max_keys: int, capacity: int, n_cols: int) -> TableState:
    return TableState(
        values=jnp.zeros((max_keys, capacity, n_cols), jnp.float32),
        ts=jnp.full((max_keys, capacity), NEG_INF, jnp.float32),
        total=jnp.zeros((max_keys,), jnp.int32),
    )


def empty_preagg(max_keys: int, capacity: int, n_cols: int,
                 bucket_size: int) -> PreAggState:
    if capacity % bucket_size != 0:
        raise ValueError(f"capacity {capacity} must be a multiple of "
                         f"bucket_size {bucket_size}")
    nb = capacity // bucket_size
    return PreAggState(
        sum=jnp.zeros((max_keys, nb, n_cols), jnp.float32),
        sumsq=jnp.zeros((max_keys, nb, n_cols), jnp.float32),
        min=jnp.full((max_keys, nb, n_cols), POS_INF, jnp.float32),
        max=jnp.full((max_keys, nb, n_cols), NEG_INF, jnp.float32),
        count=jnp.zeros((max_keys, nb), jnp.float32),
    )


def _ingest_bucket(n: int, lo: int = 8) -> int:
    """Power-of-two shape bucket for ingest batches (mirrors the query
    path's ``plan_cache.bucket_batch``; local copy avoids an import cycle
    through ``repro.core``)."""
    b = lo
    while b < n:
        b <<= 1
    return b


def _batch_seq_numbers(key_idx: jax.Array) -> jax.Array:
    """seq[i] = #{j < i : key[j] == key[i]} — per-key arrival rank inside one
    ingest batch. O(B²) elementwise, fine for B ≤ a few thousand."""
    b = key_idx.shape[0]
    same = key_idx[:, None] == key_idx[None, :]
    lower = jnp.tril(jnp.ones((b, b), jnp.bool_), k=-1)
    return jnp.sum(same & lower, axis=1).astype(jnp.int32)


def _ingest_impl(state: TableState, preagg: Optional[PreAggState],
                 key_idx: jax.Array, ts: jax.Array, vals: jax.Array,
                 *, bucket_size: int = 0
                 ) -> Tuple[TableState, Optional[PreAggState]]:
    """Append a batch of events. ``key_idx (B,) i32``, ``ts (B,) f32``,
    ``vals (B, V) f32``. Events must arrive in non-decreasing ts order per
    key (streaming ingest). Batch size must be ≤ capacity.

    Maintains the raw ring buffer and (if ``preagg`` given) the bucketed
    pre-aggregate tier in one fused scatter pass.
    """
    C = state.capacity
    seq = _batch_seq_numbers(key_idx)
    pos = state.total[key_idx] + seq             # global position p, (B,)
    slot = pos % C

    values = state.values.at[key_idx, slot].set(vals)
    tsbuf = state.ts.at[key_idx, slot].set(ts)
    counts = jax.ops.segment_sum(
        jnp.ones_like(key_idx), key_idx, num_segments=state.max_keys)
    total = state.total + counts.astype(jnp.int32)
    new_state = TableState(values=values, ts=tsbuf, total=total)

    if preagg is None:
        return new_state, None

    B = bucket_size
    nb = preagg.n_buckets
    bslot = (pos // B) % nb
    is_bucket_start = (pos % B) == 0
    # Reset slots whose bucket (re)starts in this batch, then accumulate.
    # Non-start rows are redirected to an out-of-bounds key index; JAX
    # scatter updates DROP out-of-bounds writes, giving a masked scatter
    # with no duplicate-order hazards (two bucket-start rows can never
    # target the same slot within one ≤capacity batch).
    k_rst = jnp.where(is_bucket_start, key_idx,
                      jnp.int32(state.max_keys))
    sum_t = preagg.sum.at[k_rst, bslot].set(0.0)
    sumsq_t = preagg.sumsq.at[k_rst, bslot].set(0.0)
    min_t = preagg.min.at[k_rst, bslot].set(POS_INF)
    max_t = preagg.max.at[k_rst, bslot].set(NEG_INF)
    cnt_t = preagg.count.at[k_rst, bslot].set(0.0)

    sum_t = sum_t.at[key_idx, bslot].add(vals)
    sumsq_t = sumsq_t.at[key_idx, bslot].add(vals * vals)
    min_t = min_t.at[key_idx, bslot].min(vals)
    max_t = max_t.at[key_idx, bslot].max(vals)
    cnt_t = cnt_t.at[key_idx, bslot].add(1.0)
    new_preagg = PreAggState(sum=sum_t, sumsq=sumsq_t, min=min_t,
                             max=max_t, count=cnt_t)
    return new_state, new_preagg


# Hot-path variant: donates the old buffers for in-place reuse. Any
# previously taken snapshot of those buffers becomes invalid — use only
# when the table is not being read concurrently.
ingest = jax.jit(_ingest_impl, static_argnames=("bucket_size",),
                 donate_argnums=(0, 1))

# Copy-on-write variant: the input buffers stay alive, so snapshots taken
# before the call remain readable forever (streaming double-buffer path).
ingest_nodonate = jax.jit(_ingest_impl, static_argnames=("bucket_size",))


@dataclass(frozen=True)
class TableSnapshot:
    """An immutable, consistent (state, preagg) pair.

    ``version`` increments on every publish; a reader that captures a
    snapshot sees one table version for its whole computation regardless
    of concurrent flushes (jax arrays are immutable — only the reference
    swap needs to be atomic, which a single attribute read under the GIL
    provides).
    """

    state: TableState
    preagg: Optional[PreAggState]
    version: int
    # freshness stamps (DESIGN.md §14): the max event-time this state
    # covers, and the wall-clock instant it was swapped in. Default
    # values keep hand-built snapshots (tests, recovery) valid.
    watermark: float = float("-inf")
    published_at: float = 0.0


class Table:
    """Host-side table wrapper: schema + key dictionary + device state.

    The key→dense-index map is a host hash table (as in OpenMLDB, key lookup
    happens CPU-side); all window math runs on device over dense indices.
    """

    def __init__(self, schema: TableSchema, *, max_keys: int = 1024,
                 capacity: int = 1024, bucket_size: int = 64,
                 enable_preagg: bool = True, device=None):
        if capacity % bucket_size != 0:
            raise ValueError("capacity must be a multiple of bucket_size")
        self.schema = schema
        self.max_keys = max_keys
        self.capacity = capacity
        self.bucket_size = bucket_size
        # optional jax device this table's state (and every ingest/query
        # input buffer) is pinned to. The sharded runtime (repro.shard)
        # places one shard per device so shard executions ride separate
        # device streams; None keeps jax's default placement (unchanged
        # single-engine behavior).
        self.device = device
        self.key_to_idx: Dict[object, int] = {}
        # device-side mirror of the key dict for batched hot-path lookup
        # (engine._serve); deactivates itself on non-int32 keys
        self.keydir = KeyDirectory(max_keys, device=device)
        self._pub_lock = threading.Lock()
        state = empty_state(max_keys, capacity, len(schema.value_cols))
        preagg = (empty_preagg(max_keys, capacity,
                               len(schema.value_cols), bucket_size)
                  if enable_preagg else None)
        if device is not None:
            state = jax.device_put(state, device)
            preagg = (jax.device_put(preagg, device)
                      if preagg is not None else None)
        self._published = TableSnapshot(state=state, preagg=preagg,
                                        version=0)
        self._last_ts: Dict[int, float] = {}
        # freshness/drift instrumentation (DESIGN.md §14): event-time
        # write frontier plus ingest-side distribution sketches — one
        # quantile sketch per value column and a KMV distinct-key
        # estimator, updated incrementally (vectorized) per insert.
        self._watermark = float("-inf")
        self._col_sketches: Dict[str, QuantileSketch] = {
            c: QuantileSketch() for c in schema.value_cols}
        self._key_card = CardinalityEstimator()

    def put(self, x):
        """Place a host array per this table's device policy: committed to
        ``self.device`` when pinned, default (uncommitted) placement
        otherwise. Every ingest/serve input buffer goes through this seam
        so a sharded table's uploads target its own device stream."""
        if self.device is not None:
            return jax.device_put(x, self.device)
        return jnp.asarray(x)

    # -- versioned state ---------------------------------------------------
    @property
    def state(self) -> TableState:
        return self._published.state

    @state.setter
    def state(self, s: TableState) -> None:
        with self._pub_lock:
            p = self._published
            self._published = TableSnapshot(
                s, p.preagg, p.version + 1,
                watermark=self._watermark, published_at=time.time())

    @property
    def preagg(self) -> Optional[PreAggState]:
        return self._published.preagg

    @preagg.setter
    def preagg(self, pa: Optional[PreAggState]) -> None:
        with self._pub_lock:
            p = self._published
            self._published = TableSnapshot(
                p.state, pa, p.version + 1,
                watermark=self._watermark, published_at=time.time())

    @property
    def version(self) -> int:
        return self._published.version

    def snapshot(self) -> TableSnapshot:
        """Consistent (state, preagg, version) triple for one reader."""
        return self._published

    def publish(self, state: TableState,
                preagg: Optional[PreAggState]) -> TableSnapshot:
        """Atomically swap both tiers in (one version bump). The new
        snapshot carries the current write frontier as its freshness
        watermark plus the publish wall-time."""
        with self._pub_lock:
            snap = TableSnapshot(state, preagg,
                                 self._published.version + 1,
                                 watermark=self._watermark,
                                 published_at=time.time())
            self._published = snap
        return snap

    @property
    def watermark(self) -> float:
        """Max event-time ever ingested (``-inf`` while empty)."""
        return self._watermark

    # -- key management ----------------------------------------------------
    def key_index(self, key, create: bool = False) -> int:
        idx = self.key_to_idx.get(key)
        if idx is None:
            if not create:
                raise KeyError(f"unknown key {key!r} in table "
                               f"{self.schema.name!r}")
            idx = len(self.key_to_idx)
            if idx >= self.max_keys:
                raise RuntimeError(
                    f"table {self.schema.name!r} key space exhausted "
                    f"({self.max_keys}); resize via Table(max_keys=...)")
            self.key_to_idx[key] = idx
            self.keydir.insert(key, idx)
        return idx

    def key_indices(self, keys: Sequence, create: bool = False) -> np.ndarray:
        return np.asarray([self.key_index(k, create) for k in keys],
                          dtype=np.int32)

    @property
    def n_keys(self) -> int:
        return len(self.key_to_idx)

    def last_ts_by_key(self) -> Dict[object, float]:
        """Per-key newest ingested timestamp (the authoritative write
        frontier — streaming buffers seed/reset their frontiers from it)."""
        return {k: self._last_ts.get(i, float("-inf"))
                for k, i in self.key_to_idx.items()}

    # -- ingest ------------------------------------------------------------
    def insert(self, keys: Sequence, ts: Sequence[float],
               rows: np.ndarray, *, donate: bool = True,
               pad_to_bucket: bool = True) -> None:
        """Append events. ``rows`` is (B, V) in schema column order. Events
        must be in non-decreasing ts order per key.

        ``donate=True`` (default) reuses the old device buffers — fastest,
        but invalidates outstanding snapshots. The streaming flusher calls
        with ``donate=False`` so concurrent readers keep a live snapshot
        (copy-on-write double buffering).

        ``pad_to_bucket`` rounds the batch up to a power-of-two shape
        bucket; pad rows carry the out-of-bounds key index ``max_keys``,
        which every scatter (and the segment-sum) silently drops — so the
        jitted ingest compiles once per bucket instead of once per batch
        size (streaming flushes have arbitrary sizes)."""
        keys = list(keys)
        ts_arr = np.asarray(ts, np.float32)
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != len(self.schema.value_cols):
            raise ValueError(
                f"rows must be (B, {len(self.schema.value_cols)}), got "
                f"{rows.shape}")
        if len(keys) != len(ts_arr) or len(keys) != rows.shape[0]:
            raise ValueError("keys/ts/rows length mismatch")
        if rows.shape[0] > self.capacity:
            # Keep per-batch position spans below capacity (ring safety).
            for s in range(0, rows.shape[0], self.capacity):
                self.insert(keys[s:s + self.capacity],
                            ts_arr[s:s + self.capacity],
                            rows[s:s + self.capacity], donate=donate,
                            pad_to_bucket=pad_to_bucket)
            return
        kidx = self.key_indices(keys, create=True)
        # validate first, commit _last_ts only after the device call
        # succeeds — last_ts_by_key() must reflect delivered data only
        pending: Dict[int, float] = {}
        for i, k in enumerate(kidx):
            ki = int(k)
            last = pending.get(ki, self._last_ts.get(ki, float("-inf")))
            t = float(ts_arr[i])
            if t < last:
                raise ValueError(
                    f"out-of-order ingest for key index {ki}: "
                    f"{t} < {last} (streaming tables require per-key "
                    f"non-decreasing timestamps)")
            pending[ki] = t
        B = rows.shape[0]
        # capture pre-padding views: freshness/drift stats must see the
        # REAL batch only (pad rows are shape filler)
        raw_ts, raw_rows, raw_keys = ts_arr, rows, keys
        if pad_to_bucket:
            bucket = min(_ingest_bucket(B), self.capacity)
            if bucket > B:
                pad = bucket - B
                # OOB key index: dropped by scatters and the segment sum
                kidx = np.pad(kidx, (0, pad),
                              constant_values=self.max_keys)
                ts_arr = np.pad(ts_arr, (0, pad))
                rows = np.pad(rows, ((0, pad), (0, 0)))
        fn = ingest if donate else ingest_nodonate
        snap = self.snapshot()
        new_state, new_preagg = fn(
            snap.state, snap.preagg, self.put(kidx),
            self.put(ts_arr), self.put(rows),
            bucket_size=self.bucket_size)
        # advance the frontier before publish so the new snapshot's
        # watermark covers this batch; stats commit only on success
        # (same contract as _last_ts)
        if B:
            self._watermark = max(self._watermark,
                                  float(raw_ts[:B].max()))
        self.publish(new_state, new_preagg)
        self._last_ts.update(pending)
        if B:
            self._key_card.add_many(raw_keys)
            for j, col in enumerate(self.schema.value_cols):
                self._col_sketches[col].observe_many(raw_rows[:B, j])

    def warm_ingest(self, *, max_batch: Optional[int] = None) -> int:
        """Pre-compile the (non-donating) ingest for every shape bucket up
        to ``max_batch`` (default: capacity), so streaming flushes of
        arbitrary size hit only cached executables. The warm batches carry
        all-out-of-bounds key indices — a no-op ingest that never touches
        stored data. Returns the number of buckets compiled."""
        snap = self.snapshot()
        V = len(self.schema.value_cols)
        mx = min(max_batch or self.capacity, self.capacity)
        # exactly the shapes insert pads to: pow-2 buckets clamped at
        # capacity (which need not itself be a power of two)
        sizes = []
        b = 8
        while True:
            s = min(b, self.capacity)
            sizes.append(s)
            if s >= mx:
                break
            b <<= 1
        for s in sizes:
            k = self.put(np.full((s,), self.max_keys, np.int32))
            out = ingest_nodonate(snap.state, snap.preagg, k,
                                  self.put(np.zeros((s,), np.float32)),
                                  self.put(np.zeros((s, V), np.float32)),
                                  bucket_size=self.bucket_size)
            jax.block_until_ready(jax.tree_util.tree_leaves(out[0]))
        return len(sizes)

    # -- introspection -----------------------------------------------------
    def ingest_stats(self) -> Dict[str, Any]:
        """Picklable ingest-side distribution snapshot: per-value-column
        quantile sketches plus the distinct-key estimate. Ships over the
        ``freshness_snapshot`` RPC and merges exactly across shards."""
        return {
            "key_card": self._key_card.to_dict(),
            "columns": {c: sk.to_dict()
                        for c, sk in self._col_sketches.items()
                        if sk.count},
        }

    def column_indices(self, cols: Sequence[str]) -> Tuple[int, ...]:
        return tuple(self.schema.col_index(c) for c in cols)

    def memory_bytes(self) -> int:
        n = sum(int(np.prod(x.shape)) * 4
                for x in jax.tree_util.tree_leaves(self.state))
        if self.preagg is not None:
            n += sum(int(np.prod(x.shape)) * 4
                     for x in jax.tree_util.tree_leaves(self.preagg))
        return n
