"""Feature registry: one SQL definition, consistent offline + online use.

The registry is the paper's §3.3 "bridging online and offline pipelines":
a :class:`FeatureSet` couples a table schema with a feature query. The SAME
optimized plan is executed by the offline batch path (training data) and the
online request path (serving), which is what eliminates training–serving
skew. ``tests/test_consistency.py`` asserts bit-equality between the two.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Optional

from repro.featurestore.table import TableSchema

if TYPE_CHECKING:  # avoid featurestore <-> core import cycle
    from repro.core.logical import Query

__all__ = ["FeatureSet", "FeatureRegistry"]


@dataclass
class FeatureSet:
    name: str
    query: "Query"
    version: int = 1
    description: str = ""

    @property
    def table(self) -> str:
        return self.query.table


@dataclass
class FeatureRegistry:
    """Named feature sets + table schemas (the 'feature store' catalogue)."""

    schemas: Dict[str, TableSchema] = field(default_factory=dict)
    feature_sets: Dict[str, FeatureSet] = field(default_factory=dict)

    def register_schema(self, schema: TableSchema) -> None:
        if schema.name in self.schemas:
            raise ValueError(f"schema {schema.name!r} already registered")
        self.schemas[schema.name] = schema

    def register(self, fs: FeatureSet) -> None:
        if fs.table not in self.schemas:
            raise ValueError(
                f"feature set {fs.name!r} references unknown table "
                f"{fs.table!r}; register its schema first")
        prev = self.feature_sets.get(fs.name)
        if prev is not None and prev.version >= fs.version:
            raise ValueError(
                f"feature set {fs.name!r} v{fs.version} does not supersede "
                f"registered v{prev.version}")
        self.feature_sets[fs.name] = fs

    def get(self, name: str) -> FeatureSet:
        try:
            return self.feature_sets[name]
        except KeyError:
            raise KeyError(f"unknown feature set {name!r}; registered: "
                           f"{sorted(self.feature_sets)}") from None
