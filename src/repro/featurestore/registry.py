"""Feature registry: one SQL definition, consistent offline + online use.

The registry is the paper's §3.3 "bridging online and offline pipelines":
a :class:`FeatureSet` couples a table schema with a feature query. The SAME
optimized plan is executed by the offline batch path (training data) and the
online request path (serving), which is what eliminates training–serving
skew. ``tests/test_consistency.py`` asserts bit-equality between the two.

Feature sets are **versioned**: every redeploy of a name registers the next
version, all versions stay addressable (``get(name, version=...)``), and the
``active`` pointer tracks which version the engine is currently serving —
it moves on hot-swap, promote, and rollback (DESIGN.md §6).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Optional

from repro.featurestore.table import TableSchema

if TYPE_CHECKING:  # avoid featurestore <-> core import cycle
    from repro.core.logical import Query

__all__ = ["FeatureSet", "FeatureRegistry"]


@dataclass
class FeatureSet:
    name: str
    query: "Query"
    version: int = 1
    description: str = ""

    @property
    def table(self) -> str:
        return self.query.table


@dataclass
class FeatureRegistry:
    """Named feature sets + table schemas (the 'feature store' catalogue)."""

    schemas: Dict[str, TableSchema] = field(default_factory=dict)
    feature_sets: Dict[str, FeatureSet] = field(default_factory=dict)
    # name -> version -> FeatureSet (full history; feature_sets keeps the
    # latest registered for backwards compatibility)
    versions: Dict[str, Dict[int, FeatureSet]] = field(default_factory=dict)
    # name -> the version currently serving (set by the engine on swap)
    active: Dict[str, int] = field(default_factory=dict)

    def register_schema(self, schema: TableSchema) -> None:
        if schema.name in self.schemas:
            raise ValueError(f"schema {schema.name!r} already registered")
        self.schemas[schema.name] = schema

    def register(self, fs: FeatureSet) -> None:
        if fs.table not in self.schemas:
            raise ValueError(
                f"feature set {fs.name!r} references unknown table "
                f"{fs.table!r}; register its schema first")
        prev = self.feature_sets.get(fs.name)
        if prev is not None and prev.version >= fs.version:
            raise ValueError(
                f"feature set {fs.name!r} v{fs.version} does not supersede "
                f"registered v{prev.version}")
        self.feature_sets[fs.name] = fs
        self.versions.setdefault(fs.name, {})[fs.version] = fs

    def set_active(self, name: str, version: int) -> None:
        """Point ``name`` at the serving version (swap/promote/rollback)."""
        if version not in self.versions.get(name, {}):
            raise KeyError(f"feature set {name!r} has no version {version}; "
                           f"known: {sorted(self.versions.get(name, {}))}")
        self.active[name] = version

    def latest_version(self, name: str) -> int:
        vs = self.versions.get(name)
        if not vs:
            raise KeyError(f"unknown feature set {name!r}; registered: "
                           f"{sorted(self.feature_sets)}")
        return max(vs)

    def get(self, name: str, version: Optional[int] = None) -> FeatureSet:
        """The active version by default; any version by number."""
        if version is not None:
            try:
                return self.versions[name][version]
            except KeyError:
                raise KeyError(
                    f"feature set {name!r} has no version {version}; "
                    f"known: {sorted(self.versions.get(name, {}))}") from None
        if name in self.active:
            return self.versions[name][self.active[name]]
        try:
            return self.feature_sets[name]
        except KeyError:
            raise KeyError(f"unknown feature set {name!r}; registered: "
                           f"{sorted(self.feature_sets)}") from None
