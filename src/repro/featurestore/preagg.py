"""Pre-aggregate tier maintenance + verification (paper Eq. 2).

The *incremental* maintenance lives in ``featurestore.table.ingest`` (one
fused scatter pass with the raw ring-buffer update). This module holds the
non-hot-path companions:

* ``rebuild_preagg``   — recompute the bucketed tier from raw state
  (checkpoint restore validation, corruption recovery);
* ``verify_preagg``    — invariant check: every bucket equals the fold of
  its covered raw slots (property tests + post-restore audit);
* ``preagg_memory_overhead`` — the paper's materialization cost metric.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.featurestore.table import (NEG_INF, POS_INF, PreAggState,
                                      TableState, empty_preagg)

__all__ = ["rebuild_preagg", "verify_preagg", "preagg_memory_overhead"]


@functools.partial(jax.jit, static_argnames=("bucket_size",))
def rebuild_preagg(state: TableState, *, bucket_size: int) -> PreAggState:
    """Recompute the bucketed tier from the raw ring buffers.

    Slot ``c`` of key ``k`` holds the event at global position
    ``p`` where ``p % C == c`` and ``p ∈ [total-min(total,C), total)``.
    Bucket slot ``b`` covers raw slots ``[b*B, (b+1)*B)`` *of the ring*;
    because C % B == 0, ring slots of one bucket always belong to the same
    global bucket index — so a bucket is valid iff all its covered live
    positions share that bucket.
    """
    K, C, V = state.values.shape
    B = bucket_size
    nb = C // B
    total = state.total                                    # (K,)
    # global position stored at ring slot c (for key k):
    # p = total-1 - ((cur-1 - c) % C)  where cur = total % C
    c_idx = jnp.arange(C, dtype=jnp.int32)[None, :]        # (1, C)
    cur = (total % C)[:, None]                             # (K, 1)
    back = (cur - 1 - c_idx) % C
    p = total[:, None] - 1 - back                          # (K, C) global pos
    live = (p >= jnp.maximum(total[:, None] - C, 0)) & (p < total[:, None])

    vals = state.values                                    # (K, C, V)
    w = live[..., None].astype(jnp.float32)
    grp = vals.reshape(K, nb, B, V)
    wg = w.reshape(K, nb, B, 1)
    psum = jnp.sum(grp * wg, axis=2)
    psumsq = jnp.sum(grp * grp * wg, axis=2)
    pmin = jnp.min(jnp.where(wg > 0, grp, POS_INF), axis=2)
    pmax = jnp.max(jnp.where(wg > 0, grp, NEG_INF), axis=2)
    pcnt = jnp.sum(wg[..., 0], axis=2)
    return PreAggState(sum=psum, sumsq=psumsq, min=pmin, max=pmax, count=pcnt)


def verify_preagg(state: TableState, preagg: PreAggState, *,
                  bucket_size: int, atol: float = 1e-3) -> Tuple[bool, float]:
    """Check the live portion of the incremental tier against a rebuild.

    Only buckets that are *fully live* (all B covered positions retained
    and in the same global bucket) are comparable — partially-overwritten
    buckets are never read by the query path either (the kernel fetches
    raw tails for them). Returns (ok, max_abs_err over compared entries).
    """
    K, C, V = state.values.shape
    B = bucket_size
    nb = C // B
    ref = rebuild_preagg(state, bucket_size=bucket_size)
    total = np.asarray(state.total)                       # (K,)
    errs = [0.0]
    ok = True
    got_sum = np.asarray(preagg.sum)
    ref_sum = np.asarray(ref.sum)
    got_cnt = np.asarray(preagg.count)
    ref_cnt = np.asarray(ref.count)
    for k in range(K):
        tot = int(total[k])
        if tot == 0:
            continue
        first_live = max(tot - C, 0)
        for b in range(nb):
            # bucket slot b currently holds global bucket index g where
            # g % nb == b; the *live* one is the largest such g < ceil(tot/B)
            hi_bucket = (tot - 1) // B
            g = hi_bucket - ((hi_bucket - b) % nb)
            if g < 0:
                continue
            start, end = g * B, (g + 1) * B
            if start < first_live:
                continue                                   # partially evicted
            if end > tot:
                continue                                   # still filling
            e = float(np.max(np.abs(got_sum[k, b] - ref_sum[k, b])))
            e = max(e, float(abs(got_cnt[k, b] - ref_cnt[k, b])))
            errs.append(e)
            if e > atol:
                ok = False
    return ok, max(errs)


def preagg_memory_overhead(state: TableState,
                           preagg: Optional[PreAggState]) -> float:
    """Materialization bytes as a fraction of raw storage (paper's
    caching-cost accounting)."""
    raw = sum(int(np.prod(x.shape)) * x.dtype.itemsize
              for x in jax.tree_util.tree_leaves(state))
    if preagg is None:
        return 0.0
    extra = sum(int(np.prod(x.shape)) * x.dtype.itemsize
                for x in jax.tree_util.tree_leaves(preagg))
    return extra / raw
