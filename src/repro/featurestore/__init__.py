from repro.featurestore.table import (Table, TableSchema, TableState,
                                      PreAggState)
from repro.featurestore.registry import FeatureRegistry, FeatureSet

__all__ = ["Table", "TableSchema", "TableState", "PreAggState",
           "FeatureRegistry", "FeatureSet"]
