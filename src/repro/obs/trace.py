"""Distributed tracing: spans, trace ids, bounded storage (DESIGN.md §13).

One request's path — server admission → batcher queue → router lane →
(proc transport) → worker engine serve → kernel launches — is recorded
as a tree of :class:`Span`\\ s sharing one trace id. Design points:

* **Monotonic clock.** Span times are ``time.perf_counter()`` values,
  meaningful only within one process. Spans exported by a worker
  subprocess carry worker-clock times; the client **re-bases** them onto
  its own clock against the enclosing RPC span before adoption
  (``rebase`` argument of :meth:`Tracer.adopt`).
* **Deterministic sampling.** ``sampled(trace_id)`` hashes the trace id
  (crc32 / 2^32 < rate), so every tier — client, batcher, router,
  worker — makes the SAME keep/drop decision with zero coordination; a
  trace is never half-recorded because one tier flipped a coin
  differently.
* **Bounded ring storage.** Traces live in an LRU-bounded ordered map
  (``max_traces``), each capped at ``max_spans_per_trace`` spans; a
  tracer can run forever under load without growing.
* **Idempotent adoption.** Spans are keyed by globally-unique span id
  (pid-prefixed counter); adopting the same exported span twice — the
  at-least-once transport's dup/retry path re-delivers worker spans
  verbatim — is a counted no-op, never a duplicate tree node.
* **Slow-query log.** Finishing a root span updates a duration
  reservoir; a root beyond the running p99 (after ``slow_min_samples``
  warmup) captures its full exported trace into a bounded exemplar log.
"""
from __future__ import annotations

import collections
import os
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

__all__ = ["new_trace_id", "Span", "Tracer"]

# Crockford base32 (no I/L/O/U) — the ULID alphabet
_B32 = "0123456789ABCDEFGHJKMNPQRSTVWXYZ"


def _b32(value: int, n_chars: int) -> str:
    out = []
    for _ in range(n_chars):
        out.append(_B32[value & 31])
        value >>= 5
    return "".join(reversed(out))


def new_trace_id() -> str:
    """ULID-style id: 48-bit unix-ms timestamp + 80 random bits in 26
    Crockford-base32 chars — lexically sortable by creation time and
    collision-safe across processes (the random half comes from
    ``os.urandom``, so forked workers can't repeat a sequence)."""
    ms = int(time.time() * 1000) & ((1 << 48) - 1)
    rnd = int.from_bytes(os.urandom(10), "big")
    return _b32(ms, 10) + _b32(rnd, 16)


@dataclass
class Span:
    """One timed node of a trace tree. ``start``/``end`` are
    ``perf_counter`` seconds in the RECORDING process's clock domain."""

    trace_id: str
    span_id: str
    name: str
    parent_id: Optional[str] = None
    start: float = 0.0
    end: float = 0.0
    tags: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return max(self.end - self.start, 0.0)

    def to_dict(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "name": self.name, "parent_id": self.parent_id,
                "start": self.start, "end": self.end,
                "duration_s": self.duration_s, "tags": dict(self.tags)}


class Tracer:
    """Thread-safe span recorder with bounded storage and sampling.

    The zero-sampling fast path costs one float compare per call site
    (``start``/``record`` return ``None`` immediately), which is what
    keeps tracing's serving overhead inside the ≤5% budget even when
    left compiled into every tier.
    """

    def __init__(self, sample_rate: float = 1.0, *, max_traces: int = 256,
                 max_spans_per_trace: int = 512, slow_log_size: int = 32,
                 slow_min_samples: int = 30):
        self.sample_rate = float(sample_rate)
        self.max_traces = int(max_traces)
        self.max_spans_per_trace = int(max_spans_per_trace)
        self.slow_min_samples = int(slow_min_samples)
        # trace_id -> {span_id -> Span}; LRU order, oldest evicted
        self._traces: "collections.OrderedDict[str, Dict[str, Span]]" = \
            collections.OrderedDict()
        self._root_durations: "collections.deque" = \
            collections.deque(maxlen=512)
        # cached p99 threshold, refreshed every 16 roots — an
        # np.percentile over the full reservoir on EVERY root finish
        # would put an O(reservoir) sort on the per-batch serving path
        self._slow_p99 = float("inf")
        self._roots_seen = 0
        self._slow: "collections.deque" = \
            collections.deque(maxlen=slow_log_size)
        self._lock = threading.Lock()
        self._pid = os.getpid()
        self._seq = 0
        self.counters: Dict[str, int] = {
            "spans_started": 0, "spans_recorded": 0, "spans_adopted": 0,
            "spans_deduped": 0, "spans_dropped": 0, "traces_evicted": 0,
            "slow_queries": 0}

    # ---------------------------------------------------------- sampling
    def set_sample_rate(self, rate: float) -> None:
        self.sample_rate = float(rate)

    def sampled(self, trace_id: Optional[str]) -> bool:
        """Deterministic per-trace keep/drop — identical in every
        process that sees the same trace id."""
        if trace_id is None or self.sample_rate <= 0.0:
            return False
        if self.sample_rate >= 1.0:
            return True
        return (zlib.crc32(trace_id.encode("ascii", "replace"))
                / 2.0 ** 32) < self.sample_rate

    def _next_span_id(self) -> str:
        # pid prefix: ids stay unique across worker respawns (a fresh
        # incarnation restarts its counter but not its pid... and even a
        # recycled pid restarts the RANDOM trace, not the span storage)
        with self._lock:
            self._seq += 1
            return f"{self._pid:x}-{self._seq:x}"

    # --------------------------------------------------------- recording
    def start(self, name: str, trace_id: Optional[str],
              parent_id: Optional[str] = None,
              tags: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        """Open a span (``None`` when the trace isn't sampled — every
        other method accepts ``None`` spans as no-ops)."""
        if not self.sampled(trace_id):
            return None
        self.counters["spans_started"] += 1
        return Span(trace_id=trace_id, span_id=self._next_span_id(),
                    name=name, parent_id=parent_id,
                    start=time.perf_counter(),
                    tags=dict(tags) if tags else {})

    def finish(self, span: Optional[Span],
               tags: Optional[Dict[str, Any]] = None) -> None:
        if span is None:
            return
        span.end = time.perf_counter()
        if tags:
            span.tags.update(tags)
        self._store(span)
        if span.parent_id is None:
            self._observe_root(span)

    def record(self, name: str, trace_id: Optional[str],
               parent_id: Optional[str], start: float, end: float,
               tags: Optional[Dict[str, Any]] = None) -> Optional[Span]:
        """Retroactive span: the interval already happened (e.g. a
        batcher queue wait measured from the request's enqueue time)."""
        if not self.sampled(trace_id):
            return None
        span = Span(trace_id=trace_id, span_id=self._next_span_id(),
                    name=name, parent_id=parent_id, start=float(start),
                    end=float(end), tags=dict(tags) if tags else {})
        self.counters["spans_recorded"] += 1
        self._store(span)
        return span

    def _store(self, span: Span) -> None:
        with self._lock:
            tr = self._traces.get(span.trace_id)
            if tr is None:
                tr = self._traces[span.trace_id] = {}
            if span.span_id in tr:
                self.counters["spans_deduped"] += 1
                return
            if len(tr) >= self.max_spans_per_trace:
                self.counters["spans_dropped"] += 1
                return
            tr[span.span_id] = span
            self._traces.move_to_end(span.trace_id)
            while len(self._traces) > self.max_traces:
                self._traces.popitem(last=False)
                self.counters["traces_evicted"] += 1

    # ---------------------------------------------------------- adoption
    def adopt(self, spans: Iterable[Dict[str, Any]],
              rebase: float = 0.0) -> int:
        """Insert spans exported by ANOTHER tracer (a worker subprocess),
        shifting their times by ``rebase`` seconds into this process's
        clock domain. Keyed by span id: re-adopting the same export (the
        at-least-once transport's dup path) is a counted no-op. Returns
        spans newly adopted."""
        n = 0
        for d in spans:
            span = Span(trace_id=d["trace_id"], span_id=d["span_id"],
                        name=d["name"], parent_id=d.get("parent_id"),
                        start=float(d["start"]) + rebase,
                        end=float(d["end"]) + rebase,
                        tags=dict(d.get("tags") or {}))
            before = self.counters["spans_deduped"] \
                + self.counters["spans_dropped"]
            self._store(span)
            if (self.counters["spans_deduped"]
                    + self.counters["spans_dropped"]) == before:
                n += 1
        self.counters["spans_adopted"] += n
        return n

    # ------------------------------------------------------------- query
    def trace(self, trace_id: str) -> List[Span]:
        """Spans of one trace, by start time."""
        with self._lock:
            tr = self._traces.get(trace_id, {})
            return sorted(tr.values(), key=lambda s: (s.start, s.span_id))

    def export_trace(self, trace_id: str) -> List[Dict[str, Any]]:
        return [s.to_dict() for s in self.trace(trace_id)]

    def tree(self, trace_id: str) -> Optional[Dict[str, Any]]:
        """The trace as a nested dict (root = the parentless span; spans
        whose parent was recorded elsewhere attach under the root)."""
        spans = self.trace(trace_id)
        if not spans:
            return None
        nodes = {s.span_id: {"name": s.name, "span_id": s.span_id,
                             "start": s.start, "duration_s": s.duration_s,
                             "tags": dict(s.tags), "children": []}
                 for s in spans}
        root = None
        for s in spans:
            if s.parent_id is None and root is None:
                root = nodes[s.span_id]
        orphans = []
        for s in spans:
            if s.parent_id is None:
                # sibling parentless spans (a tier called without an
                # enclosing server root) hang under the first root
                if root is not None and nodes[s.span_id] is not root:
                    orphans.append(nodes[s.span_id])
                continue
            parent = nodes.get(s.parent_id)
            if parent is not None:
                parent["children"].append(nodes[s.span_id])
            else:
                orphans.append(nodes[s.span_id])
        if root is None:
            root = (orphans or list(nodes.values()))[0]
        for o in orphans:
            if o is not root:
                root["children"].append(o)
        return root

    @staticmethod
    def walk(tree: Optional[Dict[str, Any]]) -> List[Dict[str, Any]]:
        """Flatten a :meth:`tree` into a pre-order node list."""
        out: List[Dict[str, Any]] = []
        stack = [tree] if tree else []
        while stack:
            node = stack.pop()
            out.append(node)
            stack.extend(reversed(node["children"]))
        return out

    # -------------------------------------------------------- slow query
    def _observe_root(self, span: Span) -> None:
        dur = span.duration_s
        with self._lock:
            self._root_durations.append(dur)
            self._roots_seen += 1
            n = len(self._root_durations)
            if n < self.slow_min_samples:
                return
            if (self._slow_p99 == float("inf")
                    or self._roots_seen % 16 == 0):
                self._slow_p99 = float(np.percentile(
                    np.asarray(self._root_durations, np.float64), 99))
            p99 = self._slow_p99
        if dur > p99:
            self.counters["slow_queries"] += 1
            self._slow.append({"trace_id": span.trace_id,
                               "duration_s": dur, "root": span.name,
                               "spans": self.export_trace(span.trace_id)})

    def slow_queries(self) -> List[Dict[str, Any]]:
        return list(self._slow)

    # ---------------------------------------------------------- export
    def snapshot(self) -> Dict[str, float]:
        """Monotonic counters + gauges (unified-export group)."""
        with self._lock:
            n_traces = len(self._traces)
            n_spans = sum(len(tr) for tr in self._traces.values())
        out: Dict[str, float] = dict(self.counters)
        out["sample_rate"] = self.sample_rate
        out["traces_stored"] = n_traces
        out["spans_stored"] = n_spans
        out["slow_log_size"] = len(self._slow)
        return out
