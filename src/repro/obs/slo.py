"""Declarative SLOs with multi-window burn-rate alerting (DESIGN.md §14).

An :class:`SLOSpec` declares a bound over one exported metric (latency
p99, feature-age p99, shed ratio, drift PSI — any key of the metrics
dict fed to :meth:`SLOEngine.evaluate`) and an error budget: the
allowed fraction of BAD evaluation samples. Burn rate is the classic
SRE quantity ``bad_fraction / budget`` — burn 1.0 spends the budget
exactly, burn N spends it N× too fast.

Alerting uses the standard fast+slow multi-window rule: a spec flips to
``ALERTING`` only when BOTH windows burn above ``burn_threshold`` (the
slow window filters blips, the fast window guarantees the alert fires
promptly on a real regression and RESOLVES promptly after it clears —
the fast window alone drops below threshold as soon as recent samples
are good again).

State transitions are recorded (and exported via :meth:`export`) and
the control plane delivers active ``action="tune"`` alerts into
``ControlPlane.tick()`` as a first-class ``LoadObservation`` input;
``action="report"`` alerts (drift) never steer knobs. ``evaluate``
takes an explicit ``now`` so tests drive the windows deterministically
without sleeping.
"""
from __future__ import annotations

import collections
import math
import threading
import time
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Mapping, Optional, Tuple

__all__ = ["SLOSpec", "SLOEngine", "OK", "ALERTING"]

OK = "ok"
ALERTING = "alerting"


@dataclass(frozen=True)
class SLOSpec:
    """One service-level objective over one exported metric.

    A sample is GOOD when ``value <= bound``. ``budget`` is the allowed
    bad fraction (0.01 = 99% of samples must be good). ``action`` is
    what the control plane may do with an active alert: ``"tune"`` lets
    the knob controller treat the burn as overload pressure;
    ``"report"`` is observe-only (drift SLOs must never steer knobs —
    a skewed feature distribution is a modeling problem, not a capacity
    problem)."""

    name: str
    metric: str
    bound: float
    budget: float = 0.01
    fast_window_s: float = 60.0
    slow_window_s: float = 300.0
    burn_threshold: float = 2.0
    action: str = "tune"

    def __post_init__(self):
        if self.action not in ("tune", "report"):
            raise ValueError(
                f"SLOSpec action must be 'tune' or 'report', "
                f"got {self.action!r}")
        if not 0.0 < self.budget <= 1.0:
            raise ValueError(f"budget must be in (0, 1], got {self.budget}")
        if self.fast_window_s > self.slow_window_s:
            raise ValueError("fast window must not exceed slow window")


class _SpecState:
    __slots__ = ("samples", "state", "since", "transitions")

    def __init__(self):
        # (t, bad) evaluation samples, pruned past the slow window
        self.samples: Deque[Tuple[float, bool]] = collections.deque()
        self.state = OK
        self.since = 0.0
        self.transitions = 0


class SLOEngine:
    """Evaluates every spec against a metrics dict; tracks burn rates,
    alert state, and the transition log."""

    MAX_TRANSITIONS = 256

    def __init__(self, specs: Optional[List[SLOSpec]] = None):
        self._specs: Dict[str, SLOSpec] = {}
        self._states: Dict[str, _SpecState] = {}
        self.transitions: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        for s in (specs or ()):
            self.add(s)

    def add(self, spec: SLOSpec) -> SLOSpec:
        with self._lock:
            self._specs[spec.name] = spec
            self._states[spec.name] = _SpecState()
        return spec

    def specs(self) -> List[SLOSpec]:
        with self._lock:
            return list(self._specs.values())

    # ------------------------------------------------------------ evaluate
    @staticmethod
    def _burn(samples, spec: SLOSpec, window_s: float,
              now: float) -> Tuple[float, int]:
        bad = n = 0
        cutoff = now - window_s
        for t, is_bad in samples:
            if t >= cutoff:
                n += 1
                bad += is_bad
        if n == 0:
            return 0.0, 0
        return (bad / n) / spec.budget, n

    def evaluate(self, metrics: Mapping[str, float],
                 now: Optional[float] = None) -> List[Dict[str, Any]]:
        """Feed one sample of every watched metric; returns the state
        TRANSITIONS this evaluation caused (empty list = no change).
        Metrics missing or non-finite contribute no sample (an unserved
        deployment must not look healthy OR unhealthy)."""
        now = time.monotonic() if now is None else float(now)
        events: List[Dict[str, Any]] = []
        with self._lock:
            specs = list(self._specs.values())
        for spec in specs:
            st = self._states[spec.name]
            v = metrics.get(spec.metric)
            if isinstance(v, (int, float)) and not isinstance(v, bool) \
                    and math.isfinite(float(v)):
                st.samples.append((now, float(v) > spec.bound))
            cutoff = now - spec.slow_window_s
            while st.samples and st.samples[0][0] < cutoff:
                st.samples.popleft()
            fast, n_fast = self._burn(st.samples, spec,
                                      spec.fast_window_s, now)
            slow, n_slow = self._burn(st.samples, spec,
                                      spec.slow_window_s, now)
            new_state = st.state
            if st.state == OK:
                if (n_fast > 0 and fast >= spec.burn_threshold
                        and slow >= spec.burn_threshold):
                    new_state = ALERTING
            else:
                if fast < spec.burn_threshold:
                    new_state = OK
            if new_state != st.state:
                st.state = new_state
                st.since = now
                st.transitions += 1
                ev = {"t": now, "slo": spec.name, "state": new_state,
                      "metric": spec.metric, "action": spec.action,
                      "fast_burn": fast, "slow_burn": slow,
                      "value": metrics.get(spec.metric)}
                events.append(ev)
                with self._lock:
                    self.transitions.append(ev)
                    if len(self.transitions) > self.MAX_TRANSITIONS:
                        del self.transitions[:len(self.transitions)
                                             - self.MAX_TRANSITIONS]
        return events

    # -------------------------------------------------------------- status
    def state(self, name: str) -> str:
        return self._states[name].state

    def active_alerts(self, action: Optional[str] = None
                      ) -> List[SLOSpec]:
        with self._lock:
            specs = list(self._specs.values())
        return [s for s in specs
                if self._states[s.name].state == ALERTING
                and (action is None or s.action == action)]

    def snapshot(self, now: Optional[float] = None
                 ) -> Dict[str, Dict[str, Any]]:
        now = time.monotonic() if now is None else float(now)
        out: Dict[str, Dict[str, Any]] = {}
        with self._lock:
            specs = list(self._specs.values())
        for spec in specs:
            st = self._states[spec.name]
            fast, n_fast = self._burn(st.samples, spec,
                                      spec.fast_window_s, now)
            slow, n_slow = self._burn(st.samples, spec,
                                      spec.slow_window_s, now)
            out[spec.name] = {
                "state": st.state, "metric": spec.metric,
                "bound": spec.bound, "action": spec.action,
                "fast_burn": fast, "slow_burn": slow,
                "fast_samples": n_fast, "slow_samples": n_slow,
                "transitions": st.transitions,
            }
        return out

    def export(self) -> Dict[str, float]:
        """Flat metrics for the registry ``slo`` group."""
        out: Dict[str, float] = {}
        for name, st in self.snapshot().items():
            out[f"{name}/alerting"] = 1.0 if st["state"] == ALERTING \
                else 0.0
            out[f"{name}/fast_burn"] = st["fast_burn"]
            out[f"{name}/slow_burn"] = st["slow_burn"]
            out[f"{name}/transitions"] = float(st["transitions"])
        return out
