"""Unified metrics export: one registry over every counter surface.

Before this module each consumer sampled the runtime ad hoc —
``MetricsCollector`` reached into ``EngineStats`` / ``CacheStats`` /
``HandleMetrics`` / ``ResourceManager`` / batcher / transport objects
directly. :class:`MetricsRegistry` inverts that: each surface registers
ONE collector callable returning a flat ``{key: number}`` dict, and
every consumer — the control plane's telemetry, the Prometheus text
endpoint, JSONL snapshot logs — walks the same registry.

Key convention: a ``/`` in a key separates an item label from the
metric (``"fraud/requests"`` in group ``deployment`` renders as
``repro_deployment_requests{item="fraud"}``); everything else renders
as ``repro_<group>_<key>``. Non-finite and non-numeric values are
skipped in the Prometheus text (the JSONL snapshot keeps them — NaN is
a meaningful "no sample yet" there).
"""
from __future__ import annotations

import json
import math
import re
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["MetricsRegistry", "registry_from_engine"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _escape_label(value: str) -> str:
    """Prometheus label-value escaping: backslash, double-quote and
    newline are the three characters the text format requires escaped."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt(v) -> str:
    return f"{int(v)}" if isinstance(v, int) else repr(float(v))


class MetricsRegistry:
    """Named groups of collector callables; collection is pull-based —
    nothing is cached, a collect reads the live counters."""

    def __init__(self, prefix: str = "repro"):
        self.prefix = prefix
        self._groups: Dict[str, Callable[[], Dict[str, Any]]] = {}

    def register(self, group: str,
                 collector: Callable[[], Dict[str, Any]]) -> None:
        self._groups[group] = collector

    def unregister(self, group: str) -> None:
        self._groups.pop(group, None)

    def groups(self) -> List[str]:
        return sorted(self._groups)

    def collect(self, group: Optional[str] = None
                ) -> Dict[str, Dict[str, Any]]:
        """``{group: {key: value}}`` for one group or all. A collector
        raising (e.g. a surface torn down mid-collect) yields an empty
        group rather than poisoning the rest."""
        names = [group] if group is not None else self.groups()
        out: Dict[str, Dict[str, Any]] = {}
        for g in names:
            fn = self._groups.get(g)
            if fn is None:
                out[g] = {}
                continue
            try:
                out[g] = dict(fn())
            except Exception:
                out[g] = {}
        return out

    # ---------------------------------------------------------- renderers
    def render_prometheus(self) -> str:
        """Prometheus text exposition: one gauge per numeric key, plus a
        native histogram per quantile-sketch value (``*_sketch`` entries
        in a collector dict render as cumulative ``_bucket`` series with
        ``le`` labels, ``_sum`` and ``_count``). Label values are escaped
        per the text-format rules; ``# HELP``/``# TYPE`` headers precede
        the first sample of each metric family."""
        from repro.obs.sketch import QuantileSketch
        lines: List[str] = []
        for group, metrics in self.collect().items():
            seen = set()
            for key in sorted(metrics):
                v = metrics[key]
                if "/" in key:
                    item, metric = key.split("/", 1)
                    mname = (f"{self.prefix}_{_sanitize(group)}_"
                             f"{_sanitize(metric)}")
                    item_label = f'item="{_escape_label(item)}"'
                else:
                    metric = key
                    mname = (f"{self.prefix}_{_sanitize(group)}_"
                             f"{_sanitize(key)}")
                    item_label = ""
                if QuantileSketch.is_sketch_dict(v):
                    if mname not in seen:
                        lines.append(f"# HELP {mname} {group} {metric} "
                                     f"(quantile sketch)")
                        lines.append(f"# TYPE {mname} histogram")
                        seen.add(mname)
                    sk = QuantileSketch.from_dict(v)
                    pre = f"{item_label}," if item_label else ""
                    for ub, cum in sk.histogram():
                        lines.append(f'{mname}_bucket{{{pre}le='
                                     f'"{_fmt(float(ub))}"}} {cum}')
                    lines.append(
                        f'{mname}_bucket{{{pre}le="+Inf"}} {sk.count}')
                    lab = f"{{{item_label}}}" if item_label else ""
                    lines.append(f"{mname}_sum{lab} {_fmt(sk.sum)}")
                    lines.append(f"{mname}_count{lab} {sk.count}")
                    continue
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                if isinstance(v, float) and not math.isfinite(v):
                    continue
                if mname not in seen:
                    lines.append(f"# HELP {mname} {group} {metric}")
                    lines.append(f"# TYPE {mname} gauge")
                    seen.add(mname)
                lab = f"{{{item_label}}}" if item_label else ""
                lines.append(f"{mname}{lab} {_fmt(v)}")
        return "\n".join(lines) + "\n"

    def render_jsonl(self, now: Optional[float] = None) -> str:
        """One JSON line: ``{"t": ..., "<group>": {...}, ...}`` — append
        to a file and you have a snapshot log."""
        snap: Dict[str, Any] = {
            "t": time.time() if now is None else float(now)}
        snap.update(self.collect())
        return json.dumps(snap, default=_json_default)


def _json_default(v):
    if hasattr(v, "item"):            # numpy scalar
        return v.item()
    return str(v)


# --------------------------------------------------------------- wiring
def registry_from_engine(engine, *, server=None, slo=None,
                         prefix: str = "repro") -> MetricsRegistry:
    """Wire a registry over every surface ``engine`` (an ``Engine`` or a
    ``ShardedEngine``) and the optional ``FeatureServer`` expose. Groups
    appear only when their surface exists; per-deployment and transport
    collectors enumerate at collect time, so deploys/respawns after
    wiring are picked up automatically."""
    reg = MetricsRegistry(prefix=prefix)
    shards = getattr(engine, "shards", None)

    def engine_stats() -> Dict[str, float]:
        if hasattr(engine, "stats"):                 # single Engine
            return engine.stats.snapshot()
        agg: Dict[str, float] = {}
        for sub in (shards or ()):                   # ShardedEngine
            for k, v in sub.stats.snapshot().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def cache_stats() -> Dict[str, float]:
        if shards is None:
            return engine.cache.stats.snapshot()
        agg: Dict[str, float] = {}
        for sub in shards:
            for k, v in sub.cache.stats.snapshot().items():
                if k == "hit_rate":
                    continue
                agg[k] = agg.get(k, 0) + v
        total = agg.get("hits", 0) + agg.get("misses", 0)
        agg["hit_rate"] = agg.get("hits", 0) / total if total else 0.0
        return agg

    def deployment_stats() -> Dict[str, float]:
        out: Dict[str, float] = {}
        for name, dep in getattr(engine, "deployments", {}).items():
            for k, v in dep.metrics.snapshot().items():
                out[f"{name}/{k}"] = v
            out[f"{name}/version"] = dep.version
        return out

    reg.register("engine", engine_stats)
    reg.register("cache", cache_stats)
    reg.register("deployment", deployment_stats)

    res = getattr(engine, "resources", None)
    if res is not None:
        reg.register("admission", res.metrics)
    router = getattr(engine, "router", None)
    if router is not None:
        reg.register("router", router.stats)

    backend = getattr(engine, "backend", None)
    if backend is not None:
        def transport_stats() -> Dict[str, float]:
            agg: Dict[str, float] = {}
            for c in backend.clients:
                for k, v in c.transport_stats.items():
                    agg[k] = agg.get(k, 0) + v
            return agg

        def recovery_stats() -> Dict[str, float]:
            out = dict(getattr(engine, "recovery_stats", {}))
            out.update(backend.recovery_stats)
            out["worker_restarts"] = sum(c.restarts
                                         for c in backend.clients)
            return out

        reg.register("transport", transport_stats)
        reg.register("recovery", recovery_stats)
    elif hasattr(engine, "recovery_stats"):
        reg.register("recovery",
                     lambda: dict(engine.recovery_stats))

    tracer = getattr(engine, "tracer", None)
    if tracer is not None:
        reg.register("tracer", tracer.snapshot)

    if hasattr(engine, "freshness_export"):
        reg.register("freshness", engine.freshness_export)
    if hasattr(engine, "drift_export"):
        reg.register("drift", engine.drift_export)
    flight = getattr(engine, "flight", None)
    if flight is not None:
        reg.register("flight", flight.stats)
    if slo is not None:
        reg.register("slo", slo.export)

    batcher = getattr(server, "batcher", None) if server else None
    if batcher is not None:
        def batcher_stats() -> Dict[str, float]:
            out = dict(batcher.stats)
            out["queue_depth"] = batcher.queue_depth()
            out["oldest_age_s"] = batcher.oldest_age_s()
            out["client_p99_s"] = \
                batcher.client_latency_percentile(99)
            out["max_delay_s"] = batcher.cfg.max_delay_s
            out["max_batch"] = batcher.cfg.max_batch
            return out
        reg.register("batcher", batcher_stats)
    return reg
