"""Flight recorder: a bounded ring of recent per-batch serving records
dumped to JSONL on SLO breach or worker crash (DESIGN.md §14).

Postmortems of a p99 regression or a shed storm need the HISTORY that
led into the event — which traces, which deployment versions, which
knob settings, how stale the snapshots were — but recording that
everywhere at full fidelity would be its own overhead problem. The
recorder keeps only the last ``capacity`` records in memory (a deque
append per served batch, no I/O) and writes them out ONLY when
something goes wrong: the control plane dumps on an SLO OK→ALERTING
transition, the sharded engine dumps when a worker dies.

Record schema (one JSON object per line):
``{"seq": n, "t": unix_s, "kind": ...,  **fields}`` where ``kind`` is
``serve`` (trace id, deployment, version vector, rows, status mix,
freshness stamp), ``shed`` (shed kind), ``context`` (knob settings —
written only when a value CHANGES, not copied into every record),
``worker_down`` / ``alert`` markers, and a leading ``dump`` header with
the dump reason. ``dump()`` is rate-limited so an alert storm cannot
turn the recorder into a disk-filling hazard.
"""
from __future__ import annotations

import collections
import json
import os
import re
import tempfile
import threading
import time
from typing import Any, Deque, Dict, List, Optional

__all__ = ["FlightRecorder"]

_REASON_RE = re.compile(r"[^a-zA-Z0-9_.-]+")


def _json_default(v):
    if hasattr(v, "item"):
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return str(v)


class FlightRecorder:
    """Bounded in-memory ring of serving records + JSONL dump-on-breach."""

    def __init__(self, capacity: int = 2048,
                 out_dir: Optional[str] = None,
                 min_dump_interval_s: float = 2.0):
        self.capacity = int(capacity)
        self.out_dir = (out_dir
                        or os.environ.get("REPRO_FLIGHT_DIR")
                        or tempfile.gettempdir())
        self.min_dump_interval_s = float(min_dump_interval_s)
        self._ring: Deque[Dict[str, Any]] = collections.deque(
            maxlen=self.capacity)
        self._ctx: Dict[str, Any] = {}
        self._seq = 0
        self._last_dump = -float("inf")
        self.dumps: List[str] = []
        self._lock = threading.Lock()

    # -------------------------------------------------------------- record
    def record(self, kind: str, **fields) -> None:
        """Append one record (cheap: dict build + deque append)."""
        with self._lock:
            self._seq += 1
            rec = {"seq": self._seq, "t": time.time(), "kind": kind}
            rec.update(fields)
            self._ring.append(rec)

    def set_context(self, **kv) -> None:
        """Update ambient context (knob settings, live versions). Only
        CHANGED values produce a record — replaying the ring left to
        right reconstructs the context at any record without every
        record carrying a copy."""
        with self._lock:
            changed = {k: v for k, v in kv.items()
                       if self._ctx.get(k) != v}
            if not changed:
                return
            self._ctx.update(changed)
            self._seq += 1
            rec = {"seq": self._seq, "t": time.time(), "kind": "context"}
            rec.update(changed)
            self._ring.append(rec)

    def records(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    # ---------------------------------------------------------------- dump
    def dump(self, reason: str, *, force: bool = False) -> Optional[str]:
        """Write the ring to a timestamped JSONL file; returns the path,
        or ``None`` when rate-limited (pass ``force=True`` to override).
        The ring is NOT cleared — overlapping dumps around one incident
        each carry the full window."""
        now = time.time()
        with self._lock:
            if not force and (now - self._last_dump
                              < self.min_dump_interval_s):
                return None
            self._last_dump = now
            records = list(self._ring)
            ctx = dict(self._ctx)
        slug = _REASON_RE.sub("-", reason).strip("-") or "dump"
        path = os.path.join(
            self.out_dir,
            f"flight-{int(now * 1000)}-{os.getpid()}-{slug}.jsonl")
        header = {"kind": "dump", "t": now, "reason": reason,
                  "n_records": len(records), "context": ctx}
        with open(path, "w") as f:
            f.write(json.dumps(header, default=_json_default) + "\n")
            for rec in records:
                f.write(json.dumps(rec, default=_json_default) + "\n")
        with self._lock:
            self.dumps.append(path)
        return path

    # -------------------------------------------------------------- export
    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"records": float(len(self._ring)),
                    "seq": float(self._seq),
                    "dumps": float(len(self.dumps))}

    def __repr__(self) -> str:
        return (f"FlightRecorder(n={len(self)}/{self.capacity}, "
                f"dumps={len(self.dumps)})")
