"""Observability tier (DESIGN.md §13): distributed tracing, the
EXPLAIN ANALYZE operator profiler, and the unified metrics registry."""
from repro.obs.export import MetricsRegistry, registry_from_engine
from repro.obs.profile import (OperatorProfiler, attribute_exec,
                               operator_rows)
from repro.obs.trace import Span, Tracer, new_trace_id

__all__ = ["Tracer", "Span", "new_trace_id", "OperatorProfiler",
           "operator_rows", "attribute_exec", "MetricsRegistry",
           "registry_from_engine"]
