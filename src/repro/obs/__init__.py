"""Observability tier (DESIGN.md §13–14): distributed tracing, the
EXPLAIN ANALYZE operator profiler, the unified metrics registry, and
the data-plane freshness/drift/SLO/flight-recorder modules.

Attribute access is lazy (PEP 562): the low-level sketch/freshness
modules are imported by the featurestore/streaming layers, so eagerly
importing ``profile``/``export`` here (which pull ``repro.core``) would
create an import cycle.
"""
_EXPORTS = {
    "MetricsRegistry": "repro.obs.export",
    "registry_from_engine": "repro.obs.export",
    "OperatorProfiler": "repro.obs.profile",
    "attribute_exec": "repro.obs.profile",
    "operator_rows": "repro.obs.profile",
    "Span": "repro.obs.trace",
    "Tracer": "repro.obs.trace",
    "new_trace_id": "repro.obs.trace",
    "QuantileSketch": "repro.obs.sketch",
    "RollingSketch": "repro.obs.sketch",
    "CardinalityEstimator": "repro.obs.sketch",
    "DriftMonitor": "repro.obs.sketch",
    "psi_distance": "repro.obs.sketch",
    "FreshnessTracker": "repro.obs.freshness",
    "SLOSpec": "repro.obs.slo",
    "SLOEngine": "repro.obs.slo",
    "FlightRecorder": "repro.obs.flight",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)
