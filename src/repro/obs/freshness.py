"""Feature freshness tracking (DESIGN.md §14).

The paper's sub-millisecond-serving claim is only meaningful if the
features are FRESH — OpenMLDB's system paper makes ingest-to-visible
latency and online/offline consistency first-class correctness
properties. This module instruments the data plane end to end:

- **Feature age** — at serve, per ROW: request event-time minus the
  served snapshot's watermark (the max event-time the published state
  covers). Age is in event-time units; a negative age means the request
  asked about a time the table has already ingested past.
- **Ingest-to-visible latency** — wall seconds from an event arriving
  at the pipeline to the flush that PUBLISHED it (copy-on-write swap
  making it queryable). Matched FIFO per flush, so it is exact to
  within one flush interval.
- **Ingest-side distributions** — per-value-column sketches and a
  distinct-key KMV estimator maintained incrementally at
  ``Table.insert`` ride along in the same snapshot.

Everything is held as mergeable sketches/counters
(:mod:`repro.obs.sketch`): a process-backed shard ships its tracker
snapshot over the ``freshness_snapshot`` RPC and the parent's
:meth:`FreshnessTracker.merge` recovers EXACTLY what one engine
observing the union would hold (watermarks merge by ``min`` — the
slowest shard bounds global freshness).
"""
from __future__ import annotations

import math
import threading
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.obs.sketch import (CardinalityEstimator, QuantileSketch,
                              DEFAULT_REL_ERR)

__all__ = ["FreshnessTracker"]


class FreshnessTracker:
    """Per-table freshness sketches + counters (one per engine; shard
    engines each own one and the sharded tier merges snapshots)."""

    MAX_PENDING = 256        # serve batches buffered before a forced fold

    def __init__(self, rel_err: float = DEFAULT_REL_ERR):
        self.rel_err = float(rel_err)
        self._lock = threading.Lock()
        self._tables: Dict[str, Dict[str, Any]] = {}
        # serve-path age batches are BUFFERED and folded lazily (on any
        # snapshot, or when MAX_PENDING batches pile up) so the hot path
        # pays one list append, not a sketch insert. Fold order cannot
        # change the result — sketch insertion is commutative.
        self._pending: List[Tuple[str, Any]] = []

    def _entry(self, table: str) -> Dict[str, Any]:
        ent = self._tables.get(table)
        if ent is None:
            ent = self._tables[table] = {
                "age": QuantileSketch(self.rel_err),
                "i2v": QuantileSketch(self.rel_err),
                "serve_rows": 0,
                "serve_batches": 0,
                "ingested": 0,
                "flushes": 0,
            }
        return ent

    # ------------------------------------------------------------- observe
    def _drain(self) -> None:
        """Fold every buffered age batch into the per-table sketches."""
        with self._lock:
            if not self._pending:
                return
            pending, self._pending = self._pending, []
        for table, ages in pending:
            with self._lock:
                ent = self._entry(table)
            n = ent["age"].observe_many(ages)
            with self._lock:
                ent["serve_rows"] += n
                ent["serve_batches"] += 1

    def observe_age(self, table: str, ages) -> int:
        """Per-row feature ages (event-time units) for one served batch.
        Call with the UNPADDED rows only — equal request multisets must
        produce equal sketches across backends. O(1) on the serve path:
        the batch is buffered and folded on the next snapshot (or after
        MAX_PENDING batches)."""
        a = np.asarray(ages, np.float64)
        with self._lock:
            self._pending.append((table, a))
            full = len(self._pending) >= self.MAX_PENDING
        if full:
            self._drain()
        return int(a.size)

    def observe_ingest_visibility(self, table: str, latency_s,
                                  count: int = 1) -> None:
        """One arrival cohort became visible: ``count`` events that
        waited ``latency_s`` wall seconds from pipeline arrival to the
        publishing flush."""
        with self._lock:
            ent = self._entry(table)
        ent["i2v"].observe_many(
            np.full(max(int(count), 1), float(latency_s), np.float64))
        with self._lock:
            ent["ingested"] += int(count)
            ent["flushes"] += 1

    # -------------------------------------------------------------- export
    def tables(self) -> List[str]:
        self._drain()
        with self._lock:
            return sorted(self._tables)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Picklable per-table snapshot (sketches as dicts). Watermark /
        publish stamps are NOT stored here — the engine reads them live
        from its table snapshots and folds them in
        (``Engine.freshness_snapshot``), so the tracker can never go
        stale relative to the tables it describes."""
        self._drain()
        with self._lock:
            items = list(self._tables.items())
        out: Dict[str, Dict[str, Any]] = {}
        for name, ent in items:
            out[name] = {
                "age_sketch": ent["age"].to_dict(),
                "i2v_sketch": ent["i2v"].to_dict(),
                "serve_rows": ent["serve_rows"],
                "serve_batches": ent["serve_batches"],
                "ingested": ent["ingested"],
                "flushes": ent["flushes"],
            }
        return out

    @staticmethod
    def blank_entry() -> Dict[str, Any]:
        return {"age_sketch": None, "i2v_sketch": None, "serve_rows": 0,
                "serve_batches": 0, "ingested": 0, "flushes": 0}

    @staticmethod
    def merge(snapshots: Sequence[Optional[Mapping[str, Any]]]
              ) -> Dict[str, Dict[str, Any]]:
        """Merge per-shard ``freshness_snapshot`` dicts: sketches merge
        exactly, counters add, watermark/published stamps take the MIN
        (conservative — the slowest shard bounds the data plane), table
        versions take the max."""
        out: Dict[str, Dict[str, Any]] = {}
        for snap in snapshots:
            if not snap:
                continue
            for table, ent in snap.items():
                acc = out.get(table)
                if acc is None:
                    acc = out[table] = dict(FreshnessTracker.blank_entry())
                for skey in ("age_sketch", "i2v_sketch"):
                    d = ent.get(skey)
                    if d:
                        if acc[skey] is None:
                            acc[skey] = QuantileSketch.from_dict(d) \
                                .to_dict()
                        else:
                            acc[skey] = QuantileSketch.from_dict(
                                acc[skey]).merge(dict(d)).to_dict()
                for ckey in ("serve_rows", "serve_batches", "ingested",
                             "flushes"):
                    acc[ckey] += int(ent.get(ckey, 0))
                for mkey in ("watermark", "published_at"):
                    if mkey in ent:
                        v = float(ent[mkey])
                        acc[mkey] = v if mkey not in acc \
                            else min(acc[mkey], v)
                if "table_version" in ent:
                    acc["table_version"] = max(
                        acc.get("table_version", -1),
                        int(ent["table_version"]))
                if ent.get("key_card"):
                    if acc.get("key_card") is None:
                        acc["key_card"] = CardinalityEstimator.from_dict(
                            ent["key_card"]).to_dict()
                    else:
                        acc["key_card"] = CardinalityEstimator.from_dict(
                            acc["key_card"]).merge(
                            dict(ent["key_card"])).to_dict()
                for col, d in (ent.get("columns") or {}).items():
                    cols = acc.setdefault("columns", {})
                    if col in cols:
                        cols[col] = QuantileSketch.from_dict(
                            cols[col]).merge(dict(d)).to_dict()
                    else:
                        cols[col] = QuantileSketch.from_dict(d).to_dict()
        return out

    @staticmethod
    def export(snapshot: Mapping[str, Mapping[str, Any]],
               now: Optional[float] = None) -> Dict[str, Any]:
        """Flatten a (possibly merged) snapshot into the registry's
        ``freshness`` group: ``"<table>/<metric>"`` keys. Sketch dicts
        are passed through under ``*_sketch`` keys — the Prometheus
        renderer exposes them as native histograms, the JSONL exporter
        keeps them verbatim."""
        now = time.time() if now is None else float(now)
        out: Dict[str, Any] = {}
        for table, ent in snapshot.items():
            age = ent.get("age_sketch")
            i2v = ent.get("i2v_sketch")
            agesk = (age if isinstance(age, QuantileSketch)
                     or age is None else QuantileSketch.from_dict(age))
            i2vsk = (i2v if isinstance(i2v, QuantileSketch)
                     or i2v is None else QuantileSketch.from_dict(i2v))
            out[f"{table}/age_p50"] = \
                agesk.percentile(50) if agesk else float("nan")
            out[f"{table}/age_p99"] = \
                agesk.percentile(99) if agesk else float("nan")
            out[f"{table}/age_max"] = \
                (agesk.vmax if agesk and agesk.count else float("nan"))
            out[f"{table}/age_samples"] = \
                int(agesk.count) if agesk else 0
            out[f"{table}/ingest_visible_p50_s"] = \
                i2vsk.percentile(50) if i2vsk else float("nan")
            out[f"{table}/ingest_visible_p99_s"] = \
                i2vsk.percentile(99) if i2vsk else float("nan")
            out[f"{table}/ingested"] = int(ent.get("ingested", 0))
            out[f"{table}/flushes"] = int(ent.get("flushes", 0))
            out[f"{table}/serve_rows"] = int(ent.get("serve_rows", 0))
            out[f"{table}/serve_batches"] = \
                int(ent.get("serve_batches", 0))
            wm = ent.get("watermark")
            if wm is not None:
                out[f"{table}/watermark"] = float(wm)
            pub = ent.get("published_at")
            if pub is not None:
                pub = float(pub)
                out[f"{table}/published_at"] = pub
                out[f"{table}/publish_age_s"] = (
                    now - pub if pub > 0 else float("nan"))
            if "table_version" in ent:
                out[f"{table}/table_version"] = \
                    int(ent["table_version"])
            kc = ent.get("key_card")
            if kc is not None:
                est = (kc.estimate() if isinstance(
                    kc, CardinalityEstimator)
                    else CardinalityEstimator.from_dict(kc).estimate())
                out[f"{table}/keys_est"] = est
            for col, d in (ent.get("columns") or {}).items():
                sk = QuantileSketch.from_dict(d)
                out[f"{table}/ingest_{col}_p50"] = sk.percentile(50)
                out[f"{table}/ingest_{col}_p99"] = sk.percentile(99)
            if age is not None:
                out[f"{table}/age_sketch"] = (
                    age.to_dict() if isinstance(age, QuantileSketch)
                    else dict(age))
            if i2v is not None:
                out[f"{table}/ingest_visible_sketch"] = (
                    i2v.to_dict() if isinstance(i2v, QuantileSketch)
                    else dict(i2v))
        return out

    @staticmethod
    def worst_age_p99(export_or_snapshot: Mapping[str, Any]) -> float:
        """Max per-table age p99 from an ``export()`` dict — the scalar a
        freshness SLO watches."""
        vals = [v for k, v in export_or_snapshot.items()
                if k.endswith("/age_p99") and isinstance(v, float)
                and math.isfinite(v)]
        return max(vals) if vals else float("nan")
