"""EXPLAIN ANALYZE: attribute measured serve time to plan operators.

The paper's headline table attributes its speedup 35% to plan
optimization, 25% to caching, 20% to parallelism — an attribution over
MEASURED time, not model estimates. This module is the runtime half of
that: :class:`OperatorProfiler` accumulates, per deployment, the
measured per-batch stage times the engine already captures (``exec``
from the kernel-dispatch clock, ``host`` as the serve-wall residual,
``plan`` from the compile clock) and splits the exec portion across the
physical plan's operators.

Attribution math (DESIGN.md §13): per-operator **element counts** come
from the same unit-cost model the optimizer prices plans with
(``estimate_window_cost`` / ``estimate_join_cost`` at weight 1.0 — one
row per fused-scan set, per non-fused group, per join probe), then one
batch's measured ``exec_s`` is split proportionally to
``weight(kind) · elements(op)`` under the engine's CURRENT cost model.
Kernel launches cannot be individually timed inside a jitted dispatch
(there is one ``block_until_ready`` for the whole batch), so per-operator
seconds are *attributed*, not clocked — but they always sum to the
measured total by construction, and the attribution sharpens as the
:class:`~repro.control.calibrate.CostCalibrator` refits the weights from
these same profiles (measured-per-operator feedback replacing the
plane's old EM-style split).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence

from repro.core.optimizer import (CostModel, TableMeta, estimate_join_cost,
                                  estimate_window_cost)

__all__ = ["OperatorProfiler", "operator_rows", "attribute_exec"]

# non-operator rows every profile carries: host-side work (keydir
# resolve, masking, padding) and amortized plan/compile time
HOST_ROW = "host/keydir"
PLAN_ROW = "plan/compile"


def operator_rows(handle) -> List[Dict[str, Any]]:
    """Per-operator unit-cost element rows for one deployed version —
    the per-operator refinement of
    :func:`repro.control.calibrate.plan_element_profile` (same meta,
    same unit model, same fused-scan sharing), keeping one row per
    physical operator instead of one total per kind."""
    phys = handle.phys
    table = handle.table
    meta = TableMeta(capacity=table.capacity,
                     bucket_size=table.bucket_size,
                     n_value_cols=len(table.schema.value_cols),
                     has_preagg=table.preagg is not None)
    unit = CostModel()
    rows: List[Dict[str, Any]] = []
    fused = [g for g in phys.groups if g.impl == "fused"]
    n_fused = len(fused) or 1
    fused_el = 0.0
    for g in phys.groups:
        n_cols = max(1, len(g.plain_cols) + len(g.derived_args))
        share = n_fused if g.impl == "fused" else 1
        el = estimate_window_cost(g.spec, meta, impl=g.impl,
                                  n_cols=n_cols, needs_ts_scan=True,
                                  shared_scan=share, model=unit)
        if g.impl == "fused":
            fused_el += el
            continue
        kind = "preagg" if g.impl == "preagg" else "scan"
        rows.append({"op": f"{kind}:{g.name}", "kind": kind,
                     "elements": float(el), "table": None})
    if fused:
        label = "+".join(g.name for g in fused)
        rows.insert(0, {"op": f"scan:fused[{label}]", "kind": "scan",
                       "elements": float(fused_el), "table": None})
    engine = getattr(handle, "engine", None)
    tables = getattr(engine, "tables", {}) if engine is not None else {}
    for j in handle.plan.joins:
        right = tables.get(j.table)
        cap = right.capacity if right is not None else meta.capacity
        el = estimate_join_cost(cap, max(1, len(j.columns)),
                                assume_latest=True, model=unit)
        rows.append({"op": f"join:{j.table}", "kind": "join",
                     "elements": float(el), "table": j.table})
    return rows


def attribute_exec(rows: Sequence[Dict[str, Any]], model: CostModel,
                   exec_s: float) -> List[Dict[str, Any]]:
    """Split ``exec_s`` across operator rows proportionally to
    ``weight(kind) · elements`` under ``model``. Returns copies with a
    ``seconds`` field; the seconds sum to ``exec_s`` exactly."""
    weights = {"scan": model.scan_el, "preagg": model.preagg_el,
               "join": model.join_el}
    table_w = dict(getattr(model, "table_el", ()) or ())
    def w(r):
        base = weights.get(r["kind"], 1.0) * r["elements"]
        if r["kind"] == "join" and r["table"] in table_w:
            base *= table_w[r["table"]]
        return base
    total = sum(w(r) for r in rows)
    out = []
    for r in rows:
        share = (w(r) / total) if total > 0 else 0.0
        out.append({**r, "seconds": exec_s * share, "share": share})
    return out


class OperatorProfiler:
    """Per-deployment accumulator of measured, operator-attributed serve
    time — the data behind ``EXPLAIN ANALYZE`` and the calibrator's
    measured-per-operator observations.

    ``record()`` is called once per served batch with the batch's
    measured stage times; totals and a drainable interval accumulator
    advance together. All state is plain dicts so per-shard snapshots
    merge across a pickle boundary (:meth:`merge`).
    """

    def __init__(self):
        self._lock = threading.Lock()
        # (name) -> profile dict; "ops": op -> accumulated row
        self._totals: Dict[str, Dict[str, Any]] = {}
        # interval accumulator drained by the control plane
        self._interval: Dict[str, Dict[str, Any]] = {}
        # (name, version) -> operator rows (element profile is a pure
        # function of the compiled plan; never recompute per batch)
        self._rows_cache: Dict[Any, List[Dict[str, Any]]] = {}

    def rows_for(self, handle) -> List[Dict[str, Any]]:
        key = (handle.name, handle.version)
        rows = self._rows_cache.get(key)
        if rows is None:
            rows = self._rows_cache[key] = operator_rows(handle)
        return rows

    @staticmethod
    def _blank() -> Dict[str, Any]:
        return {"ops": {}, "requests": 0, "batches": 0, "exec_s": 0.0,
                "host_s": 0.0, "plan_s": 0.0, "serve_s": 0.0}

    def record(self, handle, n_requests: int, *, exec_s: float,
               host_s: float, plan_s: float, serve_s: float,
               model: CostModel) -> List[Dict[str, Any]]:
        """Accumulate one served batch; returns this batch's attributed
        operator rows (the engine turns them into kernel child spans)."""
        attributed = attribute_exec(self.rows_for(handle), model, exec_s)
        with self._lock:
            for acc in (self._totals.setdefault(handle.name,
                                                self._blank()),
                        self._interval.setdefault(handle.name,
                                                  self._blank())):
                acc["requests"] += int(n_requests)
                acc["batches"] += 1
                acc["exec_s"] += float(exec_s)
                acc["host_s"] += float(host_s)
                acc["plan_s"] += float(plan_s)
                acc["serve_s"] += float(serve_s)
                for r in attributed:
                    op = acc["ops"].setdefault(
                        r["op"], {"kind": r["kind"], "table": r["table"],
                                  "elements": r["elements"],
                                  "seconds": 0.0})
                    op["seconds"] += r["seconds"]
        return attributed

    # ----------------------------------------------------------- export
    def snapshot(self, name: str) -> Optional[Dict[str, Any]]:
        """Deep-copied totals for ``name`` (picklable; ``None`` if the
        deployment never served)."""
        with self._lock:
            acc = self._totals.get(name)
            if acc is None:
                return None
            out = {k: v for k, v in acc.items() if k != "ops"}
            out["ops"] = {op: dict(row)
                          for op, row in acc["ops"].items()}
            return out

    def deployments(self) -> List[str]:
        with self._lock:
            return sorted(self._totals)

    @staticmethod
    def merge(snapshots: Sequence[Optional[Dict[str, Any]]]
              ) -> Optional[Dict[str, Any]]:
        """Sum per-shard snapshots (counters add; per-op ``elements``
        stays per-request so it is maxed, not summed)."""
        live = [s for s in snapshots if s]
        if not live:
            return None
        out = OperatorProfiler._blank()
        for s in live:
            for k in ("requests", "batches", "exec_s", "host_s",
                      "plan_s", "serve_s"):
                out[k] += s.get(k, 0)
            for op, row in s.get("ops", {}).items():
                acc = out["ops"].setdefault(
                    op, {"kind": row["kind"], "table": row.get("table"),
                         "elements": 0.0, "seconds": 0.0})
                acc["seconds"] += row["seconds"]
                acc["elements"] = max(acc["elements"], row["elements"])
        return out

    # -------------------------------------------------------- calibrator
    def drain_observations(self, name: str) -> List[Dict[str, Any]]:
        """Pop the interval accumulator as calibrator observations:
        per kind ``(elements-per-request, attributed-seconds-per-
        request)``, plus per-table join splits. MEASURED exec time only —
        host/plan residuals never pollute the per-element fit the way the
        plane's old EM attribution (serve_s incl. host) did."""
        with self._lock:
            acc = self._interval.pop(name, None)
        if not acc or acc["requests"] <= 0:
            return []
        reqs = acc["requests"]
        by_kind: Dict[str, Dict[str, float]] = {}
        obs: List[Dict[str, Any]] = []
        for row in acc["ops"].values():
            k = by_kind.setdefault(row["kind"],
                                   {"elements": 0.0, "seconds": 0.0})
            k["elements"] += row["elements"]
            k["seconds"] += row["seconds"]
            if row["kind"] == "join" and row.get("table"):
                obs.append({"kind": "join", "table": row["table"],
                            "elements": row["elements"],
                            "seconds": row["seconds"] / reqs})
        for kind, k in by_kind.items():
            obs.append({"kind": kind, "table": None,
                        "elements": k["elements"],
                        "seconds": k["seconds"] / reqs})
        return obs

    # ------------------------------------------------------------ render
    @staticmethod
    def render(name: str, version: int, prof: Optional[Dict[str, Any]],
               *, n_shards: int = 1) -> str:
        """The ``EXPLAIN ANALYZE`` text block for one deployment."""
        hdr = f"EXPLAIN ANALYZE deployment {name!r} v{version}"
        if n_shards > 1:
            hdr += f" (merged across {n_shards} shards)"
        if not prof or prof["batches"] <= 0:
            return hdr + "\n  (no batches served yet)"
        B, reqs = prof["batches"], max(prof["requests"], 1)
        lines = [hdr,
                 f"  served: {prof['requests']} requests in {B} "
                 f"batch(es)",
                 f"  measured per batch: serve "
                 f"{prof['serve_s'] / B * 1e3:.3f} ms = exec "
                 f"{prof['exec_s'] / B * 1e3:.3f} + host "
                 f"{prof['host_s'] / B * 1e3:.3f} + plan "
                 f"{prof['plan_s'] / B * 1e3:.3f} (amortized)",
                 "  operators (measured exec time, attributed per "
                 "unit-cost element):"]
        ops = sorted(prof["ops"].items(),
                     key=lambda kv: -kv[1]["seconds"])
        exec_s = prof["exec_s"] or 1e-12
        width = max((len(op) for op, _ in ops), default=8)
        for op, row in ops:
            lines.append(
                f"    {op:<{width}}  el/req={row['elements']:>8.1f}  "
                f"{row['seconds'] / reqs * 1e6:>9.2f} us/req  "
                f"{row['seconds'] / exec_s * 100:5.1f}% of exec")
        lines.append(
            f"    {HOST_ROW:<{width}}  {'':>12}  "
            f"{prof['host_s'] / reqs * 1e6:>9.2f} us/req  (residual)")
        lines.append(
            f"    {PLAN_ROW:<{width}}  {'':>12}  "
            f"{prof['plan_s'] / reqs * 1e6:>9.2f} us/req  (amortized)")
        attributed = (sum(r["seconds"] for _, r in ops)
                      + prof["host_s"] + prof["plan_s"])
        lines.append(
            f"  attributed total {attributed / B * 1e3:.3f} ms/batch "
            f"vs measured serve {prof['serve_s'] / B * 1e3:.3f} ms/batch"
            f" ({attributed / max(prof['serve_s'], 1e-12) * 100:.1f}%)")
        return "\n".join(lines)
