"""Mergeable observability sketches (DESIGN.md §14).

The freshness/SLO tier needs percentiles that (a) use bounded memory no
matter how long a deployment serves, (b) merge EXACTLY across shards —
a process-backed shard ships its sketch over a pickle boundary and the
parent must recover the same percentile a single engine would have
computed — and (c) are deterministic, so two runs over the same stream
agree bit for bit.

:class:`QuantileSketch` is a DDSketch-style log-bucketed quantile
sketch: a value ``v > 0`` lands in bucket ``ceil(log(v)/log(gamma))``
with ``gamma = (1+a)/(1-a)`` for relative error ``a``, negatives mirror
into their own bucket map, and near-zeros collapse into a dedicated
zero bucket. Buckets hold integer counts, so merging is integer
addition — exact, associative, and commutative — and any quantile is
recovered within relative error ``a`` by walking the buckets in value
order. Every observation (scalar included) routes through ONE
vectorized ``np.log`` path so scalar-vs-batch bucketing can never
diverge in the last ulp: equal value multisets produce equal sketches,
which is what makes the cross-shard-merged p99 bit-identical to the
single-engine p99 (tests/test_freshness.py).

:class:`RollingSketch` bounds RECENCY as well as memory: two pane
sketches rotate every ``window_s``, queries merge both panes. It
replaces the fixed-length deque reservoirs in ``HandleMetrics`` and the
batcher — those were bounded in samples (stale forever at low traffic);
this is bounded in time.

:class:`CardinalityEstimator` is a k-minimum-values distinct counter
over splitmix64 hashes (exact below k, unbiased ``(k-1)/h_k`` above,
merge = union-then-truncate). :func:`psi_distance` +
:class:`DriftMonitor` turn per-column sketches into an online/offline
feature-skew detector (population stability index over the aligned log
buckets two same-``rel_err`` sketches share by construction).
"""
from __future__ import annotations

import json
import math
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["QuantileSketch", "RollingSketch", "CardinalityEstimator",
           "psi_distance", "DriftMonitor", "ZERO_EPS", "DEFAULT_REL_ERR"]

# |v| below this collapses into the zero bucket (log of a denormal would
# otherwise mint an absurdly-negative bucket index)
ZERO_EPS = 1e-12
DEFAULT_REL_ERR = 0.01


class QuantileSketch:
    """Deterministic log-bucketed quantile sketch with exact merge.

    Thread-safe; all mutation and query methods take the internal lock.
    ``sum`` is tracked for mean/export convenience but is NOT part of the
    bit-for-bit contract (float addition is not associative across merge
    orders) — quantiles, counts, min and max are.
    """

    __slots__ = ("rel_err", "gamma", "_log_gamma", "pos", "neg", "zero",
                 "count", "sum", "vmin", "vmax", "_lock")

    def __init__(self, rel_err: float = DEFAULT_REL_ERR):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        self.rel_err = float(rel_err)
        self.gamma = (1.0 + self.rel_err) / (1.0 - self.rel_err)
        self._log_gamma = math.log(self.gamma)
        self.pos: Dict[int, int] = {}
        self.neg: Dict[int, int] = {}
        self.zero = 0
        self.count = 0
        self.sum = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._lock = threading.Lock()

    # ------------------------------------------------------------- observe
    def observe(self, value: float) -> int:
        """Observe one value (routed through the vectorized path — see
        module docstring for why there is no scalar fast path)."""
        return self.observe_many((value,))

    def observe_many(self, values) -> int:
        """Observe a batch; returns how many finite values were added
        (NaN/inf are skipped, they have no bucket)."""
        v = np.asarray(values, np.float64).ravel()
        if v.size == 0:
            return 0
        v = v[np.isfinite(v)]
        if v.size == 0:
            return 0
        with self._lock:
            self.count += int(v.size)
            self.sum += float(v.sum())
            self.vmin = min(self.vmin, float(v.min()))
            self.vmax = max(self.vmax, float(v.max()))
            neg = v < -ZERO_EPS
            pos = v > ZERO_EPS
            self.zero += int(v.size - int(neg.sum()) - int(pos.sum()))
            for store, part in ((self.pos, v[pos]), (self.neg, -v[neg])):
                if not part.size:
                    continue
                idx = np.ceil(np.log(part)
                              / self._log_gamma).astype(np.int64)
                if part.size <= 512:
                    # small batches (the per-serve path): a plain dict
                    # loop beats np.unique's sort + two array round trips
                    for i in idx.tolist():
                        store[i] = store.get(i, 0) + 1
                else:
                    uniq, cnt = np.unique(idx, return_counts=True)
                    for i, c in zip(uniq.tolist(), cnt.tolist()):
                        store[i] = store.get(i, 0) + c
        return int(v.size)

    # ------------------------------------------------------------- queries
    def _rep(self, idx: int) -> float:
        """Representative value of positive bucket ``idx`` (midpoint of
        ``(gamma^(idx-1), gamma^idx]`` in relative terms)."""
        return 2.0 * self.gamma ** idx / (self.gamma + 1.0)

    def _clip(self, v: float) -> float:
        # observed extremes bound every representative: the p0/p100 of a
        # sketch are the true min/max, and merged extremes are exact
        return max(self.vmin, min(self.vmax, v))

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1]; NaN when empty."""
        with self._lock:
            if self.count == 0:
                return float("nan")
            q = min(max(q, 0.0), 1.0)
            rank = q * (self.count - 1)
            acc = 0
            for i in sorted(self.neg, reverse=True):  # most negative first
                acc += self.neg[i]
                if acc > rank:
                    return self._clip(-self._rep(i))
            acc += self.zero
            if acc > rank:
                return self._clip(0.0)
            for i in sorted(self.pos):
                acc += self.pos[i]
                if acc > rank:
                    return self._clip(self._rep(i))
            return self.vmax

    def percentile(self, pct: float) -> float:
        """``quantile(pct / 100)`` — drop-in for ``np.percentile``."""
        return self.quantile(pct / 100.0)

    def mean(self) -> float:
        with self._lock:
            return self.sum / self.count if self.count else float("nan")

    def __len__(self) -> int:
        return self.count

    def __bool__(self) -> bool:            # empty sketch is falsy, like
        return self.count > 0              # the deques it replaces

    @property
    def n_buckets(self) -> int:
        with self._lock:
            return len(self.pos) + len(self.neg) + (1 if self.zero else 0)

    # --------------------------------------------------------------- merge
    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into ``self`` (exact: integer bucket adds).
        Accepts a sketch or a ``to_dict()`` snapshot."""
        data = other if isinstance(other, dict) else other.to_dict()
        if abs(data["rel_err"] - self.rel_err) > 1e-12:
            raise ValueError(
                f"cannot merge sketches with different rel_err "
                f"({data['rel_err']} vs {self.rel_err})")
        with self._lock:
            for i, c in data["pos"]:
                self.pos[int(i)] = self.pos.get(int(i), 0) + int(c)
            for i, c in data["neg"]:
                self.neg[int(i)] = self.neg.get(int(i), 0) + int(c)
            self.zero += int(data["zero"])
            self.count += int(data["count"])
            self.sum += float(data["sum"])
            self.vmin = min(self.vmin, float(data["min"]))
            self.vmax = max(self.vmax, float(data["max"]))
        return self

    @classmethod
    def merged(cls, sketches: Sequence) -> "QuantileSketch":
        """New sketch = exact merge of ``sketches`` (sketches or
        ``to_dict()`` snapshots; empties and ``None`` are skipped)."""
        live = [s for s in sketches if s is not None]
        rel = None
        for s in live:
            rel = s["rel_err"] if isinstance(s, dict) else s.rel_err
            break
        out = cls(rel_err=rel if rel is not None else DEFAULT_REL_ERR)
        for s in live:
            out.merge(s)
        return out

    # ----------------------------------------------------------- serialize
    def to_dict(self) -> Dict[str, Any]:
        """Picklable/JSON-able snapshot; bucket lists are index-sorted, so
        equal sketches serialize identically regardless of observation
        order (deterministic-serialization test)."""
        with self._lock:
            return {
                "kind": "qsketch", "rel_err": self.rel_err,
                "count": self.count, "zero": self.zero, "sum": self.sum,
                "min": self.vmin, "max": self.vmax,
                "pos": sorted([int(i), int(c)]
                              for i, c in self.pos.items()),
                "neg": sorted([int(i), int(c)]
                              for i, c in self.neg.items()),
            }

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "QuantileSketch":
        out = cls(rel_err=float(data["rel_err"]))
        out.merge(dict(data))
        return out

    def to_bytes(self) -> bytes:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":")).encode()

    @classmethod
    def from_bytes(cls, blob: bytes) -> "QuantileSketch":
        return cls.from_dict(json.loads(blob.decode()))

    @staticmethod
    def is_sketch_dict(v) -> bool:
        return isinstance(v, dict) and v.get("kind") == "qsketch"

    def histogram(self) -> List[Tuple[float, int]]:
        """Cumulative ``(upper_bound, count_le)`` pairs in ascending bound
        order — the native-histogram exposition for Prometheus. The last
        pair's count equals ``count``."""
        with self._lock:
            neg = sorted(self.neg.items(), reverse=True)
            pos = sorted(self.pos.items())
            zero, g = self.zero, self.gamma
        out: List[Tuple[float, int]] = []
        acc = 0
        for i, c in neg:       # bucket -(g^(i-1), g^i] has upper -g^(i-1)
            acc += c
            out.append((-(g ** (i - 1)), acc))
        if zero:
            acc += zero
            out.append((ZERO_EPS, acc))
        for i, c in pos:
            acc += c
            out.append((g ** i, acc))
        return out

    def __repr__(self) -> str:
        return (f"QuantileSketch(rel_err={self.rel_err}, n={self.count}, "
                f"buckets={self.n_buckets})")


class RollingSketch:
    """Two-pane rotating :class:`QuantileSketch` — recency-bounded
    percentiles in bounded memory.

    The current pane accumulates observations; every ``window_s`` it
    becomes the previous pane and a fresh one opens, so a percentile
    query (which merges both panes) reflects between ``window_s`` and
    ``2·window_s`` of history. This replaces the fixed-length deque
    reservoirs: those displaced by SAMPLE count, which at low traffic
    kept stale outliers alive indefinitely; panes displace by TIME.

    ``len()`` is the MONOTONIC total observed (it never rotates away) —
    the replan health gate counts batches-since-swap with it, exactly
    what the old ``len(deque)`` provided while the reservoir filled.
    """

    __slots__ = ("rel_err", "window_s", "_clock", "_cur", "_prev",
                 "_start", "total", "_lock")

    def __init__(self, rel_err: float = DEFAULT_REL_ERR,
                 window_s: float = 2.0,
                 clock: Callable[[], float] = time.monotonic):
        self.rel_err = float(rel_err)
        self.window_s = float(window_s)
        self._clock = clock
        self._cur = QuantileSketch(rel_err)
        self._prev = QuantileSketch(rel_err)
        self._start = clock()
        self.total = 0
        self._lock = threading.Lock()

    def _rotate_locked(self, now: float) -> None:
        dt = now - self._start
        if dt < self.window_s:
            return
        if dt < 2.0 * self.window_s:
            self._prev = self._cur
        else:                              # idle past both panes
            self._prev = QuantileSketch(self.rel_err)
        self._cur = QuantileSketch(self.rel_err)
        self._start = now

    def observe(self, value: float) -> None:
        self.observe_many((value,))

    def observe_many(self, values) -> int:
        now = self._clock()
        with self._lock:
            self._rotate_locked(now)
            n = self._cur.observe_many(values)
            self.total += n
        return n

    def sketch(self) -> QuantileSketch:
        """Merged copy of both panes (what exports/merges see)."""
        now = self._clock()
        with self._lock:
            self._rotate_locked(now)
            panes = (self._prev, self._cur)
        return QuantileSketch.merged(panes)

    def percentile(self, pct: float) -> float:
        """Percentile over the rolling window; NaN when empty."""
        return self.sketch().percentile(pct)

    def quantile(self, q: float) -> float:
        return self.sketch().quantile(q)

    def window_count(self) -> int:
        """Samples currently inside the rolling window."""
        now = self._clock()
        with self._lock:
            self._rotate_locked(now)
            return self._prev.count + self._cur.count

    def clear(self) -> None:
        """Drop all history (panes AND the monotonic total) — same
        contract as ``deque.clear()`` on the reservoirs this replaces."""
        with self._lock:
            self._cur = QuantileSketch(self.rel_err)
            self._prev = QuantileSketch(self.rel_err)
            self._start = self._clock()
            self.total = 0

    def __len__(self) -> int:
        return self.total

    def __bool__(self) -> bool:
        return self.total > 0

    def __repr__(self) -> str:
        return (f"RollingSketch(window_s={self.window_s}, "
                f"total={self.total}, in_window={self.window_count()})")


# --------------------------------------------------------- cardinality
_SM_GOLD = np.uint64(0x9E3779B97F4B7C15)
_SM_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_M2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer over uint64 (wrapping math)."""
    z = x + _SM_GOLD
    z = (z ^ (z >> np.uint64(30))) * _SM_M1
    z = (z ^ (z >> np.uint64(27))) * _SM_M2
    return z ^ (z >> np.uint64(31))


class CardinalityEstimator:
    """k-minimum-values distinct-key counter (exact below ``k``).

    Keeps the ``k`` smallest splitmix64 hashes seen; with the hash space
    normalized to [0, 1), the kth minimum ``h_k`` estimates density and
    ``(k-1)/h_k`` the distinct count. Merge = union then truncate — the
    same invariant a single estimator over the union would hold, so
    cross-shard merges are exact in distribution.
    """

    __slots__ = ("k", "_kmv", "_lock")

    def __init__(self, k: int = 256):
        self.k = int(k)
        self._kmv: set = set()
        self._lock = threading.Lock()

    @staticmethod
    def _hash(keys) -> np.ndarray:
        arr = np.asarray(keys)
        if arr.size == 0:
            return np.zeros((0,), np.uint64)
        if arr.dtype.kind not in "iu":
            # non-integer keys: stable content hash (NOT Python's salted
            # hash() — shards in different processes must agree)
            arr = np.asarray([zlib.crc32(repr(k).encode())
                              for k in arr.ravel().tolist()], np.uint64)
        return _splitmix64(arr.astype(np.uint64, copy=False).ravel())

    def add(self, key) -> None:
        self.add_many((key,))

    def add_many(self, keys) -> None:
        h = self._hash(keys)
        if h.size == 0:
            return
        with self._lock:
            self._kmv.update(h.tolist())
            if len(self._kmv) > 4 * self.k:
                self._truncate_locked()

    def _truncate_locked(self) -> None:
        if len(self._kmv) > self.k:
            self._kmv = set(sorted(self._kmv)[:self.k])

    def estimate(self) -> float:
        with self._lock:
            self._truncate_locked()
            mv = sorted(self._kmv)
        if not mv:
            return 0.0
        if len(mv) < self.k:
            return float(len(mv))
        return (self.k - 1) * 2.0 ** 64 / float(mv[-1])

    def merge(self, other) -> "CardinalityEstimator":
        data = other if isinstance(other, dict) else other.to_dict()
        with self._lock:
            self._kmv.update(int(h) for h in data["kmv"])
            self._truncate_locked()
        return self

    def to_dict(self) -> Dict[str, Any]:
        with self._lock:
            self._truncate_locked()
            return {"kind": "kmv", "k": self.k,
                    "kmv": sorted(self._kmv)}

    @classmethod
    def from_dict(cls, data: Mapping[str, Any]) -> "CardinalityEstimator":
        out = cls(k=int(data["k"]))
        out.merge(dict(data))
        return out

    def __repr__(self) -> str:
        return f"CardinalityEstimator(k={self.k}, est={self.estimate():.0f})"


# ----------------------------------------------------------------- drift
def _bucket_fracs(d: Mapping[str, Any]) -> Dict[Tuple[str, int], float]:
    total = float(d["count"]) or 1.0
    out: Dict[Tuple[str, int], float] = {}
    for i, c in d["neg"]:
        out[("n", int(i))] = c / total
    if d["zero"]:
        out[("z", 0)] = d["zero"] / total
    for i, c in d["pos"]:
        out[("p", int(i))] = c / total
    return out


def psi_distance(ref, live, *, eps: float = 1e-4) -> float:
    """Population stability index between two same-``rel_err`` sketches.

    Log buckets with equal gamma are ALIGNED bins by construction, so no
    re-binning step is needed — PSI is summed over the union of occupied
    buckets with ``eps`` smoothing for empty cells. Conventional reading:
    < 0.1 stable, 0.1–0.25 moderate shift, > 0.25 drifted. NaN when
    either side is empty (no distribution to compare).
    """
    rd = ref if isinstance(ref, dict) else ref.to_dict()
    ld = live if isinstance(live, dict) else live.to_dict()
    if abs(rd["rel_err"] - ld["rel_err"]) > 1e-12:
        raise ValueError("PSI needs equal rel_err (aligned buckets), got "
                         f"{rd['rel_err']} vs {ld['rel_err']}")
    if rd["count"] == 0 or ld["count"] == 0:
        return float("nan")
    p = _bucket_fracs(rd)
    q = _bucket_fracs(ld)
    psi = 0.0
    for k in set(p) | set(q):
        pe = max(p.get(k, 0.0), eps)
        qe = max(q.get(k, 0.0), eps)
        psi += (qe - pe) * math.log(qe / pe)
    return psi


class DriftMonitor:
    """Online/offline feature-skew detector over per-column sketches.

    The serve path feeds the LIVE side (output feature columns, pad rows
    excluded); the reference side is either observed directly from an
    offline/training materialisation (:meth:`observe_reference`) or
    pinned from the current live window (:meth:`pin_reference` — e.g. at
    deploy time, "what serving looked like when the model shipped").
    :meth:`report` scores each column's live-vs-reference PSI. Snapshots
    are plain dicts so per-shard monitors merge across the worker RPC
    boundary exactly like the freshness sketches.
    """

    MAX_PENDING = 256        # serve batches buffered before a forced fold

    def __init__(self, rel_err: float = 0.02,
                 psi_threshold: float = 0.25):
        self.rel_err = float(rel_err)
        self.psi_threshold = float(psi_threshold)
        self._live: Dict[str, QuantileSketch] = {}
        self._ref: Dict[str, QuantileSketch] = {}
        # serve-path batches are BUFFERED (column-array references) and
        # folded into the live sketches lazily — on any read, or when
        # MAX_PENDING batches pile up. The hot path pays one list append
        # instead of a per-column sketch insert; fold order can't change
        # the result (sketch insertion is commutative).
        self._pending: List[Tuple[Mapping[str, Any], Optional[int]]] = []
        self._lock = threading.Lock()

    def _store(self, store: Dict[str, QuantileSketch],
               columns: Mapping[str, Any], n: Optional[int]) -> None:
        for name, vals in columns.items():
            if name.startswith("__"):       # hidden/meta columns
                continue
            with self._lock:
                sk = store.get(name)
                if sk is None:
                    sk = store[name] = QuantileSketch(self.rel_err)
            arr = np.asarray(vals)
            sk.observe_many(arr[:n] if n is not None else arr)

    def _drain(self) -> None:
        """Fold every buffered serve batch into the live sketches."""
        with self._lock:
            if not self._pending:
                return
            pending, self._pending = self._pending, []
        for cols, n in pending:
            self._store(self._live, cols, n)

    def observe(self, columns: Mapping[str, Any],
                n: Optional[int] = None) -> None:
        """Feed served feature columns into the live side (``n`` caps to
        the first n rows — lane edge-pad rows must not skew the
        distribution). O(1) on the serve path: the batch is buffered and
        folded on the next read (or after MAX_PENDING batches)."""
        with self._lock:
            self._pending.append((columns, n))
            full = len(self._pending) >= self.MAX_PENDING
        if full:
            self._drain()

    def observe_reference(self, columns: Mapping[str, Any],
                          n: Optional[int] = None) -> None:
        self._store(self._ref, columns, n)

    def pin_reference(self) -> List[str]:
        """Adopt the current live window as the reference and restart
        live accumulation; returns the pinned column names."""
        self._drain()
        with self._lock:
            self._ref = self._live
            self._live = {}
            return sorted(self._ref)

    def psi(self, column: str) -> float:
        self._drain()
        with self._lock:
            ref = self._ref.get(column)
            live = self._live.get(column)
        if ref is None or live is None:
            return float("nan")
        return psi_distance(ref, live)

    def columns(self) -> List[str]:
        self._drain()
        with self._lock:
            return sorted(set(self._live) | set(self._ref))

    def report(self) -> Dict[str, Dict[str, float]]:
        self._drain()
        out: Dict[str, Dict[str, float]] = {}
        for col in self.columns():
            with self._lock:
                ref = self._ref.get(col)
                live = self._live.get(col)
            psi = (psi_distance(ref, live)
                   if ref is not None and live is not None
                   else float("nan"))
            out[col] = {
                "psi": psi,
                "drifted": bool(psi > self.psi_threshold)
                if math.isfinite(psi) else False,
                "live_count": live.count if live is not None else 0,
                "ref_count": ref.count if ref is not None else 0,
            }
        return out

    def max_psi(self) -> float:
        """Worst finite column PSI (NaN if nothing is comparable) — the
        scalar the SLO engine watches."""
        vals = [r["psi"] for r in self.report().values()
                if math.isfinite(r["psi"])]
        return max(vals) if vals else float("nan")

    def export(self) -> Dict[str, float]:
        """Flat metrics for the registry ``drift`` group."""
        out: Dict[str, float] = {}
        for col, r in self.report().items():
            out[f"{col}/psi"] = r["psi"]
            out[f"{col}/drifted"] = 1.0 if r["drifted"] else 0.0
            out[f"{col}/live_count"] = float(r["live_count"])
            out[f"{col}/ref_count"] = float(r["ref_count"])
        return out

    # ------------------------------------------------------ shard merging
    def snapshot(self) -> Dict[str, Any]:
        self._drain()
        with self._lock:
            live = dict(self._live)
            ref = dict(self._ref)
        return {"rel_err": self.rel_err,
                "psi_threshold": self.psi_threshold,
                "live": {c: s.to_dict() for c, s in live.items()},
                "ref": {c: s.to_dict() for c, s in ref.items()}}

    @classmethod
    def merge(cls, snapshots: Sequence[Optional[Mapping[str, Any]]]
              ) -> "DriftMonitor":
        """New monitor = exact per-column merge of per-shard snapshots."""
        live = [s for s in snapshots if s]
        rel = live[0]["rel_err"] if live else 0.02
        thr = live[0].get("psi_threshold", 0.25) if live else 0.25
        out = cls(rel_err=rel, psi_threshold=thr)
        for s in live:
            for side, store in (("live", out._live), ("ref", out._ref)):
                for col, d in s.get(side, {}).items():
                    sk = store.get(col)
                    if sk is None:
                        sk = store[col] = QuantileSketch(rel_err=rel)
                    sk.merge(dict(d))
        return out
