"""Relational tier: multi-table catalog + point-in-time LAST JOIN.

See DESIGN.md §8. The logical ``Join`` node lives in ``repro.core.logical``
(it is part of the plan IR); this package owns the table catalog the
optimizer validates joins against.
"""
from repro.relational.catalog import Catalog, CatalogEntry

__all__ = ["Catalog", "CatalogEntry"]
