"""Relational catalog: the engine's multi-table tier.

OpenMLDB's headline scenarios (fraud detection, personalized
recommendation) are multi-table: a request over a transactions stream is
enriched with the latest account-profile / merchant rows *as of the
request timestamp* via ``LAST JOIN`` (the system paper's signature
operator). The :class:`Catalog` is the registry that makes that safe:
every table the engine creates is registered together with its **declared
join keys**, and the optimizer validates each ``LAST JOIN`` against those
declarations before any plan is compiled — an undeclared probe column is
a deploy-time error, never a silent full scan.

A join key must resolve through the right table's key directory (the
device-resident hash index ``featurestore.keydir`` builds over the
table's partition key), so today the only declarable join key is the
table's ``key_col``. Secondary join-key indexes are a ROADMAP open item
("multi-key indexes"); declaring one fails loudly here instead of
degrading to a scan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence, Tuple

from repro.featurestore.table import Table, TableSchema

__all__ = ["Catalog", "CatalogEntry"]


@dataclass(frozen=True)
class CatalogEntry:
    """One joinable table: storage + the keys LAST JOIN may probe."""

    table: Table
    join_keys: Tuple[str, ...]

    @property
    def schema(self) -> TableSchema:
        return self.table.schema


class Catalog:
    """Name -> :class:`CatalogEntry` registry for the relational tier."""

    def __init__(self) -> None:
        self._entries: Dict[str, CatalogEntry] = {}

    def register(self, table: Table,
                 join_keys: Sequence[str] = ()) -> CatalogEntry:
        """Register ``table`` with its declared join keys.

        The table's partition key (``schema.key_col``) is always declared
        — it is the one column the key directory can probe. Additional
        join keys would need secondary indexes (ROADMAP: multi-key
        indexes) and are rejected until those exist.
        """
        name = table.schema.name
        if name in self._entries:
            raise ValueError(f"table {name!r} already in the catalog")
        extra = [k for k in join_keys if k != table.schema.key_col]
        if extra:
            raise ValueError(
                f"table {name!r}: secondary join key(s) {sorted(extra)} are "
                f"not supported yet — LAST JOIN probes resolve through the "
                f"table's key directory, which indexes only the partition "
                f"key {table.schema.key_col!r} (ROADMAP open item: "
                f"multi-key indexes)")
        entry = CatalogEntry(table=table,
                             join_keys=(table.schema.key_col,))
        self._entries[name] = entry
        return entry

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def get(self, name: str) -> CatalogEntry:
        entry = self._entries.get(name)
        if entry is None:
            raise KeyError(
                f"unknown table {name!r} in the relational catalog; "
                f"registered: {sorted(self._entries)} (create_table "
                f"registers tables automatically)")
        return entry

    def schema(self, name: str) -> TableSchema:
        return self.get(name).schema

    def join_keys(self, name: str) -> Tuple[str, ...]:
        return self.get(name).join_keys

    def tables(self) -> Tuple[str, ...]:
        return tuple(sorted(self._entries))
