"""Launchers: mesh construction, multi-pod dry-run, training and serving
drivers. ``python -m repro.launch.dryrun --help`` etc."""
