"""End-to-end serving driver: the paper's online mode, runnable on CPU.

Pipeline (paper Figure 4/5): synthetic event stream -> feature tables ->
deployed SQL window queries -> real-time feature vectors -> ML model
(logistic scorer by default; ``--decode`` adds LM token generation with a
reduced assigned architecture) — all behind the dynamic batcher.

Reports the paper's headline metrics: QPS, latency percentiles, and the
L = L_parse + L_plan + L_exec decomposition.

    PYTHONPATH=src python -m repro.launch.serve --requests 2000 --batch 64
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import get_config, list_archs
from repro.core.engine import Engine
from repro.core.optimizer import OptFlags
from repro.data.synthetic import (EventStreamConfig, generate_events,
                                  make_labels, request_stream)
from repro.featurestore.table import TableSchema
from repro.serving.batcher import BatcherConfig
from repro.serving.server import FeatureServer, ModelServer, ServerConfig

FEATURE_SQL = """
SELECT
  SUM(amount)  OVER w1 AS amt_sum_10,
  AVG(amount)  OVER w1 AS amt_avg_10,
  MAX(amount)  OVER w1 AS amt_max_10,
  COUNT(amount) OVER w1 AS txn_cnt_10,
  STD(amount)  OVER w1 AS amt_std_10,
  AVG(lat)     OVER w2 AS lat_avg_100,
  AVG(lon)     OVER w2 AS lon_avg_100,
  MIN(amount)  OVER w2 AS amt_min_100,
  MAX(amount)  OVER w2 AS amt_max_100,
  LAST(amount) OVER w1 AS amt_last
FROM events
WINDOW w1 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 10 PRECEDING AND CURRENT ROW),
       w2 AS (PARTITION BY user ORDER BY ts
              ROWS BETWEEN 100 PRECEDING AND CURRENT ROW)
"""


def build_engine(n_events: int, n_keys: int, *,
                 flags: OptFlags = OptFlags()) -> Engine:
    eng = Engine(flags)
    schema = TableSchema("events", key_col="user", ts_col="ts",
                         value_cols=("amount", "lat", "lon", "cat",
                                     "drift", "drift2"))
    eng.create_table(schema, max_keys=n_keys, capacity=1024, bucket_size=64)
    ev = EventStreamConfig(n_events=n_events, n_keys=n_keys, n_features=6)
    keys, ts, rows = generate_events(ev)
    eng.insert("events", keys.tolist(), ts.tolist(), rows)
    eng.deploy("fraud_features", FEATURE_SQL)
    return eng


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=64,
                    help="client-side request batch size")
    ap.add_argument("--events", type=int, default=20000)
    ap.add_argument("--keys", type=int, default=256)
    ap.add_argument("--decode", action="store_true",
                    help="also run LM decode on top of the features")
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    eng = build_engine(args.events, args.keys)
    ev = EventStreamConfig(n_events=args.events, n_keys=args.keys)
    keys, ts, rows = generate_events(ev)

    # ---- warm the plan cache (paper: compile charged to first request) ----
    warm = eng.request("fraud_features", keys[:args.batch].tolist(),
                       (ts[:args.batch] + 1e4).tolist())
    n_feat = len(warm)

    # ---- replay the online workload ---------------------------------------
    lat: List[float] = []
    n_served = 0
    t_start = time.perf_counter()
    for ks, rts in request_stream(keys, ts, batch=args.batch,
                                  n_batches=args.requests // args.batch):
        t0 = time.perf_counter()
        out = eng.request("fraud_features", ks.tolist(), rts.tolist())
        lat.append(time.perf_counter() - t0)
        n_served += len(ks)
    wall = time.perf_counter() - t_start
    lat_ms = np.asarray(lat) * 1e3 / args.batch      # per request amortised
    batch_ms = np.asarray(lat) * 1e3

    report = {
        "qps": n_served / wall,
        "latency_ms_per_request_p50": float(np.percentile(lat_ms, 50)),
        "latency_ms_per_batch_p50": float(np.percentile(batch_ms, 50)),
        "latency_ms_per_batch_p99": float(np.percentile(batch_ms, 99)),
        "n_features": n_feat,
        "decomposition": eng.latency_decomposition(),
    }

    if args.decode:
        cfg = reduced(get_config(args.arch))
        params = None
        from repro.launch.steps import init_params
        params = init_params(jax.random.PRNGKey(0), cfg)
        srv = ModelServer(cfg, params, batch=8, cache_len=64)
        prompt = np.ones((4, 8), np.int32)
        slots = srv.prefill(prompt)
        t0 = time.perf_counter()
        srv.decode(steps=16)
        report["decode_tokens_per_s"] = 4 * 16 / (time.perf_counter() - t0)
        srv.release(slots)

    print(json.dumps(report, indent=2))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(report, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
