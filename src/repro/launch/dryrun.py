import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, and extract the roofline terms.

MUST keep the two lines above as the very first statements — jax locks the
device count on first init, and the production meshes need 512 host
placeholder devices. (That is also why this module must never be imported
by tests/benches: run it as ``python -m repro.launch.dryrun``.)

Per cell this script reports (EXPERIMENTS.md §Dry-run / §Roofline):

* ``memory_analysis()`` — per-device argument/output/temp bytes (fits?),
* ``cost_analysis()``   — per-device HLO FLOPs + bytes accessed,
* collective bytes      — parsed from the compiled HLO: summed operand
  sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute ops,
* the three roofline terms vs TPU v5e constants
  (197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI),
* MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) and the useful-compute ratio.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k \
        --mesh pod,multipod --out experiments/dryrun
    python -m repro.launch.dryrun --all   # every cell, both meshes
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import SHAPES, ModelConfig, param_count
from repro.configs.registry import get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step_bundle
from repro.optim.adamw import AdamWConfig

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e, per chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (conservative single-link)

# HLO dtype byte widths for collective-bytes parsing
_DT = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
       "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
       "f64": 8, "c64": 8, "c128": 16}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _parse_operand_bytes(op_args: str) -> int:
    """Sum byte sizes of 'f32[128,512], bf16[4]{0}' style operand lists."""
    total = 0
    for m in _SHAPE_RE.finditer(op_args):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT[dt]
    return total


def collective_bytes(hlo: str) -> Dict[str, int]:
    """Per-collective summed operand bytes from compiled HLO text."""
    out = {k: 0 for k in _COLL}
    for line in hlo.splitlines():
        s = line.strip()
        # e.g. '%ag = bf16[8,128]{1,0} all-gather(%x), replica_groups=...'
        m = re.search(r"=\s*([^=]*?)\s+(all-gather|all-reduce|"
                      r"reduce-scatter|all-to-all|collective-permute)"
                      r"(?:-start|-done)?\(", s)
        if not m:
            continue
        kind = m.group(2)
        if "-done(" in s:
            continue                       # count start, not done
        # operands are inside the call parens; take text after '('
        args = s[s.index("(", m.start(2)):]
        # operand tuple may reference named values without shapes; fall back
        # to the RESULT shape (for all-reduce in==out; for all-gather the
        # result overcounts by world/size — use operands when present).
        opb = _parse_operand_bytes(args)
        if opb == 0:
            opb = _parse_operand_bytes(m.group(1))
        out[kind] += opb
    return out


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    ss = SHAPES[shape]
    if shape == "long_500k" and not cfg.long_context_ok:
        return False, ("pure full-attention arch: no sub-quadratic path "
                       "for 500k decode (DESIGN.md §Arch-applicability)")
    return True, ""


def _compile_once(cfg: ModelConfig, shape: str, mesh, *, accum: int,
                  compress: bool) -> Tuple[Any, Any, float, float]:
    """Lower+compile one config on one mesh -> (compiled, bundle, t_l, t_c)."""
    t0 = time.time()
    bundle = build_step_bundle(cfg, shape, mesh, opt_cfg=AdamWConfig(),
                               accum=accum, compress_crosspod=compress)

    def to_named(specs):
        return jax.tree_util.tree_map(
            lambda s: jax.NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))

    kw = {}
    if bundle.out_specs is not None:
        kw["out_shardings"] = to_named(bundle.out_specs)
    jf = jax.jit(bundle.fn, in_shardings=to_named(bundle.in_specs),
                 donate_argnums=bundle.donate, **kw)
    with mesh:
        lowered = jf.lower(*bundle.arg_structs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, bundle, t_lower, t_compile


def _costs(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": dict(coll)}


def run_cell(arch: str, shape: str, mesh_kind: str, *,
             accum: Optional[int] = None,
             remat: Optional[str] = None,
             compress: bool = False,
             measure: bool = True,
             attn_block: int = 0,
             rules_name: str = "default") -> Dict[str, Any]:
    """Lower + compile one cell; return the report dict.

    Compiles the FULL config (the multi-pod runnability proof + memory
    analysis), then — because XLA cost_analysis counts ``while``-loop
    bodies once, not per trip — compiles m=2 and m=4 layer-period variants
    at accum=1 and extrapolates ``cost(R) = base + R*layer`` to the full
    depth for the roofline terms (see EXPERIMENTS.md §Methodology).
    """
    from repro.configs.base import scale_layers
    cfg = get_config(arch)
    ok, why = applicable(cfg, shape)
    cell = {"arch": arch, "shape": shape, "mesh": mesh_kind,
            "rules": rules_name, "status": "skip", "skip_reason": why}
    if not ok:
        return cell

    ss = SHAPES[shape]
    if remat is None:
        remat = "full" if ss.kind == "train" else "none"
    if accum is None:
        # keep per-microbatch tokens <= 64k tokens/device-row to bound
        # activation memory on the big archs
        accum = 1
        if ss.kind == "train":
            accum = {"jamba-v0.1-52b": 8, "mixtral-8x22b": 8,
                     "starcoder2-7b": 4, "qwen2-vl-7b": 4,
                     "phi4-mini-3.8b": 4, "granite-moe-3b-a800m": 2,
                     }.get(arch, 2)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    from repro.distributed.sharding import dp_axes
    dp = dp_axes(mesh)
    import numpy as _np
    dp_n = int(_np.prod([mesh.shape[a] for a in dp]))
    act_dp = tuple(dp) if (attn_block and ss.global_batch % dp_n == 0) else ()
    act_sp = "model" if (act_dp and ss.kind in ("train", "prefill")
                         and ss.seq_len % mesh.shape["model"] == 0) else None
    # local MoE dispatch groups aligned with DP shards (only useful when
    # streaming/opt mode is on, and only when the batch divides)
    moe_groups = dp_n if (attn_block and cfg.moe is not None
                          and ss.global_batch % dp_n == 0) else 0
    cfg = dataclasses.replace(cfg, remat=remat, attn_block_k=attn_block,
                              act_dp=act_dp, act_sp=act_sp,
                              moe_groups=moe_groups)
    n_chips = int(np.prod(list(mesh.shape.values())))

    # ---- 1. full-config compile: the runnability proof --------------------
    compiled, bundle, t_lower, t_compile = _compile_once(
        cfg, shape, mesh, accum=accum, compress=compress)
    ma = compiled.memory_analysis()
    raw = _costs(compiled)

    # ---- 2. cost extrapolation over layer depth ---------------------------
    # XLA cost_analysis counts ``while`` bodies once, so the scanned full
    # model underreports by ~R×. Measure UNROLLED (scan_layers=False)
    # 1-period and 2-period models at accum=1 and extrapolate
    # cost(R) = base + R*layer. Unrolled small models compile in seconds;
    # per-layer shapes (and hence per-layer cost) equal the full model's.
    R_full = cfg.n_layers // len(cfg.pattern)
    if measure:
        small = dataclasses.replace(cfg, scan_layers=False)
        c1 = _costs(_compile_once(scale_layers(small, 1), shape, mesh,
                                  accum=1, compress=compress)[0])
        c2 = _costs(_compile_once(scale_layers(small, 2), shape, mesh,
                                  accum=1, compress=compress)[0])

        def extrap(v1: float, v2: float) -> float:
            layer = v2 - v1
            return max(v1 + (R_full - 1) * layer, 0.0)

        flops_dev = extrap(c1["flops"], c2["flops"])
        bytes_dev = extrap(c1["bytes"], c2["bytes"])
        coll = {k: extrap(c1["coll"][k], c2["coll"][k]) for k in _COLL}
        measure_mode = "unrolled-extrapolated(m1,m2)"
    else:
        flops_dev, bytes_dev, coll = raw["flops"], raw["bytes"], raw["coll"]
        measure_mode = "raw-scanned(underreports R x)"
    coll_total = sum(coll.values())

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_total / ICI_BW

    n_params = param_count(cfg)
    n_active = param_count(cfg, active=True)
    if ss.kind == "train":
        tokens = ss.global_batch * ss.seq_len
        model_flops = 6 * n_active * tokens
    elif ss.kind == "prefill":
        tokens = ss.global_batch * ss.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = ss.global_batch          # one new token per sequence
        model_flops = 2 * n_active * tokens
    model_flops_dev = model_flops / n_chips

    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])[0]

    cell.update({
        "status": "ok",
        "kind": ss.kind,
        "accum": accum,
        "remat": remat,
        "attn_block": attn_block,
        "n_chips": n_chips,
        "measure_mode": measure_mode,
        "raw_full_compile": raw,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_total,
        "collectives": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dom,
        "step_s_est": max(compute_s, memory_s, collective_s),
        "params": n_params,
        "params_active": n_active,
        "model_flops_per_device": model_flops_dev,
        "useful_flop_ratio": (model_flops_dev / flops_dev
                              if flops_dev else 0.0),
        "roofline_frac": (model_flops_dev / PEAK_FLOPS
                          / max(compute_s, memory_s, collective_s)
                          if max(compute_s, memory_s, collective_s) > 0
                          else 0.0),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
    })
    return cell


def cell_path(outdir: str, arch: str, shape: str, mesh_kind: str) -> str:
    return os.path.join(outdir, f"{arch}__{shape}__{mesh_kind}.json")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="shape cell (default: all)")
    ap.add_argument("--mesh", default="pod,multipod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--compress", action="store_true",
                    help="int8 cross-pod gradient all-reduce")
    ap.add_argument("--attn-block", type=int, default=0,
                    help="KV block for streaming attention (0=dense)")
    ap.add_argument("--force", action="store_true",
                    help="recompute cached cells")
    ap.add_argument("--rules", default="default")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = args.mesh.split(",")
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                p = cell_path(args.out, arch, shape, mk)
                if (os.path.exists(p) and not args.force
                        and args.rules == "default"):
                    print(f"[cached] {arch} {shape} {mk}")
                    continue
                tag = f"{arch} × {shape} × {mk}"
                try:
                    # roofline measurement on the single-pod mesh only;
                    # the multipod pass is the pod-axis sharding proof
                    cell = run_cell(arch, shape, mk, accum=args.accum,
                                    remat=args.remat,
                                    compress=args.compress,
                                    measure=(mk == "pod"),
                                    attn_block=args.attn_block,
                                    rules_name=args.rules)
                except Exception as e:
                    traceback.print_exc()
                    cell = {"arch": arch, "shape": shape, "mesh": mk,
                            "status": "fail", "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                if args.rules == "default":
                    with open(p, "w") as f:
                        json.dump(cell, f, indent=1)
                st = cell["status"]
                extra = ""
                if st == "ok":
                    extra = (f" dom={cell['dominant']}"
                             f" comp={cell['compute_s']:.3e}s"
                             f" mem={cell['memory_s']:.3e}s"
                             f" coll={cell['collective_s']:.3e}s"
                             f" useful={cell['useful_flop_ratio']:.2f}"
                             f" compile={cell['compile_s']:.0f}s")
                elif st == "fail":
                    extra = " " + cell.get("error", "")[:160]
                print(f"[{st}] {tag}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
