"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, while tests/benches must see the real single device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh as _make_mesh

__all__ = ["make_production_mesh", "make_local_mesh", "POD_SHAPE",
           "MULTIPOD_SHAPE"]

POD_SHAPE: Tuple[int, ...] = (16, 16)            # one v5e pod: 256 chips
MULTIPOD_SHAPE: Tuple[int, ...] = (2, 16, 16)    # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return _make_mesh((data, model), ("data", "model"))
