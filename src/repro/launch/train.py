"""End-to-end training driver (CPU-runnable with ``--reduced``).

Wires every substrate layer together: config -> model -> sharded
``train_step`` -> host data pipeline -> checkpoint manager -> supervisor.

Fault tolerance in the loop (the at-scale contract, exercised for real by
tests/test_train_driver.py):

* async atomic checkpoints every ``--ckpt-every`` steps, retention-K;
* NaN/divergence supervisor: non-finite steps are skipped in-step (zero
  update); after ``--max-bad-steps`` consecutive bad steps the driver
  rolls back to the last checkpoint and re-seeds the data stream past
  the bad batch;
* resume: ``--resume`` restarts from the latest checkpoint (elastic:
  the restore reshards onto whatever mesh the new run has).

Example:
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --reduced --steps 30 --batch 8 --seq 64 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, reduced
from repro.configs.registry import get_config, list_archs
from repro.data.pipeline import HostPipeline, PipelineConfig
from repro.data.synthetic import token_batch_stream
from repro.distributed.sharding import (DEFAULT_RULES, batch_specs,
                                        opt_specs, param_specs, tree_named)
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import init_params, make_train_step
from repro.models import frontend
from repro.optim.adamw import AdamWConfig, adamw_init

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Owns params/opt/step + checkpointing + the supervisor."""

    def __init__(self, cfg: ModelConfig, *, opt_cfg: AdamWConfig,
                 mesh=None, accum: int = 1, compress: bool = False,
                 ckpt_dir: Optional[str] = None, retain: int = 3,
                 seed: int = 0):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.mesh = mesh if mesh is not None else make_local_mesh()
        self.step = 0
        self.bad_streak = 0
        key = jax.random.PRNGKey(seed)
        with self.mesh:
            self.params = init_params(key, cfg)
            self.opt_state = adamw_init(self.params, opt_cfg)
        pspecs = param_specs(self.params, self.mesh)
        ospecs = opt_specs(self.opt_state, self.mesh)
        self.params = jax.device_put(self.params,
                                     tree_named(self.mesh, pspecs))
        self.opt_state = jax.device_put(self.opt_state,
                                        tree_named(self.mesh, ospecs))
        fn = make_train_step(cfg, opt_cfg, accum=accum, mesh=self.mesh,
                             compress_crosspod=compress)
        self.train_step = jax.jit(
            fn, in_shardings=(tree_named(self.mesh, pspecs),
                              tree_named(self.mesh, ospecs), None),
            donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(ckpt_dir, retain=retain)
                     if ckpt_dir else None)

    # ----------------------------------------------------------- checkpoint
    def save(self, block: bool = False) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step,
                       {"params": self.params, "opt": self.opt_state},
                       extra={"step": self.step}, block=block)

    def restore(self, step: Optional[int] = None) -> bool:
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        like = {"params": self.params, "opt": self.opt_state}
        tree, meta = self.ckpt.restore(step, like)
        pspecs = param_specs(tree["params"], self.mesh)
        ospecs = opt_specs(tree["opt"], self.mesh)
        self.params = jax.device_put(tree["params"],
                                     tree_named(self.mesh, pspecs))
        self.opt_state = jax.device_put(tree["opt"],
                                        tree_named(self.mesh, ospecs))
        self.step = int(meta.extra.get("step", meta.step))
        return True

    # ------------------------------------------------------------------ run
    def run(self, batches, *, steps: int, ckpt_every: int = 0,
            max_bad_steps: int = 3, log_every: int = 10,
            on_metrics=None) -> Dict[str, Any]:
        history = []
        it = iter(batches)
        t0 = time.perf_counter()
        while self.step < steps:
            batch = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            with self.mesh:
                self.params, self.opt_state, m = self.train_step(
                    self.params, self.opt_state, batch)
            m = {k: float(v) for k, v in m.items()}
            self.step += 1

            # --- supervisor ---------------------------------------------
            if m.get("skipped", 0.0) > 0 or not np.isfinite(m["loss"]):
                self.bad_streak += 1
                if self.bad_streak >= max_bad_steps and self.ckpt:
                    rolled = self.restore()
                    m["rolled_back"] = float(rolled)
                    self.bad_streak = 0
            else:
                self.bad_streak = 0

            if ckpt_every and self.step % ckpt_every == 0:
                self.save()
            if on_metrics:
                on_metrics(self.step, m)
            if log_every and self.step % log_every == 0:
                dt = time.perf_counter() - t0
                print(f"step {self.step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m.get('grad_norm', float('nan')):.3f} "
                      f"lr {m.get('lr', 0):.2e} {dt / log_every:.3f}s/step",
                      flush=True)
                t0 = time.perf_counter()
            history.append(m)
        if self.ckpt:
            self.ckpt.wait()
        return {"history": history, "final_loss": history[-1]["loss"]
                if history else float("nan")}


def make_batches(cfg: ModelConfig, *, batch: int, seq: int, seed: int,
                 pipeline: bool = True):
    gen = token_batch_stream(vocab=cfg.vocab_size, batch=batch, seq=seq,
                             seed=seed)
    raw = list(next(gen) for _ in range(8))     # cycled pool (deterministic)

    def add_extras(b, i):
        b = dict(b)
        if cfg.frontend:
            emb = frontend.stub_frontend(
                jax.random.PRNGKey(i), cfg, batch)
            b["embeds"] = np.asarray(emb, np.float32)
        if cfg.is_encdec:
            b["enc_embeds"] = np.asarray(frontend.stub_audio_frames(
                jax.random.PRNGKey(i), cfg, batch, n_frames=seq),
                np.float32)
        return b

    def producer(i):
        return add_extras(raw[i % len(raw)], i)

    if pipeline:
        return HostPipeline(producer, n_batches=None,
                            cfg=PipelineConfig(prefetch=2, n_workers=2))
    return (producer(i) for i in range(10 ** 9))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-runnable reduced config of the same family")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 3),
                          total_steps=args.steps)
    loop = TrainLoop(cfg, opt_cfg=opt_cfg, accum=args.accum,
                     compress=args.compress_grads, ckpt_dir=args.ckpt_dir,
                     seed=args.seed)
    if args.resume and loop.restore():
        print(f"resumed from step {loop.step}")
    batches = make_batches(cfg, batch=args.batch, seq=args.seq,
                           seed=args.seed)
    out = loop.run(batches, steps=args.steps, ckpt_every=args.ckpt_every)
    print(f"final loss {out['final_loss']:.4f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f)
    return 0


if __name__ == "__main__":
    sys.exit(main())
