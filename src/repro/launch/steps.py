"""Model-agnostic step builders: one place that turns a ``ModelConfig``
into jit-able ``train_step`` / ``prefill_step`` / ``serve_step`` functions
plus their input/parameter/optimizer/cache sharding specs.

Used by launch/train.py (real execution on the local mesh), launch/serve.py,
and launch/dryrun.py (lower+compile on the 512-device production meshes).

Conventions:

* ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
  with optional gradient accumulation (``accum`` microbatches via
  ``lax.scan``) and optional int8-compressed cross-pod gradient reduce.
* ``serve_step(params, caches, token, position[, enc_out])
  -> (logits, caches)`` — one decode token against the KV/SSM cache.
* ``prefill_step(params, tokens[, ...]) -> (last_logits, caches)``.
* Non-finite-gradient guard: a step whose global grad norm is non-finite
  applies a zero update (params/opt unchanged except the skip counter) —
  the at-scale "one bad host must not poison the run" rule.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map
from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, input_specs
from repro.distributed.sharding import (DEFAULT_RULES, ShardingRules,
                                        batch_specs, cache_specs_tree,
                                        dp_axes, opt_specs, param_specs)
from repro.models import encdec, lm
from repro.optim.adamw import (AdamWConfig, OptState, adamw_init,
                               adamw_update)

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "init_params", "params_struct", "opt_struct", "cache_struct",
           "StepBundle", "build_step_bundle"]


# ---------------------------------------------------------------------------
# Param / state structure helpers
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    if cfg.is_encdec:
        return encdec.init_encdec(key, cfg)
    return lm.init_lm(key, cfg)


def params_struct(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def opt_struct(cfg: ModelConfig, opt_cfg: AdamWConfig):
    ps = params_struct(cfg)
    return jax.eval_shape(lambda p: adamw_init(p, opt_cfg), ps)


def cache_struct(cfg: ModelConfig, batch: int, cache_len: int):
    if cfg.is_encdec:
        return jax.eval_shape(
            lambda: encdec.init_dec_cache(cfg, batch, cache_len))
    return jax.eval_shape(lambda: lm.init_cache(cfg, batch, cache_len))


def _loss_fn(cfg: ModelConfig):
    if cfg.is_encdec:
        return functools.partial(encdec.loss_fn)
    return functools.partial(lm.loss_fn)


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    accum: int = 1, mesh: Optional[Mesh] = None,
                    compress_crosspod: bool = False,
                    rules: ShardingRules = DEFAULT_RULES) -> Callable:
    """Builds ``train_step(params, opt_state, batch)``.

    ``accum > 1`` splits the leading batch dim into microbatches and scans,
    accumulating f32 gradients — memory drops ~accum-fold while FLOPs stay.
    ``compress_crosspod`` computes per-pod gradients under shard_map over
    the ``pod`` axis and reduces them with the int8 collective.
    """
    loss_fn = _loss_fn(cfg)

    def make_grads_of(cfg_):
        def grads_of(params, batch):
            if accum == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg_, batch)
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.float32), grads)
                return grads, loss, metrics

            def split(x):
                b = x.shape[0]
                return x.reshape((accum, b // accum) + x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                g_acc, l_acc = acc
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, cfg_, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            (g_sum, l_sum), metrics = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, g_sum)
            metrics = jax.tree_util.tree_map(lambda m: jnp.mean(m), metrics)
            return grads, l_sum / accum, metrics

        return grads_of

    grads_of = make_grads_of(cfg)

    def apply_update(params, opt_state, grads):
        new_p, new_s, om = adamw_update(grads, opt_state, params, opt_cfg)
        # non-finite guard: zero-out the update, keep the old state
        ok = jnp.isfinite(om.get("grad_norm", jnp.float32(0.0)))
        pick = lambda a, b: jnp.where(ok, a, b)
        new_p = jax.tree_util.tree_map(pick, new_p, params)
        new_s = jax.tree_util.tree_map(pick, new_s, opt_state)
        om["skipped"] = (~ok).astype(jnp.float32)
        return new_p, new_s, om

    if not compress_crosspod or mesh is None or "pod" not in mesh.axis_names:
        def train_step(params, opt_state, batch):
            grads, loss, metrics = grads_of(params, batch)
            params, opt_state, om = apply_update(params, opt_state, grads)
            metrics = dict(metrics)
            metrics.update(om)
            metrics["loss"] = loss
            return params, opt_state, metrics

        return train_step

    # ---- int8-compressed cross-pod variant --------------------------------
    from repro.distributed.collectives import psum_int8
    npods = mesh.shape["pod"]
    # inside shard_map the pod axis is Manual — activation pins may only
    # reference the auto axes
    cfg_local = dataclasses.replace(
        cfg, act_dp=tuple(a for a in cfg.act_dp if a != "pod"))
    grads_of_local = make_grads_of(cfg_local)

    def train_step(params, opt_state, batch):
        # per-pod grads: batch leading dim sharded over pod inside the
        # shard_map; data/model axes stay in auto (XLA) mode.
        bspec_in = jax.tree_util.tree_map(lambda _: P("pod"), batch)

        @functools.partial(
            _shard_map, mesh=mesh,
            in_specs=(P(), bspec_in), out_specs=P(),
            manual_axes={"pod"})
        def pod_grads(p, b):
            g, loss, metrics = grads_of_local(p, b)
            g = jax.tree_util.tree_map(
                lambda x: psum_int8(x, "pod") / npods, g)
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m, "pod"), metrics)
            return g, loss, metrics

        grads, loss, metrics = pod_grads(params, batch)
        params, opt_state, om = apply_update(params, opt_state, grads)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, cache_len: int) -> Callable:
    if cfg.is_encdec:
        def prefill_step(params, enc_embeds, tokens):
            enc_out = encdec.encode(params, cfg, enc_embeds)
            return encdec.dec_prefill(params, cfg, enc_out, tokens,
                                      cache_len)
        return prefill_step

    def prefill_step(params, tokens, embeds=None):
        return lm.prefill(params, cfg, tokens, cache_len, embeds)

    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    if cfg.is_encdec:
        def serve_step(params, caches, token, position, enc_out):
            return encdec.dec_decode_step(params, cfg, enc_out, caches,
                                          token, position)
        return serve_step

    def serve_step(params, caches, token, position):
        return lm.decode_step(params, cfg, caches, token, position)

    return serve_step


# ---------------------------------------------------------------------------
# Bundle: everything the dry-run / drivers need for one (arch, shape) cell
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    """One shape cell's jit-ready callable + arg structures + shardings."""

    fn: Callable                     # step function (pure)
    arg_structs: Tuple               # ShapeDtypeStructs, positional
    in_specs: Tuple                  # PartitionSpec pytrees, positional
    donate: Tuple[int, ...]          # donated argnums
    kind: str                        # train | prefill | decode
    # explicit output shardings: carried state (params/opt/caches) MUST
    # keep its input sharding — leaving it to XLA lets the partitioner
    # replicate donated caches, which shows up as a cache-sized all-gather
    # per step (found in the qwen2 decode baseline; EXPERIMENTS.md §Perf)
    out_specs: Optional[Tuple] = None


def build_step_bundle(cfg: ModelConfig, shape: str, mesh: Mesh, *,
                      opt_cfg: AdamWConfig = AdamWConfig(),
                      accum: int = 1,
                      compress_crosspod: bool = False,
                      rules: ShardingRules = DEFAULT_RULES) -> StepBundle:
    """Assemble (fn, arg structs, shardings) for one (arch × shape) cell."""
    ss = SHAPES[shape]
    pstruct = params_struct(cfg)
    pspecs = param_specs(pstruct, mesh, rules)
    ins = input_specs(cfg, shape)
    bspecs_all = batch_specs(cfg, mesh, ss.kind, ss.global_batch, rules)
    bspecs = {k: bspecs_all[k] for k in ins}

    if ss.kind == "train":
        ostruct = opt_struct(cfg, opt_cfg)
        ospecs = opt_specs(ostruct, mesh, rules)
        fn = make_train_step(cfg, opt_cfg, accum=accum, mesh=mesh,
                             compress_crosspod=compress_crosspod,
                             rules=rules)
        return StepBundle(fn=fn, arg_structs=(pstruct, ostruct, ins),
                          in_specs=(pspecs, ospecs, bspecs),
                          donate=(0, 1), kind="train",
                          out_specs=(pspecs, ospecs, None))

    if ss.kind == "prefill":
        cache_len = ss.seq_len
        cstruct_p = cache_struct(cfg, ss.global_batch, cache_len)
        cspecs_p = cache_specs_tree(cstruct_p, cfg, mesh, ss.global_batch,
                                    rules)
        fn = make_prefill_step(cfg, cache_len)
        if cfg.is_encdec:
            args = (pstruct, ins["enc_embeds"], ins["tokens"])
            specs = (pspecs, bspecs["enc_embeds"], bspecs["tokens"])
        elif cfg.frontend:
            args = (pstruct, ins["tokens"], ins["embeds"])
            specs = (pspecs, bspecs["tokens"], bspecs["embeds"])
        else:
            args = (pstruct, ins["tokens"])
            specs = (pspecs, bspecs["tokens"])
        return StepBundle(fn=fn, arg_structs=args, in_specs=specs,
                          donate=(), kind="prefill",
                          out_specs=(None, cspecs_p))

    # decode
    cstruct = cache_struct(cfg, ss.global_batch, ss.seq_len)
    cspecs = cache_specs_tree(cstruct, cfg, mesh, ss.global_batch, rules)
    fn = make_serve_step(cfg)
    if cfg.is_encdec:
        args = (pstruct, cstruct, ins["token"], ins["position"],
                ins["enc_out"])
        specs = (pspecs, cspecs, bspecs["token"], bspecs["position"],
                 bspecs["enc_out"])
    else:
        args = (pstruct, cstruct, ins["token"], ins["position"])
        specs = (pspecs, cspecs, bspecs["token"], bspecs["position"])
    return StepBundle(fn=fn, arg_structs=args, in_specs=specs,
                      donate=(1,), kind="decode",
                      out_specs=(None, cspecs))
