"""Phi-4-mini 3.8B [dense] — 32L d=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.

RoPE, SwiGLU, GQA, RMSNorm, tied embeddings.
[arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    head_dim=128,
    qkv_bias=False,
    rope_theta=1e4,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    remat="dots",
    source="arXiv:2412.08905; hf:microsoft/Phi-4-mini-instruct",
)
