"""SeamlessM4T-medium [audio] — enc-dec, 12L(+12L) d=1024 16H (kv=16)
d_ff=4096 vocab=256206.

Encoder-decoder transformer backbone; the speech frontend (w2v-BERT conv
feature extractor) is a STUB per the assignment — ``input_specs`` supplies
precomputed frame embeddings to the encoder. Adaptation note: RoPE replaces
the original relative/sinusoidal positions (our unified positional layer);
LayerNorm + GELU as released. Decode shapes lower the cached decoder step
with cross-attention over encoder memory (enc-dec, NOT encoder-only, so
decode is not skipped). [arXiv:2308.11596; hf:facebook/seamless-m4t-medium]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,           # decoder layers
    encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e4,
    frontend="audio",
    frontend_len=1024,
    tie_embeddings=True,
    norm="layernorm",
    act="gelu",
    remat="none",
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-medium",
)
