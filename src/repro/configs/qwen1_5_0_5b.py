"""Qwen1.5-0.5B [dense] — 24L d=1024 16H (kv=16, i.e. MHA) d_ff=2816
vocab=151936. QKV bias, RoPE (theta 1e6), SwiGLU, RMSNorm, tied embeddings.
[hf:Qwen/Qwen1.5-0.5B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    remat="none",
    source="hf:Qwen/Qwen1.5-0.5B",
)
