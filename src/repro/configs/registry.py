"""``--arch <id>`` registry for the assigned architectures."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "qwen1.5-0.5b": "repro.configs.qwen1_5_0_5b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
}

_CACHE: Dict[str, ModelConfig] = {}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    if arch not in _CACHE:
        import importlib
        _CACHE[arch] = importlib.import_module(_MODULES[arch]).CONFIG
    return _CACHE[arch]


def list_archs() -> List[str]:
    return sorted(_MODULES)
