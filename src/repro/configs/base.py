"""Model/architecture configuration schema + the shape grid.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``repro.configs.registry`` maps ``--arch`` ids to them.
``input_specs(cfg, shape)`` builds ShapeDtypeStruct stand-ins for the
dry-run (no allocation); ``reduced(cfg)`` shrinks any config to a
CPU-runnable smoke variant of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["MoEConfig", "SSMConfig", "ModelConfig", "ShapeSpec", "SHAPES",
           "input_specs", "reduced", "param_count", "scale_layers"]


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    num_shared: int = 0          # shared (always-on) experts
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    headdim: int = 64
    ngroups: int = 1
    conv_kernel: int = 4
    expand: int = 2
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | vlm | audio | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None   # M-RoPE (t,h,w)
    sliding_window: Optional[int] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # per-period layer pattern for hybrids: "a"=attention block, "m"=mamba
    layer_pattern: Optional[Tuple[str, ...]] = None
    encoder_layers: int = 0          # >0 => encoder-decoder
    tie_embeddings: bool = False
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu | geglu
    frontend: Optional[str] = None   # None | vision | audio (stubbed)
    frontend_len: int = 256          # prefix length of precomputed embeddings
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # which attention layers exist in hybrids (derived), remat policy etc.
    remat: str = "none"              # none | dots | full
    long_context_ok: bool = False    # sub-quadratic path exists (long_500k)
    source: str = ""                 # provenance note
    # False => python-loop over layers instead of lax.scan. Used by the
    # dry-run cost measurement: XLA cost_analysis counts scan bodies once,
    # so honest per-step FLOPs need the unrolled form (DESIGN.md §6).
    scan_layers: bool = True
    # KV block size for streaming (online-softmax) attention on the
    # non-Pallas path; 0 = dense reference attention. The production TPU
    # path always streams (Pallas flash kernel); setting this makes the
    # dry-run lowering match the kernel's memory behaviour.
    attn_block_k: int = 0
    # Mesh axes to pin attention activations to (pure-DP attention).
    # Head counts like 36q/4kv admit no clean 16-way tensor parallelism,
    # and without a pin GSPMD picks depth-dependent strategies that
    # all-reduce flash accumulators per KV block. Set by the launcher to
    # dp_axes(mesh) when the batch divides.
    act_dp: Tuple[str, ...] = ()
    # Context parallelism: mesh axis to shard the QUERY sequence dim over
    # in streaming attention (KV stays DP-replicated and is broadcast) —
    # divides the per-device S^2 score traffic and attention FLOPs by the
    # axis size. Set by the launcher for prefill/train when S divides.
    act_sp: Optional[str] = None
    # MoE dispatch groups (0/1 = single global group). Set to the DP shard
    # count so the sort-based dispatch stays local to each data shard —
    # per-group capacity, no cross-shard dispatch collectives.
    moe_groups: int = 0

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def pattern(self) -> Tuple[str, ...]:
        return self.layer_pattern or ("a",)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def __post_init__(self):
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError(f"{self.name}: n_heads {self.n_heads} not "
                             f"divisible by n_kv_heads {self.n_kv_heads}")
        if self.layer_pattern and self.n_layers % len(self.layer_pattern):
            raise ValueError(f"{self.name}: n_layers {self.n_layers} not a "
                             f"multiple of pattern {self.layer_pattern}")


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape cell.

    train/prefill: token batch (+ stub frontend embeddings for [vlm]/[audio]).
    decode: one new token per sequence + KV/SSM cache of ``seq_len``.
    The actual cache pytree structs are built by the model module
    (``lm.init_cache_specs``); here we return the *data* inputs.
    """
    ss = SHAPES[shape]
    B, S = ss.global_batch, ss.seq_len
    f32, i32 = jnp.float32, jnp.int32
    bf16 = jnp.bfloat16
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if ss.kind == "train":
        S_txt = S - (cfg.frontend_len if cfg.frontend else 0)
        if cfg.is_encdec:
            # encoder side consumes the (stub) audio frames; decoder consumes
            # text tokens. Total work budget ~ S split 1:1.
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S // 2, cfg.d_model), bf16)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S // 2), i32)
            specs["targets"] = jax.ShapeDtypeStruct((B, S // 2), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S_txt), i32)
            specs["targets"] = jax.ShapeDtypeStruct((B, S_txt), i32)
            if cfg.frontend:
                specs["embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), bf16)
    elif ss.kind == "prefill":
        S_txt = S - (cfg.frontend_len if cfg.frontend else 0)
        if cfg.is_encdec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, S // 2, cfg.d_model), bf16)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S // 2), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S_txt), i32)
            if cfg.frontend:
                specs["embeds"] = jax.ShapeDtypeStruct(
                    (B, cfg.frontend_len, cfg.d_model), bf16)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((B,), i32)
        specs["position"] = jax.ShapeDtypeStruct((B,), i32)
        if cfg.is_encdec:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (B, min(S, 4096), cfg.d_model), bf16)
    return specs


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256) -> ModelConfig:
    """Shrink any config to a smoke-testable variant of the same family."""
    period = len(cfg.pattern)
    n_layers = max(layers, period)
    n_layers -= n_layers % period
    n_heads = max(2, min(cfg.n_heads, 4))
    rep = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // min(rep, n_heads))
    changes = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads,
        n_kv_heads=n_kv, d_ff=d_model * 2, vocab_size=vocab,
        head_dim=d_model // n_heads, frontend_len=8,
        encoder_layers=(2 if cfg.is_encdec else 0),
        sliding_window=(16 if cfg.sliding_window else None),
        dtype="float32", param_dtype="float32",
    )
    if cfg.moe:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=d_model * 2)
    if cfg.ssm:
        changes["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, headdim=16, chunk=8)
    if cfg.mrope_sections:
        hd2 = (d_model // n_heads) // 2   # rope pairs
        changes["mrope_sections"] = (hd2 - 2 * (hd2 // 4), hd2 // 4, hd2 // 4)
    return dataclasses.replace(cfg, **changes)


def scale_layers(cfg: ModelConfig, m: int) -> ModelConfig:
    """Same architecture with ``m`` pattern-periods of layers (and ``m``
    encoder layers for enc-dec). All other dims unchanged, so the per-layer
    HLO cost equals the full model's — used by the dry-run to extrapolate
    scan-body costs (XLA cost_analysis counts while bodies once):
    ``cost(R) = base + R * layer``."""
    period = len(cfg.pattern)
    changes: Dict[str, object] = {"n_layers": m * period}
    if cfg.is_encdec:
        changes["encoder_layers"] = m
    return dataclasses.replace(cfg, **changes)


def _norm_token(cfg: ModelConfig, t: str) -> str:
    """Expand legacy one-char tokens to <mixer><ffn> form."""
    if len(t) == 2:
        return t
    if t == "a":
        return "ae" if cfg.moe else "ad"
    if t == "m":
        return "m-"
    raise ValueError(f"bad pattern token {t!r}")


def param_count(cfg: ModelConfig, active: bool = False) -> int:
    """Analytic parameter count. ``active=True`` counts only the top-k
    experts' parameters (roofline MODEL_FLOPS = 6·N_active·D for MoE)."""
    d, hd = cfg.d_model, cfg.hd
    n_q, n_kv = cfg.n_heads, cfg.n_kv_heads
    attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
    if cfg.qkv_bias:
        attn += (n_q + 2 * n_kv) * hd

    def mlp_params(dff: int) -> int:
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        return mult * d * dff

    def mamba_params() -> int:
        s = cfg.ssm
        din = s.d_inner(d)
        nh = s.nheads(d)
        conv_ch = din + 2 * s.ngroups * s.d_state
        p = d * (2 * din + 2 * s.ngroups * s.d_state + nh)   # in_proj
        p += conv_ch * s.conv_kernel + conv_ch               # conv w + b
        p += 3 * nh                                          # A, dt_bias, D
        p += din                                             # gated norm
        p += din * d                                         # out_proj
        return p

    def ffn_params(ffn: str) -> int:
        if ffn == "-":
            return 0
        if ffn == "d":
            return mlp_params(cfg.d_ff) + d                  # + norm2
        m = cfg.moe
        n_e = m.top_k if active else m.num_experts
        return (d * m.num_experts + n_e * mlp_params(m.d_ff_expert)
                + m.num_shared * mlp_params(m.d_ff_expert) + d)

    total = 0
    pattern = cfg.pattern
    reps = cfg.n_layers // len(pattern)
    for tok in pattern:
        tok = _norm_token(cfg, tok)
        blk = d                                              # norm1
        blk += attn if tok[0] == "a" else mamba_params()
        blk += ffn_params(tok[1])
        total += blk * reps
    total += cfg.vocab_size * d                              # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d                          # lm head
    if cfg.is_encdec:
        enc_blk = attn + mlp_params(cfg.d_ff) + 2 * d
        total += cfg.encoder_layers * enc_blk
        total += cfg.n_layers * (attn + d)                   # cross-attn
    return int(total)
