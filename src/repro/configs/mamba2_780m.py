"""Mamba2-780m [ssm] — 48L d=1536 attention-free, vocab=50280,
ssm_state=128 (SSD, state-space duality). d_inner = 2·d = 3072,
headdim 64 → 48 SSD heads, depthwise conv k=4.

Attention-free ⇒ the long_500k decode shape runs (O(1) recurrent state).
[arXiv:2405.21060; hf:state-spaces/mamba2-780m]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=24,          # unused by 'm-' blocks; kept for schema validity
    n_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    ssm=SSMConfig(d_state=128, headdim=64, ngroups=1, conv_kernel=4,
                  expand=2, chunk=256),
    layer_pattern=("m-",),
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    remat="none",
    long_context_ok=True,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-780m",
)
