"""Granite-3.0-3B-A800M MoE [moe] — 32L d=1536 24H (GQA kv=8)
expert d_ff=512, vocab=49155, 40 experts top-8.

Fine-grained MoE (many small experts), SwiGLU, RMSNorm, tied embeddings.
[hf:ibm-granite/granite-3.0-3b-a800m-base; config per assignment]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    head_dim=64,
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512,
                  capacity_factor=1.25),
    layer_pattern=("ae",),
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    remat="none",
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
)
