"""Qwen2-1.5B [dense] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA with QKV bias, RoPE (theta 1e6), SwiGLU, RMSNorm, tied embeddings.
[arXiv:2407.10671; hf:Qwen/Qwen2-1.5B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    norm="rmsnorm",
    act="swiglu",
    remat="dots",
    source="arXiv:2407.10671; hf:Qwen/Qwen2-1.5B",
)
