"""Qwen2-VL-7B [vlm] — 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (multimodal rotary: temporal/height/width sections 16/24/24 pairs
of head_dim 128), QKV bias, SwiGLU, RMSNorm. The vision frontend (dynamic-
resolution ViT) is a STUB per the assignment: ``input_specs`` supplies
precomputed patch embeddings (B, frontend_len, d_model); the backbone
prepends them to text tokens. [arXiv:2409.12191; hf:Qwen/Qwen2-VL-7B]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    frontend="vision",
    frontend_len=1024,
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    remat="dots",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-7B-Instruct",
)
