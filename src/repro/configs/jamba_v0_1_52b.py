"""Jamba-v0.1 52B [hybrid] — 32L d=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave.

Period-8 block (paper §3.1: one attention per 8 layers, MoE every other
layer): ('md','me','md','me','ad','me','md','me'). Adaptation note: we use
the Mamba-2/SSD mixer (TPU-friendly chunked dual form) in place of
Jamba's Mamba-1 — same state-space recurrence family, MXU-alignable
(DESIGN.md §2). Hybrid ⇒ long_500k runs (only 4 of 32 layers hold KV).
[arXiv:2403.19887; hf:ai21labs/Jamba-v0.1]
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  capacity_factor=1.25),
    ssm=SSMConfig(d_state=128, headdim=64, ngroups=1, conv_kernel=4,
                  expand=2, chunk=256),
    layer_pattern=("md", "me", "md", "me", "ad", "me", "md", "me"),
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    remat="dots",
    long_context_ok=True,
    source="arXiv:2403.19887; hf:ai21labs/Jamba-v0.1",
)
