"""Mixtral-8x22B [moe] — 56L d=6144 48H (GQA kv=8) expert d_ff=16384
vocab=32768, 8 experts top-2, sliding-window attention (per assignment).

SWA ⇒ decode KV caches are rolling rings capped at the 4096-token window,
which is what makes the long_500k decode shape runnable (sub-quadratic,
O(window) memory). [arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25),
    layer_pattern=("ae",),
    tie_embeddings=False,
    norm="rmsnorm",
    act="swiglu",
    remat="dots",
    long_context_ok=True,
    source="arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1",
)
