"""StarCoder2-7B [dense] — 32L d=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.

GQA, RoPE, LayerNorm + GELU MLP, attention/MLP biases (use_bias=True in the
released model; we keep QKV bias). [arXiv:2402.19173; hf:bigcode/starcoder2-7b]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e5,
    tie_embeddings=False,
    norm="layernorm",
    act="gelu",
    remat="dots",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
)
