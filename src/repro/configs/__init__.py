from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig, SHAPES,
                                ShapeSpec, input_specs, reduced, param_count)
from repro.configs.registry import get_config, list_archs

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "SHAPES", "ShapeSpec",
           "input_specs", "reduced", "param_count", "get_config",
           "list_archs"]
