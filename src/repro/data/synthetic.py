"""Synthetic feature-rich event streams (paper §Data Availability).

The paper evaluates on synthetic transaction-like data generated inside
Docker: keyed event streams with timestamps, numeric features, bursty
arrival patterns and heavy-tailed key popularity (some users transact far
more than others). We reproduce that generator with explicit knobs so
every benchmark is seeded + replayable:

* keys ~ Zipf(alpha) over ``n_keys`` users;
* inter-arrival ~ Exp(rate) with sinusoidal diurnal modulation;
* ``n_features`` value columns: amounts ~ LogNormal, coordinates ~ Normal,
  a categorical-ish column (small ints), and AR(1) per-key drift so window
  aggregates are informative;
* optional fraud labels from a planted rule (big amount + far from the
  key's home location + short window burst) for the end-to-end examples.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["EventStreamConfig", "generate_events", "request_stream",
           "make_labels", "token_batch_stream"]


@dataclass(frozen=True)
class EventStreamConfig:
    n_events: int = 10_000
    n_keys: int = 256
    n_features: int = 6
    zipf_alpha: float = 1.2
    rate_hz: float = 50.0
    diurnal_depth: float = 0.5
    ar_rho: float = 0.85
    seed: int = 0


def generate_events(cfg: EventStreamConfig
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (keys (N,) int64, ts (N,) f32 sorted, rows (N, F) f32)."""
    rng = np.random.default_rng(cfg.seed)
    N, F = cfg.n_events, cfg.n_features

    # heavy-tailed key popularity
    ranks = np.arange(1, cfg.n_keys + 1, dtype=np.float64)
    pk = ranks ** (-cfg.zipf_alpha)
    pk /= pk.sum()
    keys = rng.choice(cfg.n_keys, size=N, p=pk).astype(np.int64)

    # bursty arrivals: exponential gaps modulated by a diurnal sinusoid
    gaps = rng.exponential(1.0 / cfg.rate_hz, size=N)
    t = np.cumsum(gaps)
    mod = 1.0 + cfg.diurnal_depth * np.sin(2 * np.pi * t / (t[-1] + 1e-9))
    ts = np.cumsum(gaps * mod).astype(np.float32)

    rows = np.empty((N, F), np.float32)
    # col 0: amount ~ LogNormal
    rows[:, 0] = rng.lognormal(mean=3.0, sigma=1.0, size=N)
    # col 1-2: per-key home location + noise
    home = rng.normal(0, 10, size=(cfg.n_keys, 2))
    rows[:, 1:3] = home[keys] + rng.normal(0, 1.0, size=(N, 2))
    # col 3: small-int categorical-ish (merchant category)
    if F > 3:
        rows[:, 3] = rng.integers(0, 12, size=N).astype(np.float32)
    # col 4+: per-key AR(1) drift series
    for c in range(4, F):
        noise = rng.normal(0, 1, size=N).astype(np.float32)
        series = np.zeros(N, np.float32)
        last = np.zeros(cfg.n_keys, np.float32)
        for i in range(N):            # host-side gen; fine at bench sizes
            k = keys[i]
            last[k] = cfg.ar_rho * last[k] + noise[i]
            series[i] = last[k]
        rows[:, c] = series
    return keys, ts, rows


def make_labels(keys: np.ndarray, ts: np.ndarray, rows: np.ndarray,
                *, amount_thresh: float = 60.0, dist_thresh: float = 4.0,
                seed: int = 0) -> np.ndarray:
    """Planted fraud rule + label noise -> (N,) float32 in {0,1}."""
    rng = np.random.default_rng(seed)
    n_keys = int(keys.max()) + 1
    home = np.zeros((n_keys, 2), np.float32)
    cnt = np.zeros(n_keys, np.int64)
    for k, r in zip(keys, rows[:, 1:3]):          # running home estimate
        home[k] = (home[k] * cnt[k] + r) / (cnt[k] + 1)
        cnt[k] += 1
    dist = np.linalg.norm(rows[:, 1:3] - home[keys], axis=1)
    y = ((rows[:, 0] > amount_thresh) & (dist > dist_thresh))
    flip = rng.random(len(y)) < 0.02
    return (y ^ flip).astype(np.float32)


def request_stream(keys: np.ndarray, ts: np.ndarray, *,
                   batch: int, n_batches: int, seed: int = 0,
                   ts_offset: float = 1.0
                   ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Online serving workload: batches of (key, request-ts) pairs drawn
    from the observed key distribution, timestamps past the ingest
    horizon (fresh "now" queries, as in the paper's QPS runs)."""
    rng = np.random.default_rng(seed + 1)
    t_max = float(ts.max())
    uniq, freq = np.unique(keys, return_counts=True)
    p = freq / freq.sum()
    for i in range(n_batches):
        ks = rng.choice(uniq, size=batch, p=p)
        rts = np.full(batch, t_max + ts_offset * (i + 1), np.float32)
        yield ks, rts


def token_batch_stream(*, vocab: int, batch: int, seq: int, seed: int = 0,
                       n_batches: Optional[int] = None
                       ) -> Iterator[Dict[str, np.ndarray]]:
    """LM training batches (synthetic Zipf tokens; deterministic)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -1.1
    p /= p.sum()
    i = 0
    while n_batches is None or i < n_batches:
        toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
        yield {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        i += 1
