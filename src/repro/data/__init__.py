from repro.data.synthetic import (EventStreamConfig, generate_events,
                                  request_stream, make_labels,
                                  token_batch_stream)
from repro.data.pipeline import HostPipeline, PipelineConfig

__all__ = ["EventStreamConfig", "generate_events", "request_stream",
           "make_labels", "token_batch_stream", "HostPipeline",
           "PipelineConfig"]
