"""Host-side input pipeline: prefetch, double-buffer, straggler hedging.

TPU training stalls whenever the host cannot hand the next batch to the
device in time. This pipeline runs producers on background threads with a
bounded queue (double buffering), and applies *hedged batch assembly* for
straggler mitigation: if a producer misses its deadline, the pipeline
re-issues the request to a spare producer and takes whichever finishes
first (the classic tail-at-scale trick, applied to input workers —
at 1000+ nodes a slow host must never stall the global step).

Producers are plain callables ``f(batch_index) -> pytree`` so the same
pipeline serves token streams, feature-engine offline scans, and the
serving replay benchmarks.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional

__all__ = ["PipelineConfig", "HostPipeline"]


@dataclass(frozen=True)
class PipelineConfig:
    prefetch: int = 2                 # queue depth (double buffer = 2)
    n_workers: int = 2                # producer threads
    hedge_after_s: Optional[float] = None   # straggler deadline; None = off
    max_hedges: int = 1


class HostPipeline:
    """Pull-based prefetching iterator over ``producer(i)`` calls."""

    def __init__(self, producer: Callable[[int], Any],
                 n_batches: Optional[int] = None,
                 cfg: PipelineConfig = PipelineConfig()):
        self.producer = producer
        self.n_batches = n_batches
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._next_index = 0
        self._index_lock = threading.Lock()
        self._threads = [
            threading.Thread(target=self._worker, daemon=True)
            for _ in range(cfg.n_workers)]
        self.stats = {"produced": 0, "hedges": 0, "hedge_wins": 0}
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- producers
    def _claim(self) -> Optional[int]:
        with self._index_lock:
            i = self._next_index
            if self.n_batches is not None and i >= self.n_batches:
                return None
            self._next_index += 1
            return i

    def _produce_hedged(self, i: int) -> Any:
        cfg = self.cfg
        if cfg.hedge_after_s is None:
            return self.producer(i)
        result: Dict[str, Any] = {}
        done = threading.Event()

        def attempt(tag: str):
            try:
                r = self.producer(i)
            except Exception as e:                      # surfaced by get()
                r = e
            if tag not in result and not done.is_set():
                result[tag] = r
                done.set()

        t0 = threading.Thread(target=attempt, args=("primary",), daemon=True)
        t0.start()
        done.wait(cfg.hedge_after_s)
        hedges = 0
        while not done.is_set() and hedges < cfg.max_hedges:
            hedges += 1
            self.stats["hedges"] += 1
            th = threading.Thread(target=attempt, args=(f"hedge{hedges}",),
                                  daemon=True)
            th.start()
            done.wait(cfg.hedge_after_s)
        done.wait()                                      # someone finishes
        tag, val = next(iter(result.items()))
        if tag != "primary":
            self.stats["hedge_wins"] += 1
        if isinstance(val, Exception):
            raise val
        return val

    def _worker(self):
        while not self._stop.is_set():
            i = self._claim()
            if i is None:
                self._q.put((None, StopIteration()))
                return
            try:
                item = self._produce_hedged(i)
                self._q.put((i, item))
                self.stats["produced"] += 1
            except Exception as e:
                self._q.put((i, e))
                return

    # -------------------------------------------------------------- consumer
    def __iter__(self) -> Iterator[Any]:
        finished = 0
        served = 0
        pending: Dict[int, Any] = {}
        next_i = 0
        while True:
            if self.n_batches is not None and served >= self.n_batches:
                return
            if next_i in pending:                 # in-order delivery
                item = pending.pop(next_i)
                next_i += 1
                served += 1
                yield item
                continue
            i, item = self._q.get()
            if i is None:
                finished += 1
                if finished >= len(self._threads) and not pending:
                    return
                continue
            if isinstance(item, Exception):
                raise item
            pending[i] = item

    def close(self):
        self._stop.set()
