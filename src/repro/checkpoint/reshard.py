"""Elastic restore: load a checkpoint onto a *different* mesh.

Checkpoints store full (unsharded) host arrays per leaf (manager.py), so
resharding is placement-only: given the new mesh and the sharding-rule
table, every leaf is ``jax.device_put`` with its freshly derived
NamedSharding. This supports:

* scaling the data axis up/down (elastic DP — e.g. 16x16 -> 8x16 after
  losing a slice, or onto the 2x16x16 multi-pod mesh);
* changing the rule table itself (e.g. switching FSDP<->TP between
  training and serving restores).

For 1000+-node restores you would stream shards instead of full arrays;
the manifest already records per-leaf shapes so a sharded reader can seek
exactly its slice of each ``.npy`` (numpy format = header + C-contiguous
payload). ``leaf_slice_bytes`` below computes those offsets — used by the
tests to prove the layout supports partial reads.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager, CheckpointMeta
from repro.distributed.sharding import (DEFAULT_RULES, ShardingRules,
                                        param_specs)

__all__ = ["restore_resharded", "save_unsharded_spec", "leaf_slice_bytes"]


def restore_resharded(mgr: CheckpointManager, step: Optional[int],
                      like: Any, mesh: Mesh,
                      specs: Optional[Any] = None,
                      rules: ShardingRules = DEFAULT_RULES
                      ) -> Tuple[Any, CheckpointMeta]:
    """Restore ``like``-shaped tree and place it sharded on ``mesh``.

    ``specs`` defaults to the standard parameter rules — pass explicit
    specs for optimizer state or caches.
    """
    host_tree, meta = mgr.restore(step, like)
    if specs is None:
        specs = param_specs(host_tree, mesh, rules)
    placed = jax.tree_util.tree_map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
        host_tree, specs,
        is_leaf=lambda x: not isinstance(x, (dict, tuple, list)))
    return placed, meta


def save_unsharded_spec(tree: Any) -> Dict[str, Any]:
    """Manifest fragment describing each leaf for sharded readers."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "__".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        out[name] = {"shape": list(np.shape(leaf)),
                     "dtype": str(np.asarray(leaf).dtype)}
    return out


def leaf_slice_bytes(shape, dtype, axis: int, shard: int, n_shards: int
                     ) -> Tuple[int, int]:
    """(offset, length) in bytes of one contiguous shard of a C-contiguous
    array sharded on ``axis`` — only meaningful when axis == 0 (leading-dim
    sharding reads are contiguous; others need strided reads)."""
    if axis != 0:
        raise ValueError("contiguous partial reads need leading-axis shards")
    itemsize = np.dtype(dtype).itemsize
    row = int(np.prod(shape[1:])) * itemsize if len(shape) > 1 else itemsize
    per = shape[0] // n_shards
    return shard * per * row, per * row
