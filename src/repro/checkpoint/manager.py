"""Fault-tolerant checkpointing: atomic, async, retention-K, self-validating.

Design for 1000+-node operation:

* **Atomic**: write to ``<dir>/tmp.<step>.<nonce>/`` then ``os.rename`` to
  ``<dir>/step_<step>/`` — a crashed writer never corrupts a restore
  point; a restore always sees the newest *complete* step.
* **Async**: the serialize+write runs on a background thread; training
  only blocks on the previous save (single-slot pipeline) so checkpoint
  I/O overlaps the next steps' compute.
* **Retention**: keep the newest K checkpoints + optional every-Nth
  "archive" steps, delete the rest (bounded disk).
* **Self-validating**: every leaf file carries a crc32 in the manifest;
  restore verifies before handing params to the trainer.
* **Multi-host**: each host writes only its ``process_index`` shard files
  (here always process 0 — the container is single-host, but the layout
  and the manifest schema are multi-host-ready).

Storage format: one ``.npy`` per pytree leaf (streamable, mmap-able) +
a JSON manifest with the treedef, shapes, dtypes, crcs and step metadata.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager", "CheckpointMeta"]


@dataclass
class CheckpointMeta:
    step: int
    timestamp: float
    leaf_count: int
    extra: Dict[str, Any] = field(default_factory=dict)


def _flatten_with_names(tree: Any) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path) or "root"
        out.append((name, np.asarray(leaf)))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, retain: int = 3,
                 archive_every: int = 0, async_save: bool = True):
        self.dir = directory
        self.retain = retain
        self.archive_every = archive_every
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot ``tree`` at ``step``. Device arrays are fetched to host
        *synchronously* (cheap, and required for consistency), the disk
        write happens on the background thread."""
        self.wait()                      # single-slot async pipeline
        host_tree = jax.tree_util.tree_map(lambda a: np.asarray(a), tree)

        def write():
            try:
                self._write(step, host_tree, extra or {})
                self._gc()
            except BaseException as e:          # surfaced on next wait()
                self._last_error = e

        if self.async_save and not block:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            e, self._last_error = self._last_error, None
            raise RuntimeError(f"async checkpoint write failed: {e!r}") from e

    def _write(self, step: int, tree: Any, extra: Dict) -> None:
        leaves, treedef = _flatten_with_names(tree)
        nonce = f"{os.getpid()}_{int(time.time() * 1e6) % 10**9}"
        tmp = os.path.join(self.dir, f"tmp.{step}.{nonce}")
        os.makedirs(tmp, exist_ok=True)
        manifest: Dict[str, Any] = {
            "step": step, "timestamp": time.time(),
            "treedef": str(treedef), "extra": extra, "leaves": [],
            "process_count": jax.process_count(),
        }
        for name, arr in leaves:
            fn = f"{name}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic publish

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> List[int]:
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_"):
                try:
                    out.append(int(n[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, step: Optional[int], like: Any, *,
                validate: bool = True) -> Tuple[Any, CheckpointMeta]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). Sharded placement is the caller's job
        (see checkpoint.reshard.restore_resharded)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        by_name = {l["name"]: l for l in manifest["leaves"]}
        flat, treedef = _flatten_with_names(
            jax.tree_util.tree_map(
                lambda x: np.zeros([0]), like))   # names only
        arrs = []
        for name, _ in flat:
            entry = by_name.get(name)
            if entry is None:
                raise KeyError(f"checkpoint {step} missing leaf {name!r}")
            arr = np.load(os.path.join(path, entry["file"]))
            if validate:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != entry["crc32"]:
                    raise IOError(
                        f"crc mismatch for {name!r} in step {step} "
                        f"(corrupt checkpoint)")
            arrs.append(arr)
        leaves_like, treedef_like = jax.tree_util.tree_flatten(like)
        tree = jax.tree_util.tree_unflatten(treedef_like, arrs)
        meta = CheckpointMeta(step=manifest["step"],
                              timestamp=manifest["timestamp"],
                              leaf_count=len(arrs),
                              extra=manifest.get("extra", {}))
        return tree, meta

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = self.all_steps()
        keep = set(steps[-self.retain:]) if self.retain else set(steps)
        if self.archive_every:
            keep |= {s for s in steps if s % self.archive_every == 0}
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                              ignore_errors=True)
