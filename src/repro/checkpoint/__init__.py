from repro.checkpoint.manager import CheckpointManager, CheckpointMeta
from repro.checkpoint.reshard import restore_resharded, save_unsharded_spec

__all__ = ["CheckpointManager", "CheckpointMeta", "restore_resharded",
           "save_unsharded_spec"]
