"""Per-architecture sharding rules (DP/FSDP/TP/EP/SP) as declarative tables.

Strategy (DESIGN.md §5):

* ``data`` axis (16): FSDP — every weight's ``d_model``-role dim is sharded
  over it; activations' batch dim is sharded over ``("pod","data")``.
* ``model`` axis (16): TP — attention head projections, FFN hidden, expert
  hidden, and the vocab dim of embedding/lm_head.
* ``pod`` axis (2, multi-pod only): pure data parallelism (composes with
  ``data`` for the batch), so cross-pod traffic is gradient all-reduce
  only — the slice compression in optim/compression.py targets exactly it.
* EP: the expert dim shards over ``data`` *when divisible* (jamba: 16e/16);
  otherwise experts keep FSDP+TP on their (d, ff) dims (mixtral 8e,
  granite 40e — 16 ∤ E).
* SP: long-context decode (B=1) shards the KV-cache sequence dim over
  ``data`` instead of the unshardable batch.

Every rule is divisibility-checked against the actual dim size: a mesh
axis that does not divide the dim is dropped (replicated) rather than
letting ``jit`` reject the sharding. This is what makes ONE rule table
serve all 10 architectures (12-head qwen2 and 48-head mixtral included).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = ["ShardingRules", "DEFAULT_RULES", "dp_axes", "param_specs",
           "batch_specs", "cache_specs_tree", "opt_specs", "spec_for_leaf",
           "named", "tree_named"]

Axis = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """Logical-role -> mesh-axis mapping (MaxText-style logical axis rules)."""

    fsdp: Axis = "data"          # weight d_model-role dims
    tensor: Axis = "model"       # heads / ffn-hidden / vocab dims
    expert: Axis = "data"        # MoE expert dim (EP), when divisible
    dp_extra: Axis = "pod"       # extra pure-DP axis when present in mesh
    seq: Axis = "data"           # SP for unshardable-batch caches
    # when True, expert dim takes priority over fsdp on expert weights
    prefer_ep: bool = True


DEFAULT_RULES = ShardingRules()


def dp_axes(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES) -> Axis:
    """Batch-dim axes: ("pod","data") on multi-pod, ("data",) otherwise."""
    names = mesh.axis_names
    out = []
    if isinstance(rules.dp_extra, str) and rules.dp_extra in names:
        out.append(rules.dp_extra)
    for a in (rules.fsdp if isinstance(rules.fsdp, tuple)
              else (rules.fsdp,)):
        if a in names:
            out.append(a)
    return tuple(out)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.axis_names else 0
    n = 1
    for a in axis:
        s = mesh.shape[a] if a in mesh.axis_names else 0
        if s == 0:
            return 0
        n *= s
    return n


def _fit(mesh: Mesh, axis: Axis, dim: int) -> Axis:
    """Return ``axis`` if it exists in the mesh and divides ``dim``."""
    sz = _axis_size(mesh, axis)
    if sz <= 1 or dim % sz != 0:
        return None
    return axis


def _mk(mesh: Mesh, shape: Tuple[int, ...], wanted: Sequence[Axis]) -> P:
    """Divisibility-checked PartitionSpec; drops duplicate axis uses."""
    used: set = set()
    out = []
    for dim, ax in zip(shape, wanted):
        ax = _fit(mesh, ax, dim)
        flat = (ax,) if isinstance(ax, str) else (ax or ())
        if ax is not None and not any(a in used for a in flat):
            out.append(ax)
            used.update(flat)
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_leaf(path: str, shape: Tuple[int, ...], mesh: Mesh,
                  rules: ShardingRules = DEFAULT_RULES) -> P:
    """Sharding rule for one parameter leaf, by name + shape.

    ``path`` is the '/'-joined pytree key path; stacked-layer params carry a
    leading ``reps`` dim that is never sharded (it is scanned over).
    """
    fs, tp, ep = rules.fsdp, rules.tensor, rules.expert
    nd = len(shape)

    def tail(*axes: Axis) -> P:
        """Apply ``axes`` to the trailing dims, replicate leading dims."""
        lead = nd - len(axes)
        return _mk(mesh, shape, [None] * lead + list(axes))

    # --- embeddings / lm head: (V, d) -> vocab TP + d FSDP
    if re.search(r"(^|/)(embed|lm_head)$", path):
        return tail(tp, fs)

    # --- MoE ----------------------------------------------------------------
    if "/router" in path:
        return tail(fs, None)                       # (d, E)
    if "/experts/" in path or "/shared/" in path:
        # (reps, E, d, ff) for wi/wg; (reps, E, ff, d) for wo
        is_wo = path.endswith("wo")
        e_dim = shape[-3]
        ep_ok = _fit(mesh, ep, e_dim) is not None and rules.prefer_ep
        if is_wo:
            return (tail(ep, tp, None) if ep_ok else tail(None, tp, fs))
        return (tail(ep, None, tp) if ep_ok else tail(None, fs, tp))

    # --- Mamba ---------------------------------------------------------------
    if path.endswith("in_proj"):
        return tail(fs, tp)                         # (d, d_proj)
    if path.endswith("out_proj"):
        return tail(tp, fs)                         # (d_in, d)
    if path.endswith("conv_w"):
        return tail(None, tp)                       # (k, conv_ch)
    if re.search(r"(A_log|dt_bias|/D|conv_b)$", path):
        return tail(None)

    # --- attention / MLP matmul weights --------------------------------------
    if re.search(r"/(wq|wk|wv|wi|wg)(/w)?$", path):
        return tail(fs, tp)                         # column-parallel
    if re.search(r"/(wo)(/w)?$", path):
        return tail(tp, fs)                         # row-parallel

    # --- norms, biases, scalars ----------------------------------------------
    return _mk(mesh, shape, [None] * nd)


def param_specs(params_shape: Any, mesh: Mesh,
                rules: ShardingRules = DEFAULT_RULES) -> Any:
    """Pytree of PartitionSpec matching a params (shape-)pytree."""

    def one(path, leaf):
        return spec_for_leaf(_path_str(path), tuple(leaf.shape), mesh, rules)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def opt_specs(opt_shape: Any, mesh: Mesh,
              rules: ShardingRules = DEFAULT_RULES) -> Any:
    """OptState specs: m/v/master shard like params; count replicated."""

    def one(path, leaf):
        ps = _path_str(path)
        if ps.endswith("count") or leaf.ndim == 0:
            return P()
        # strip the leading "m/"|"v/"|"master/" component
        sub = ps.split("/", 1)[1] if "/" in ps else ps
        return spec_for_leaf(sub, tuple(leaf.shape), mesh, rules)

    return jax.tree_util.tree_map_with_path(one, opt_shape)


# ---------------------------------------------------------------------------
# Activation / input / cache rules
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, mesh: Mesh, kind: str,
                global_batch: int, rules: ShardingRules = DEFAULT_RULES
                ) -> Dict[str, P]:
    """Input shardings for one shape cell. Batch over ("pod","data")."""
    dp = dp_axes(mesh, rules)
    dp = _fit(mesh, dp, global_batch)
    b = dp  # None when batch is unshardable (long_500k B=1)
    specs: Dict[str, P] = {}
    if kind == "train":
        specs["tokens"] = P(b, None)
        specs["targets"] = P(b, None)
        if cfg.frontend:
            specs["embeds"] = P(b, None, None)
        if cfg.is_encdec:
            specs["enc_embeds"] = P(b, None, None)
    elif kind == "prefill":
        specs["tokens"] = P(b, None)
        if cfg.frontend:
            specs["embeds"] = P(b, None, None)
        if cfg.is_encdec:
            specs["enc_embeds"] = P(b, None, None)
    else:  # decode
        specs["token"] = P(b)
        specs["position"] = P(b)
        if cfg.is_encdec:
            specs["enc_out"] = P(b, None, None)
    return specs


def cache_specs_tree(cache_shape: Any, cfg: ModelConfig, mesh: Mesh,
                     global_batch: int,
                     rules: ShardingRules = DEFAULT_RULES) -> Any:
    """Specs for a stacked KV/SSM cache pytree.

    Leaves are (reps, B, S, Hkv, D) [attn k/v], (reps, B, k-1, conv_ch)
    [mamba conv], (reps, B, nh, N, P) [mamba ssm]. Batch shards over
    ("pod","data") when divisible; otherwise the sequence dim (attn) or
    the heads dim (ssm) shards over ``data`` — sequence parallelism for
    the long_500k cells.
    """
    dp = _fit(mesh, dp_axes(mesh, rules), global_batch)

    def kv_axes(S: int, hkv: int, hd: int):
        """TP for a KV cache (S_ax, H_ax, D_ax). Shard kv-heads when they
        divide the tensor axis; otherwise shard the SEQUENCE dim — the
        split-KV flash-decode layout: the one-position scatter stays
        local, softmax stats + pv reduction are KB-sized all-reduces.
        (Replicating the heads makes GSPMD all-gather the whole cache over
        the model axis every layer: 537 MB/device/layer measured on qwen2
        decode — EXPERIMENTS.md §Perf iteration 2.)"""
        if _fit(mesh, rules.tensor, hkv) is not None:
            return None, rules.tensor, None
        if _fit(mesh, rules.tensor, S) is not None:
            return rules.tensor, None, None
        if _fit(mesh, rules.tensor, hd) is not None:
            return None, None, rules.tensor
        return None, None, None

    def one(path, leaf):
        ps = _path_str(path)
        sh = tuple(leaf.shape)
        nd = len(sh)
        if nd == 5:            # attn kv (reps,B,S,H,D) or ssm (reps,B,nh,N,P)
            if ps.endswith("ssm"):
                if dp is not None:
                    return _mk(mesh, sh, [None, dp, rules.tensor, None, None])
                return _mk(mesh, sh, [None, None, rules.tensor, None, None])
            s_ax, h_ax, d_ax = kv_axes(sh[2], sh[3], sh[4])
            if dp is not None:
                return _mk(mesh, sh, [None, dp, s_ax, h_ax, d_ax])
            # B unshardable: sequence takes BOTH axes when possible (SP)
            return _mk(mesh, sh, [None, None,
                                  (rules.seq if s_ax is None else
                                   (rules.seq, s_ax) if isinstance(s_ax, str)
                                   else rules.seq),
                                  h_ax, d_ax])
        if nd == 4:            # unstacked kv (B,S,H,D) / conv (reps,B,k,ch)
            if ps.endswith("conv"):
                return _mk(mesh, sh, [None, dp, None, rules.tensor])
            s_ax, h_ax, d_ax = kv_axes(sh[1], sh[2], sh[3])
            if dp is not None:
                return _mk(mesh, sh, [dp, s_ax, h_ax, d_ax])
            return _mk(mesh, sh, [(rules.seq if s_ax is None else
                                   (rules.seq, s_ax) if isinstance(s_ax, str)
                                   else rules.seq),
                                  h_ax, d_ax])
        if nd == 3 and ps.endswith("conv"):
            return _mk(mesh, sh, [dp, None, rules.tensor])
        return _mk(mesh, sh, [dp] + [None] * (nd - 1))

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
