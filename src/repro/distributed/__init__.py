from repro.distributed.sharding import (ShardingRules, DEFAULT_RULES,
                                        dp_axes, param_specs, batch_specs,
                                        cache_specs_tree, opt_specs,
                                        spec_for_leaf)

__all__ = ["ShardingRules", "DEFAULT_RULES", "dp_axes", "param_specs",
           "batch_specs", "cache_specs_tree", "opt_specs", "spec_for_leaf"]
