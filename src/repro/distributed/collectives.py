"""Compression-aware collectives + compute/communication overlap helpers.

The hot cross-pod path is the gradient all-reduce over the ``pod`` mesh
axis. ``psum_int8`` runs it at 1/4 the bytes of f32 (int8 payload + one
f32 scale per tensor) using shard_map over *only* the pod axis — the
``data``/``model`` axes stay in XLA's automatic-sharding world via
``axis_names=... auto`` so the rest of the step is untouched.

``overlapped_grad_reduce`` staggers per-leaf reduces so XLA's scheduler
can overlap them with the optimizer math that does not depend on them
(the leaves are independent); on real ICI this is the standard
bucketed-overlap trick, here it falls out of HLO dataflow.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map as _shard_map

__all__ = ["psum_int8", "pod_allreduce_int8", "crosspod_grad_mean"]


def psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """In-collective int8-compressed psum (call inside shard_map).

    Per-tensor symmetric quantization; the scale is agreed via a (tiny)
    f32 max-psum, the payload travels as int32-accumulated int8.
    Bias is bounded by 0.5 * scale * n_pods; pair with error feedback
    (optim.compression.ErrorFeedback) on the training path.
    """
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale


def pod_allreduce_int8(tree: Any, mesh: Mesh, *, axis: str = "pod",
                       mean: bool = True) -> Any:
    """int8-compressed all-reduce of a pytree over ``axis``.

    Works on trees whose leaves are replicated w.r.t. ``axis`` *contents*
    but hold different values per pod (per-pod partial gradients). Leaves
    keep their existing data/model sharding: shard_map is entered only
    over ``axis`` and the other mesh axes stay automatic.
    """
    if axis not in mesh.axis_names:
        return tree
    npods = mesh.shape[axis]

    @functools.partial(
        _shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
        manual_axes={axis})
    def reduce_fn(t):
        out = jax.tree_util.tree_map(
            lambda g: psum_int8(g, axis), t)
        if mean:
            out = jax.tree_util.tree_map(lambda g: g / npods, out)
        return out

    return reduce_fn(tree)


def crosspod_grad_mean(grads: Any, mesh: Mesh, *, compress: bool = False
                       ) -> Any:
    """Average per-pod gradients across pods.

    ``compress=False``: plain f32 pmean (XLA all-reduce).
    ``compress=True``: int8 payload (4x less cross-pod traffic).
    """
    if "pod" not in mesh.axis_names:
        return grads
    if compress:
        return pod_allreduce_int8(grads, mesh, axis="pod", mean=True)

    @functools.partial(_shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                       manual_axes={"pod"})
    def reduce_fn(t):
        return jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g, "pod"), t)

    return reduce_fn(grads)
