"""Per-deployment admission control + deadline-aware load shedding.

The control plane of the sharded serving runtime (DESIGN.md §9): before a
batch is scattered, the :class:`ResourceManager` decides whether it may
enter at all.

* **In-flight bound** — at most ``max_inflight`` batches of one
  deployment may be executing/queued at once; an admit blocks (up to the
  request's own deadline, capped by ``admit_timeout_s``) for a slot.
  A **deadlined** request that cannot get a slot in time is SHED at the
  door (whole-batch ``STATUS_SHED``, never an exception — the deadline
  IS its give-up bound); a deadline-less request REJECTS with
  backpressure, so overload surfaces as an explicit error at the door
  instead of unbounded queueing behind the shards.
* **Queue-depth bound** — if any target shard's worker queue is deeper
  than ``max_queue_depth`` sub-batches, the batch is rejected: one
  saturated shard must not keep absorbing work it cannot serve in time.
* **Deadline shedding** — a batch whose context deadline has already
  passed (on arrival, or while waiting for a slot) is SHED: the caller
  gets a whole-batch ``STATUS_SHED`` result immediately and the shards
  never see the work. Shedding is all-or-nothing per batch — the runtime
  never returns a mix of shed and computed rows.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

__all__ = ["AdmissionConfig", "Admission", "ResourceManager"]


@dataclass(frozen=True)
class AdmissionConfig:
    max_inflight: int = 8          # concurrent batches per deployment
    max_queue_depth: int = 64      # pending sub-batches per shard worker
    admit_timeout_s: float = 1.0   # max wait for an in-flight slot
    # shed at the door when a deadlined request's remaining budget is
    # below this — it would only be shed later at lane dequeue anyway,
    # after wasting a slot and scatter work. 0 disables (admit anything
    # not yet expired); the control plane raises it when it observes
    # post-admission sheds (work admitted, then thrown away in a queue)
    min_service_budget_s: float = 0.0


class Admission:
    """Outcome of an admit: either a held slot (release it!) or a shed."""

    __slots__ = ("_mgr", "_name", "shed", "_released")

    def __init__(self, mgr: Optional["ResourceManager"], name: str,
                 shed: bool):
        self._mgr = mgr
        self._name = name
        self.shed = shed
        self._released = False

    def release(self) -> None:
        if self.shed or self._released or self._mgr is None:
            return
        self._released = True
        self._mgr._release(self._name)

    def __enter__(self) -> "Admission":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.release()
        return False


class ResourceManager:
    """Tracks per-deployment in-flight batches and shed/reject counters."""

    def __init__(self, cfg: AdmissionConfig = AdmissionConfig()):
        self.cfg = cfg
        self._lock = threading.Lock()
        self._slot_freed = threading.Condition(self._lock)
        self._inflight: Dict[str, int] = {}
        self.stats = {"admitted": 0, "shed_deadline": 0,
                      "shed_worker_down": 0, "served_degraded": 0,
                      "rejected_inflight": 0, "rejected_queue_depth": 0}

    # ---------------------------------------------------------------- admit
    def admit(self, name: str, ctx=None,
              queue_depths: Optional[Callable[[], list]] = None
              ) -> Admission:
        """Admit one batch of deployment ``name``; returns an
        :class:`Admission` whose ``shed`` flag tells the caller to return
        a whole-batch shed status. Raises ``RuntimeError`` on capacity
        rejection (backpressure) — deadline-less requests only: a
        deadlined request that cannot be admitted in time is SHED, never
        errored, because its deadline is already the give-up bound."""
        cfg = self.cfg
        deadlined = ctx is not None and ctx.deadline is not None
        if ctx is not None and ctx.expired:
            with self._lock:
                self.stats["shed_deadline"] += 1
            return Admission(None, name, shed=True)
        deadline = time.monotonic() + cfg.admit_timeout_s
        if deadlined:
            deadline = min(deadline, ctx.deadline)
        with self._lock:
            while self._inflight.get(name, 0) >= self.cfg.max_inflight:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    if deadlined:
                        # could not get a slot within the request's
                        # budget (or the cap): shed NOW at the door —
                        # before the fix this raised backpressure (cap <
                        # deadline) or kept the caller blocked until the
                        # work would only be shed later at lane dequeue
                        self.stats["shed_deadline"] += 1
                        return Admission(None, name, shed=True)
                    self.stats["rejected_inflight"] += 1
                    raise RuntimeError(
                        f"admission control: deployment {name!r} has "
                        f"{self._inflight.get(name, 0)} batches in flight "
                        f"(max_inflight={self.cfg.max_inflight})")
                self._slot_freed.wait(wait)
            # a slot is free; shed rather than take it when the remaining
            # budget is gone (or too small to plausibly finish in)
            if ctx is not None and (ctx.expired or (
                    deadlined and self.cfg.min_service_budget_s > 0.0
                    and ctx.remaining() < self.cfg.min_service_budget_s)):
                self.stats["shed_deadline"] += 1
                return Admission(None, name, shed=True)
            if queue_depths is not None:
                depths = queue_depths()
                if depths and max(depths) >= cfg.max_queue_depth:
                    self.stats["rejected_queue_depth"] += 1
                    raise RuntimeError(
                        f"admission control: a shard queue is "
                        f"{max(depths)} sub-batches deep "
                        f"(max_queue_depth={cfg.max_queue_depth})")
            self._inflight[name] = self._inflight.get(name, 0) + 1
            self.stats["admitted"] += 1
            return Admission(self, name, shed=False)

    def record_shed(self, n: int = 1, kind: str = "deadline") -> None:
        """Count a post-admission shed: ``deadline`` (expired inside a
        shard queue) or ``worker_down`` (a subprocess shard died with the
        sub-batch queued/executing — shed, respawn in progress)."""
        with self._lock:
            self.stats["shed_worker_down" if kind == "worker_down"
                       else "shed_deadline"] += n

    def record_degraded(self, n: int = 1) -> None:
        """Count rows answered from the stale tier (STATUS_DEGRADED) —
        the step of the degradation ladder between OK and SHED."""
        with self._lock:
            self.stats["served_degraded"] += n

    def _release(self, name: str) -> None:
        with self._lock:
            n = self._inflight.get(name, 1)
            self._inflight[name] = max(0, n - 1)
            # notify_all: waiters for OTHER deployments share this
            # condition — waking a single (possibly wrong-name) waiter
            # could strand the freed slot until the next release
            self._slot_freed.notify_all()

    # ----------------------------------------------------------------- tune
    def reconfigure(self, **changes) -> AdmissionConfig:
        """Replace admission bounds live (control-plane knob surface).
        Blocked admits re-read ``self.cfg`` each loop, so a raised
        ``max_inflight`` takes effect on waiters immediately. Returns the
        previous config."""
        with self._lock:
            prev = self.cfg
            self.cfg = dataclasses.replace(prev, **changes)
            self._slot_freed.notify_all()   # bounds may have loosened
            return prev

    # ---------------------------------------------------------------- intro
    def inflight(self, name: str) -> int:
        with self._lock:
            return self._inflight.get(name, 0)

    def metrics(self) -> Dict[str, int]:
        with self._lock:
            return dict(self.stats)
