"""Scatter/gather request routing across hash-partitioned shard engines.

The router is the data plane of the sharded serving runtime
(DESIGN.md §9): a request batch is **scattered** by key hash into
per-shard sub-batches, each shard's queue is served by an execution
**lane** (worker thread) over the shard's device-pinned tables and
compiled executables, and the rows are **gathered** back into one result
in the original request order.

Key properties:

* **Stable routing.** ``shard_of`` is a pure function of ``(key,
  n_shards)`` — the same multiplicative hash the device key directory
  uses — so a key's owning shard never changes across publishes,
  redeploys, or process restarts.
* **Shards ≠ lanes.** Shards are data partitions (one queue + one
  engine each); lanes are execution threads, one per available device.
  When shards outnumber devices, a lane serves several shard queues
  round-robin — running more execution threads than physical lanes just
  thrashes (4 streams on 2 cores measured ~35% slower than 2), exactly
  like tablets sharing a tablet-server's executor pool.
* **Coalescing lanes.** A lane drains one shard queue at a time, fusing
  consecutive sub-batches **of the same deployment handle** into
  fixed-size dispatch chunks (``dispatch_rows``, tails padded to a
  power-of-two bucket). Sub-batch sizes vary wildly under scatter
  (binomial around B/S); without re-chunking every distinct size would
  compile a fresh executable and eager pad/slice ops — the chunk
  discipline keeps the executable set bounded and the vector unit full.
* **Deadline-aware shedding.** A sub-batch whose request context expired
  while queued is completed with ``shed=True`` at dequeue, before any
  feature computation — a saturated shard drops late work instead of
  stalling every batch behind it (the gather side then returns a
  whole-batch shed status, never a mix of shed and computed rows).
"""
from __future__ import annotations

import threading
import time
import zlib
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["shard_of", "shard_ids", "SubBatch", "ShardRouter",
           "ShardDownError"]


class ShardDownError(RuntimeError):
    """A shard's backing worker is dead (process backend): the request
    cannot be served there right now. Lanes translate this into a
    whole-batch SHED (the caller sees ``STATUS_SHED``, never a hang or a
    raw exception) while the supervisor respawns the worker."""

# Knuth multiplicative constant — the same one featurestore.keydir hashes
# with, so routing and key-directory slot math share one hash family
_MULT = 2654435761
_MASK32 = 0xFFFFFFFF


def shard_of(key, n_shards: int) -> int:
    """Owning shard of ``key`` — pure in (key, n_shards), stable forever."""
    if n_shards <= 1:
        return 0
    if isinstance(key, np.generic):
        # normalize numpy scalars to their Python value BEFORE hashing:
        # repr(np.str_('x')) differs between numpy majors (and from
        # repr('x')), which would route the same key differently on the
        # scalar vs vectorized path
        key = key.item()
    if isinstance(key, int) and not isinstance(key, bool):
        return ((key & _MASK32) * _MULT & _MASK32) % n_shards
    return zlib.crc32(repr(key).encode()) % n_shards


def shard_ids(keys: np.ndarray, n_shards: int) -> np.ndarray:
    """Vectorised ``shard_of`` over a key batch -> (B,) int32 shard ids."""
    if n_shards <= 1:
        return np.zeros(len(keys), np.int32)
    if keys.dtype.kind in "iu":
        h = (keys.astype(np.uint64) & _MASK32) * _MULT & _MASK32
        return (h % n_shards).astype(np.int32)
    # tolist() yields Python values, keeping the per-element hash
    # identical to the scalar path's
    return np.asarray([shard_of(k, n_shards) for k in keys.tolist()],
                      np.int32)


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b <<= 1
    return b


class SubBatch:
    """One shard's slice of a client batch, in flight through a lane."""

    __slots__ = ("handle", "keys", "ts", "rows", "ctx", "done",
                 "columns", "status", "table_version", "error", "shed",
                 "shed_reason", "watermark", "feature_age")

    def __init__(self, handle, keys: np.ndarray, ts: np.ndarray,
                 rows: Optional[np.ndarray], ctx=None):
        self.handle = handle
        self.keys = keys
        self.ts = ts
        self.rows = rows
        self.ctx = ctx
        self.done = threading.Event()
        self.columns: Optional[Dict[str, np.ndarray]] = None
        self.status: Optional[np.ndarray] = None
        self.table_version: int = -1
        self.error: Optional[BaseException] = None
        self.shed = False
        self.shed_reason: Optional[str] = None
        # freshness stamps from the serving shard (DESIGN.md §14):
        # snapshot watermark and worst per-row feature age of the slice
        self.watermark: Optional[float] = None
        self.feature_age: Optional[float] = None

    def __len__(self) -> int:
        return len(self.keys)


class _ShardQueue:
    """One shard's pending sub-batches (drained by its lane)."""

    def __init__(self, shard_id: int, lane: "_Lane"):
        self.shard_id = shard_id
        self.lane = lane
        self.q: deque = deque()
        # a retired shard's runtime is (about to be) closed: late submits
        # — scatters that read the pre-reshard route table — must shed,
        # not execute against deleted buffers
        self.retired = False
        self.stats = {"sub_batches": 0, "shed_sub_batches": 0,
                      "max_queue_depth": 0}

    def submit(self, item: SubBatch) -> SubBatch:
        lane = self.lane
        with lane.cv:
            if lane.stop or not lane.accepting:
                raise RuntimeError("shard router is closed")
            if self.retired:
                item.shed = True
                item.shed_reason = "worker_down"
                self.stats["shed_sub_batches"] += 1
                item.done.set()
                return item
            self.q.append(item)
            self.stats["max_queue_depth"] = max(
                self.stats["max_queue_depth"], len(self.q))
            lane.cv.notify()
        return item

    @property
    def queue_depth(self) -> int:
        return len(self.q)


class _Lane:
    """One execution thread serving one or more shard queues round-robin:
    drain -> coalesce -> chunk -> execute."""

    def __init__(self, lane_id: int, dispatch_rows: int,
                 coalesce_delay_s: float = 0.002):
        self.lane_id = lane_id
        self.dispatch_rows = dispatch_rows
        # a drain may carry several chunks' worth — full chunks slice out
        # of a big concat with zero pad waste
        self.max_drain_rows = 4 * dispatch_rows
        self.coalesce_delay_s = coalesce_delay_s
        self.queues: List[_ShardQueue] = []
        self.cv = threading.Condition()
        self.stop = False
        # shutdown(drain=True) flips this first so new submits fail fast
        # while already-queued work still completes
        self.accepting = True
        # True while the lane thread holds drained-but-unfinished items
        # (between _drain and the end of _execute) — the drain wait in
        # shutdown() needs it: empty queues alone don't mean idle
        self.busy = False
        self._rr = 0
        self.stats = {"dispatches": 0, "rows": 0}
        self.thread: Optional[threading.Thread] = None
        # assigned by ShardRouter.tracer = ... (sharded engine wiring)
        self.tracer = None

    def start(self) -> None:
        self.thread = threading.Thread(target=self._loop, daemon=True,
                                       name=f"shard-lane-{self.lane_id}")
        self.thread.start()

    # ------------------------------------------------------------- worker
    def _pick_queue(self) -> Optional[_ShardQueue]:
        n = len(self.queues)
        for i in range(n):
            sq = self.queues[(self._rr + i) % n]
            if sq.q:
                self._rr = (self._rr + i + 1) % n
                return sq
        return None

    def _pending_rows(self) -> int:
        return sum(len(it) for sq in self.queues for it in sq.q)

    def _drain(self) -> Tuple[Optional[_ShardQueue], List[SubBatch]]:
        """Pop a run of same-handle sub-batches from the next non-empty
        queue, up to ``max_drain_rows`` (full ``dispatch_rows`` chunks
        slice out of one concat with no pad waste; the first item is
        always taken and oversized items are chunked downstream). When
        less than one full chunk is available AND the lane is otherwise
        idle, wait up to ``coalesce_delay_s`` for more arrivals — under
        scatter, sub-batch sizes are binomial around B/S and a lone
        sub-batch just above a bucket boundary would waste up to half its
        dispatch on padding. Different handles (deployment versions)
        never coalesce into one dispatch."""
        with self.cv:
            while not self.stop:
                sq = self._pick_queue()
                if sq is not None:
                    break
                self.cv.wait(0.1)
            if self.stop:
                items = []
                for q in self.queues:
                    items.extend(q.q)
                    q.q.clear()
                for it in items:   # fail fast instead of hanging waiters
                    it.error = RuntimeError("shard router closed")
                    it.done.set()
                return None, []
            items: List[SubBatch] = []
            n = 0
            handle = sq.q[0].handle
            # flagged BEFORE the coalesce wait can release the cv: a
            # drain-waiter that sees empty queues must also see busy=True
            # for the items this drain is about to pop
            self.busy = True
            deadline: Optional[float] = None
            while True:
                while sq.q and sq.q[0].handle is handle:
                    if items and n + len(sq.q[0]) > self.max_drain_rows:
                        break
                    it = sq.q.popleft()
                    items.append(it)
                    n += len(it)
                if (n >= self.dispatch_rows or self.stop
                        or self.coalesce_delay_s <= 0
                        or self._pending_rows() > 0):
                    break
                now = time.monotonic()
                if deadline is None:
                    deadline = now + self.coalesce_delay_s
                if now >= deadline:
                    break
                self.cv.wait(deadline - now)
            return sq, items

    def _loop(self) -> None:
        while True:
            sq, items = self._drain()
            if not items:
                if self.stop:
                    return
                continue
            try:
                self._execute(sq, items)
            except BaseException as e:          # the lane must never die
                for it in items:
                    if not it.done.is_set():
                        it.error = e
                        it.done.set()
            finally:
                with self.cv:
                    self.busy = False
                    self.cv.notify_all()

    def _execute(self, sq: _ShardQueue, items: List[SubBatch]) -> None:
        # shed expired work at dequeue — BEFORE concat/compute; the whole
        # client batch will come back shed, so computing the rest of the
        # sub-batch would be wasted work on the saturated path
        live: List[SubBatch] = []
        for it in items:
            if it.ctx is not None and it.ctx.expired:
                it.shed = True
                it.shed_reason = "deadline"
                sq.stats["shed_sub_batches"] += 1
                it.done.set()
            else:
                live.append(it)
        if not live:
            return
        handle = live[0].handle
        # tracing: one exemplar span per coalesced dispatch — the first
        # live item with a sampled trace lends its context; downstream
        # (in-process serve or the serve RPC into a worker) re-parents
        # under this span via a deadline-free forwarded context
        span = None
        ctx_fwd = None
        tracer = self.tracer
        if tracer is not None:
            ex = next((it.ctx for it in live
                       if it.ctx is not None and it.ctx.trace_id
                       and tracer.sampled(it.ctx.trace_id)), None)
            if ex is not None:
                span = tracer.start(
                    "lane.execute", ex.trace_id, parent_id=ex.parent_span,
                    tags={"lane": self.lane_id, "shard": sq.shard_id,
                          "n_coalesced": len(live)})
            if span is not None:
                from repro.core.results import RequestContext
                ctx_fwd = RequestContext(trace_id=ex.trace_id,
                                         parent_span=span.span_id)
        # per-RPC deadline (process backend): the serve RPC gets the
        # tightest remaining request budget among the coalesced items,
        # so a wedged worker turns into a bounded TimeoutError → shed
        # instead of pinning the lane for the transport's default 120 s
        timeout_s: Optional[float] = None
        if getattr(handle, "supports_rpc_deadline", False):
            for it in live:
                if it.ctx is not None and it.ctx.deadline is not None:
                    rem = it.ctx.remaining()
                    if rem is not None:
                        timeout_s = rem if timeout_s is None \
                            else min(timeout_s, rem)
            if timeout_s is not None:
                timeout_s = max(timeout_s, 0.05)
        keys = np.concatenate([it.keys for it in live])
        ts = np.concatenate([it.ts for it in live])
        rows = None
        if any(it.rows is not None for it in live):
            V = len(handle.table.schema.value_cols)
            rows = np.concatenate(
                [it.rows if it.rows is not None
                 else np.zeros((len(it), V), np.float32) for it in live])
        B = len(keys)
        step = self.dispatch_rows
        col_parts: List[Dict[str, np.ndarray]] = []
        st_parts: List[np.ndarray] = []
        tver = -1
        wm_min: Optional[float] = None
        age_max: Optional[float] = None
        try:
            for s0 in range(0, B, step):
                ke = keys[s0:s0 + step]
                te = ts[s0:s0 + step]
                re = rows[s0:s0 + step] if rows is not None else None
                nb = len(ke)
                bk = _bucket(nb)
                if bk > nb:
                    # edge-pad: repeat the last row so pad rows carry KNOWN
                    # keys (no unknown-key status pollution) and the
                    # executable set stays one-per-bucket
                    pad = bk - nb
                    ke = np.concatenate([ke, np.repeat(ke[-1:], pad)])
                    te = np.concatenate([te, np.repeat(te[-1:], pad)])
                    if re is not None:
                        re = np.concatenate(
                            [re, np.repeat(re[-1:], pad, axis=0)])
                kw = {"n_live": nb}     # pad rows are shape filler: the
                # engine serves them but excludes them from freshness /
                # drift sketches (bit-for-bit cross-backend contract)
                if timeout_s is not None:
                    kw["timeout_s"] = timeout_s
                if ctx_fwd is not None:
                    kw["ctx"] = ctx_fwd
                frame = handle.request(ke, te, re, **kw)
                col_parts.append(
                    {k: np.asarray(v)[:nb] for k, v in frame.columns.items()})
                st_parts.append(np.asarray(frame.status)[:nb])
                tver = max(tver, frame.table_version)
                if frame.watermark is not None:
                    wm = frame.watermark
                    wm_min = wm if wm_min is None else min(wm_min, wm)
                if frame.feature_age is not None:
                    age = frame.feature_age
                    age_max = age if age_max is None \
                        else max(age_max, age)
                self.stats["dispatches"] += 1
                self.stats["rows"] += nb
        except (ShardDownError, TimeoutError) as e:
            # dead worker — or one that blew the per-RPC deadline: shed,
            # don't error — the caller gets a clean whole-batch
            # STATUS_SHED while the supervisor respawns / retries
            reason = "worker_down" if isinstance(e, ShardDownError) \
                else "deadline"
            if span is not None:
                tracer.finish(span, tags={"shed": reason})
            for it in live:
                it.shed = True
                it.shed_reason = reason
                sq.stats["shed_sub_batches"] += 1
                it.done.set()
            return
        except BaseException as e:
            if span is not None:
                tracer.finish(span, tags={"error": type(e).__name__})
            for it in live:
                it.error = e
                it.done.set()
            return
        if span is not None:
            tracer.finish(span, tags={"rows": B})
        cols = {k: (np.concatenate([p[k] for p in col_parts])
                    if len(col_parts) > 1 else col_parts[0][k])
                for k in col_parts[0]}
        status = (np.concatenate(st_parts) if len(st_parts) > 1
                  else st_parts[0])
        s = 0
        for it in live:
            e = s + len(it)
            it.columns = {k: v[s:e] for k, v in cols.items()}
            it.status = status[s:e]
            it.table_version = tver
            it.watermark = wm_min
            it.feature_age = age_max
            sq.stats["sub_batches"] += 1
            it.done.set()
            s = e

    def close(self) -> None:
        with self.cv:
            self.stop = True
            self.cv.notify_all()
        if self.thread is not None:
            self.thread.join(timeout=5.0)


class ShardRouter:
    """Owns the per-shard queues, the execution lanes that serve them,
    and the scatter/gather plumbing."""

    def __init__(self, n_shards: int, *, dispatch_rows: int = 256,
                 coalesce_delay_s: float = 0.002,
                 n_lanes: Optional[int] = None):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        self.n_shards = n_shards
        self.dispatch_rows = dispatch_rows
        n_lanes = min(n_shards, max(1, n_lanes or n_shards))
        self.lanes = [_Lane(i, dispatch_rows,
                            coalesce_delay_s=coalesce_delay_s)
                      for i in range(n_lanes)]
        # shard s -> lane s % L: aligned with the engine's device
        # placement (shard s -> device s % D), so a lane's queues all
        # target the same device when L == D
        self.queues = [_ShardQueue(s, self.lanes[s % n_lanes])
                       for s in range(n_shards)]
        for sq in self.queues:
            sq.lane.queues.append(sq)
        for lane in self.lanes:
            lane.start()
        self._closed = False
        self._tracer = None

    # ------------------------------------------------------------- tracing
    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, t) -> None:
        """Share one tracer with every lane (sharded-engine wiring);
        lanes open one ``lane.execute`` span per coalesced dispatch."""
        self._tracer = t
        for lane in self.lanes:
            lane.tracer = t

    # ------------------------------------------------------------- scatter
    def submit(self, shard: int, item: SubBatch) -> SubBatch:
        return self.queues[shard].submit(item)

    def scatter(self, handles: Sequence, keys: np.ndarray, ts: np.ndarray,
                rows: Optional[np.ndarray], ctx=None,
                owners: Optional[np.ndarray] = None
                ) -> List[Tuple[np.ndarray, SubBatch]]:
        """Split a batch by key hash and enqueue one SubBatch per owning
        shard (``handles[s]`` serves shard ``s``). Returns
        ``[(original_row_indices, sub_batch), ...]``. ``owners`` lets the
        caller supply a precomputed (B,) shard-id array — the sharded
        engine passes its consistent-hash route table's answer; the
        default stays the pure modulo partitioner."""
        sid = owners if owners is not None \
            else shard_ids(keys, self.n_shards)
        out: List[Tuple[np.ndarray, SubBatch]] = []
        for s in np.unique(sid):
            idx = np.flatnonzero(sid == s)
            item = SubBatch(handles[s], keys[idx], ts[idx],
                            rows[idx] if rows is not None else None,
                            ctx=ctx)
            out.append((idx, self.queues[s].submit(item)))
        return out

    @staticmethod
    def gather(parts: List[Tuple[np.ndarray, SubBatch]], B: int,
               timeout: float = 120.0):
        """Wait for every sub-batch and reassemble columns/status in the
        original request order. Returns ``(columns, status,
        table_versions_by_part, any_shed)``; raises the first sub-batch
        error."""
        for _, it in parts:
            if not it.done.wait(timeout):
                raise TimeoutError(
                    f"shard {it.handle} did not answer within {timeout}s")
        for _, it in parts:
            if it.error is not None:
                raise it.error
        if any(it.shed for _, it in parts):
            return None, None, [], True
        columns: Dict[str, np.ndarray] = {}
        status = np.zeros(B, np.int8)
        tvers = []
        for idx, it in parts:
            for k, v in it.columns.items():
                col = columns.get(k)
                if col is None:
                    col = np.zeros((B,) + v.shape[1:], v.dtype)
                    columns[k] = col
                col[idx] = v
            status[idx] = it.status
            tvers.append(it.table_version)
        return columns, status, tvers, False

    # ----------------------------------------------------------------- tune
    def set_dispatch_rows(self, rows: int) -> int:
        """Retune the coalescing chunk size live (control-plane knob).
        Lanes read it per drain/execute, so the next dispatch uses the
        new chunking; ``max_drain_rows`` keeps its 4x relation. Returns
        the previous value."""
        if rows < 1:
            raise ValueError(f"dispatch_rows must be >= 1, got {rows}")
        prev = self.dispatch_rows
        self.dispatch_rows = rows
        for lane in self.lanes:
            with lane.cv:
                lane.dispatch_rows = rows
                lane.max_drain_rows = 4 * rows
                lane.cv.notify_all()
        return prev

    def set_coalesce_delay(self, seconds: float) -> float:
        """Retune how long an otherwise-idle lane waits to fill a chunk.
        Returns the previous value."""
        if seconds < 0:
            raise ValueError(f"coalesce_delay_s must be >= 0, got {seconds}")
        prev = self.lanes[0].coalesce_delay_s if self.lanes else 0.0
        for lane in self.lanes:
            with lane.cv:
                lane.coalesce_delay_s = seconds
                lane.cv.notify_all()
        return prev

    # -------------------------------------------------------------- elastic
    def add_queue(self) -> int:
        """Grow by one shard queue (consistent-hash resharding): the new
        queue rides an existing lane round-robin (``s % n_lanes``), so no
        new execution thread is needed. Returns the new shard id."""
        s = len(self.queues)
        lane = self.lanes[s % len(self.lanes)]
        sq = _ShardQueue(s, lane)
        with lane.cv:
            if lane.stop or not lane.accepting:
                raise RuntimeError("shard router is closed")
            lane.queues.append(sq)
            self.queues.append(sq)
        self.n_shards = len(self.queues)
        return s

    # --------------------------------------------------------------- intro
    @property
    def n_lanes(self) -> int:
        return len(self.lanes)

    def queue_depths(self) -> List[int]:
        return [sq.queue_depth for sq in self.queues]

    def stats(self) -> Dict[str, float]:
        agg = {"dispatches": 0, "rows": 0, "sub_batches": 0,
               "shed_sub_batches": 0, "max_queue_depth": 0,
               "n_lanes": len(self.lanes)}
        for lane in self.lanes:
            agg["dispatches"] += lane.stats["dispatches"]
            agg["rows"] += lane.stats["rows"]
        for sq in self.queues:
            agg["sub_batches"] += sq.stats["sub_batches"]
            agg["shed_sub_batches"] += sq.stats["shed_sub_batches"]
            agg["max_queue_depth"] = max(agg["max_queue_depth"],
                                         sq.stats["max_queue_depth"])
        agg["rows_per_dispatch"] = (agg["rows"] / agg["dispatches"]
                                    if agg["dispatches"] else 0.0)
        return agg

    def retire_queue(self, s: int) -> None:
        """Flip shard ``s`` to shed-on-submit. Items already queued were
        submitted before retirement and still execute (the runtime stays
        open through the following ``drain_shard``); anything arriving
        later — a scatter that routed on the pre-reshard table — sheds
        as ``worker_down`` instead of racing the runtime close."""
        sq = self.queues[s]
        with sq.lane.cv:
            sq.retired = True

    def drain_shard(self, s: int, timeout: float = 30.0) -> bool:
        """Wait until shard ``s``'s queue is empty and its lane idle — a
        shard runtime about to be retired must not be closed with
        sub-batches still queued/executing against it. The lane's busy
        flag covers a *popped* item; requiring two consecutive idle
        observations closes the narrow window between an owners_of()
        read and the submit it feeds."""
        sq = self.queues[s]
        lane = sq.lane
        deadline = time.monotonic() + timeout
        idle_seen = 0
        while time.monotonic() < deadline:
            with lane.cv:
                idle = not sq.q and not lane.busy
            idle_seen = idle_seen + 1 if idle else 0
            if idle_seen >= 2:
                return True
            time.sleep(0.005)
        return False

    def shutdown(self, *, drain: bool = True, timeout: float = 30.0
                 ) -> None:
        """Stop the router. With ``drain=True`` (the graceful path —
        mirrors ``DynamicBatcher.close()``): new submits fail fast
        immediately, but every already-queued sub-batch COMPLETES before
        any lane thread stops — an in-flight gather can never race a
        closing queue. ``drain=False`` is the old fail-fast close: queued
        items error out with "shard router closed"."""
        if self._closed:
            return
        self._closed = True
        # 1) stop accepting new work everywhere, atomically per lane
        for lane in self.lanes:
            with lane.cv:
                lane.accepting = False
                lane.cv.notify_all()
        # 2) drain: wait until every queue is empty AND every lane has
        #    finished the items it already popped
        if drain:
            deadline = time.monotonic() + timeout
            for lane in self.lanes:
                with lane.cv:
                    while ((lane.busy or any(sq.q for sq in lane.queues))
                           and time.monotonic() < deadline):
                        lane.cv.wait(0.05)
        # 3) only now stop the lane threads (fail-fasting any remainder —
        #    none on the drain path unless the timeout was hit)
        for lane in self.lanes:
            with lane.cv:
                lane.stop = True
                lane.cv.notify_all()
        for lane in self.lanes:
            if lane.thread is not None:
                lane.thread.join(timeout=5.0)

    def close(self) -> None:
        """Fail-fast close (legacy semantics): queued-but-unstarted work
        errors out instead of completing. Prefer ``shutdown()``."""
        self.shutdown(drain=False)
