"""Sharded serving runtime: hash-partitioned shard engines behind the
single-engine API (DESIGN.md §9, §11).

``ShardedEngine`` wraps N key-hash-partitioned shard engines — in this
process (default) or one subprocess per shard (``backend="process"`` /
``REPRO_SHARD_BACKEND=process``, see ``shard/proc/``); a ``ShardRouter``
scatters request batches to per-shard coalescing workers and gathers
rows back in request order; a consistent-hash ring (``shard/ring.py``)
owns key -> shard placement so the shard count can grow/shrink under
live traffic; a ``ResourceManager`` bounds per-deployment concurrency
and sheds past-deadline (or dead-worker) work whole-batch.
"""
from repro.shard.engine import (ShardConfig, ShardedDeploymentHandle,
                                ShardedEngine, ShardedPipeline)
from repro.shard.resource import AdmissionConfig, ResourceManager
from repro.shard.ring import HashRing, ModuloRouting, RouteTable, \
    key_hash, key_hashes
from repro.shard.router import ShardDownError, ShardRouter, shard_ids, \
    shard_of

__all__ = ["ShardConfig", "ShardedEngine", "ShardedDeploymentHandle",
           "ShardedPipeline", "AdmissionConfig", "ResourceManager",
           "ShardRouter", "ShardDownError", "shard_ids", "shard_of",
           "HashRing", "RouteTable", "ModuloRouting", "key_hash",
           "key_hashes"]
