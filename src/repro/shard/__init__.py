"""Sharded serving runtime: hash-partitioned shard engines behind the
single-engine API (DESIGN.md §9).

``ShardedEngine`` wraps N key-hash-partitioned shard engines; a
``ShardRouter`` scatters request batches to per-shard coalescing workers
and gathers rows back in request order; a ``ResourceManager`` bounds
per-deployment concurrency and sheds past-deadline work whole-batch.
"""
from repro.shard.engine import (ShardConfig, ShardedDeploymentHandle,
                                ShardedEngine, ShardedPipeline)
from repro.shard.resource import AdmissionConfig, ResourceManager
from repro.shard.router import ShardRouter, shard_ids, shard_of

__all__ = ["ShardConfig", "ShardedEngine", "ShardedDeploymentHandle",
           "ShardedPipeline", "AdmissionConfig", "ResourceManager",
           "ShardRouter", "shard_ids", "shard_of"]
