"""Key-range migration primitives for consistent-hash resharding.

Two operations over a plain :class:`repro.core.engine.Engine` (the
process backend exposes the same pair as worker RPCs):

* :func:`extract_events` — read a set of keys' **retained** events out
  of a table's published snapshot, globally ts-sorted with per-key
  arrival order preserved (stable sort), ready to re-insert elsewhere.
* :func:`migrate_in` — insert extracted events into a target engine,
  skipping any prefix the target already holds. The skip matters because
  migration never physically deletes the source copy (stale rows are
  harmless — routing never sends readers there, and ``query_offline``
  filters by current ownership): a key that moves A→B and later back
  B→A finds its pre-move history still on A, and re-inserting it would
  both duplicate rows and violate the table's per-key non-decreasing-ts
  invariant. Events strictly newer than the target's last ts are always
  inserted; at an equal-ts boundary the target's tail count at that ts
  decides how many of the source's equal-ts events are new (exact unless
  capacity trimming split an equal-ts run — a documented edge; see
  DESIGN.md §11).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["extract_events", "migrate_in", "list_keys"]


def list_keys(eng, table: str) -> List:
    """All keys materialised in ``table`` on this engine."""
    return list(eng.tables[table].key_to_idx.keys())


def _retained(tab, idx: int) -> Tuple[np.ndarray, np.ndarray]:
    """(ts (n,), rows (n, V)) retained for key slot ``idx``, oldest
    first — the same ring enumeration ``query_offline`` uses."""
    snap = tab.snapshot()
    totals = np.asarray(snap.state.total)
    ts_all = np.asarray(snap.state.ts)
    val_all = np.asarray(snap.state.values)
    C = ts_all.shape[1]
    tot = int(totals[idx])
    n = min(tot, C)
    slots = [p % C for p in range(tot - n, tot)]
    return (ts_all[idx, slots].astype(np.float32),
            val_all[idx, slots].astype(np.float32))


def extract_events(eng, table: str, keys: Sequence
                   ) -> Tuple[List, np.ndarray, np.ndarray]:
    """Pull the retained events of ``keys`` from ``table``'s published
    snapshot. Returns ``(keys, ts, rows)`` globally ts-sorted (stable,
    so per-key order survives the merge); empty arrays when none of the
    keys have rows."""
    tab = eng.tables[table]
    V = len(tab.schema.value_cols)
    out_k: List = []
    out_t: List[np.ndarray] = []
    out_r: List[np.ndarray] = []
    for k in keys:
        idx = tab.key_to_idx.get(k)
        if idx is None:
            continue
        ts, rows = _retained(tab, int(idx))
        if not len(ts):
            continue
        out_k.extend([k] * len(ts))
        out_t.append(ts)
        out_r.append(rows)
    if not out_k:
        return [], np.zeros((0,), np.float32), np.zeros((0, V), np.float32)
    ts = np.concatenate(out_t)
    rows = np.concatenate(out_r)
    order = np.argsort(ts, kind="stable")
    return ([out_k[int(i)] for i in order], ts[order].astype(np.float32),
            rows[order].astype(np.float32))


def migrate_in(eng, table: str, keys: Sequence, ts: np.ndarray,
               rows: np.ndarray) -> int:
    """Insert extracted events into this engine's ``table``, skipping
    whatever prefix the target already holds (stale copy from an earlier
    migration-out). Returns the number of events inserted."""
    if not len(keys):
        return 0
    tab = eng.tables[table]
    ts = np.asarray(ts, np.float32)
    rows = np.asarray(rows, np.float32)
    last = tab.last_ts_by_key()
    # equal-ts boundary: how many events at exactly last_ts the target
    # retains per key — that many of the source's equal-ts events are the
    # shared prefix, the rest are genuinely new
    eq_seen: Dict[object, int] = {}
    keep = np.zeros(len(keys), bool)
    for i, k in enumerate(keys):
        lt = last.get(k)
        t = float(ts[i])
        if lt is None or t > lt:
            keep[i] = True
        elif t == lt:
            if k not in eq_seen:
                idx = tab.key_to_idx.get(k)
                kts, _ = _retained(tab, int(idx)) if idx is not None else \
                    (np.zeros(0, np.float32), None)
                eq_seen[k] = int(np.sum(kts == np.float32(lt)))
            if eq_seen[k] > 0:
                eq_seen[k] -= 1          # shared-prefix event: skip it
            else:
                keep[i] = True           # new event at the boundary ts
    idxs = np.flatnonzero(keep)
    if not idxs.size:
        return 0
    # donate=False: the target engine is LIVE — a lane thread may be
    # serving off a snapshot of this table right now, and a donating
    # ingest would delete the buffers out from under it
    eng.insert(table, [keys[int(i)] for i in idxs],
               ts[idxs].tolist(), rows[idxs], donate=False)
    return int(idxs.size)
