"""ShardedEngine: horizontal scale-out behind the single-engine API.

DESIGN.md §9/§11. N key-hash-partitioned **shard engines** — each a full
:class:`repro.core.engine.Engine` with its own tables, device-resident
key directory, plan cache, and (when streams are attached) ingest
pipeline with its own watermarks — behind the familiar ``create_table /
insert / attach_stream / deploy / request / query_offline`` surface.

Two backends host the shard set (``ShardedEngine(backend=...)``, the
``REPRO_SHARD_BACKEND`` env var, or ``ShardConfig.backend``):

* ``"inprocess"`` (default) — shard engines are objects in this
  process, optionally pinned to distinct jax devices. Zero transport
  cost, but every shard shares one GIL and one jax runtime.
* ``"process"`` — each shard engine lives in its OWN subprocess
  (``shard/proc/``) with its own Python interpreter and jax runtime,
  pinned via per-process env (``--xla_force_host_platform_device_count``
  etc. — jax reads them once at import, which is exactly why threads
  cannot do this). Scatter/gather sub-batches, control RPCs and
  telemetry snapshots cross a length-prefixed pickle channel; worker
  death is supervised (shed → respawn → catalog replay → re-warm).

* **Routing** (``shard/ring.py``): a consistent-hash ring (virtual
  nodes) replaces the bare ``hash % N`` partitioner, so the shard count
  can grow/shrink under live traffic — ``add_shard``/``remove_shard``
  migrate only the key ranges adjacent to the moved virtual nodes,
  interval by interval, while reads keep routing consistently (the old
  owner retains a stale copy until its range flips; readers are never
  sent to a shard that does not yet hold the data).
  ``ShardConfig(partitioner="modulo")`` keeps the pure modulo routing
  as an escape hatch (it cannot reshard).
* **Deployments**: ``deploy`` compiles one executable set per shard
  (``Engine.build_version``) and then publishes the whole set under ONE
  :class:`ShardedDeploymentHandle` — hot swap, counter-based canary and
  rollback operate on the set atomically; a batch is always served by a
  single (version, shard-set). The serialized control RPCs of the
  process backend keep ``build -> publish`` atomic across workers via
  the same version vector.
* **Tables**: partitioned by default; ``replicate=True`` broadcasts a
  table to every shard (dimension tables — LAST JOIN probes then
  resolve through the owning shard's local replica, no cross-shard
  hop). Replicated ingest through the process backend serializes the
  payload ONCE and fans the same bytes to every worker.
* **Transactional ingest**: a multi-shard ``insert`` into a
  stream-attached table is all-or-nothing — phase 1 ``prepare``s the
  per-shard slices against every involved stream buffer (validating
  frontiers), phase 2 ``commit``s them (the buffers hold their
  watermarks so a prepared slice can never become late in between);
  any reject aborts every prepared slice with nothing staged.
* **Offline parity**: ``query_offline`` runs per shard against pinned
  snapshots and stamps the result with the cross-shard **version
  vector**; rows are filtered by CURRENT ring ownership so stale
  migration copies never surface, keeping outputs bit-identical to the
  unsharded engine before/during/after a reshard.
* **Admission control** (``shard/resource.py``): per-deployment
  in-flight and queue-depth bounds plus deadline shedding; a dead
  worker sheds with an explicit ``worker_down`` reason instead of
  hanging gathers.
"""
from __future__ import annotations

import collections
import dataclasses
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple, \
    Union

import numpy as np

from repro.core import dsl
from repro.core.engine import DeploymentHandle, Engine, HandleMetrics
from repro.core.logical import Query
from repro.core.optimizer import CostModel, OptFlags
from repro.core.results import (STATUS_DEGRADED, STATUS_OK, STATUS_SHED,
                                FeatureFrame, RequestContext)
from repro.featurestore.table import TableSchema
from repro.obs.flight import FlightRecorder
from repro.obs.freshness import FreshnessTracker
from repro.obs.sketch import DriftMonitor, QuantileSketch, RollingSketch
# stdlib-only module: importing the plan type does not pull the proc
# backend (or jax) into in-process users
from repro.shard.proc.faults import FaultPlan
from repro.shard.resource import AdmissionConfig, ResourceManager
from repro.shard.ring import HashRing, ModuloRouting, RouteTable, \
    key_hashes
from repro.shard.router import ShardDownError, ShardRouter, shard_ids, \
    shard_of
from repro.streaming.wal import WalConfig, read_dir as wal_read_dir, \
    resolve_shard as wal_resolve_shard

__all__ = ["ShardConfig", "ShardedEngine", "ShardedDeploymentHandle",
           "ShardedPipeline"]


@dataclass(frozen=True)
class ShardConfig:
    n_shards: int = 2
    dispatch_rows: int = 256          # coalesced rows per shard dispatch
    # max wait for a worker to fill one dispatch chunk (batcher-style
    # deadline policy; 0 disables waiting)
    coalesce_delay_s: float = 0.002
    # execution lanes (worker threads). None = one per distinct device
    # in use for the in-process backend (more execution streams than
    # devices just thrashes) and one per shard for the process backend
    # (lanes block on channel I/O with the GIL released, so a lane per
    # worker keeps every subprocess busy)
    n_lanes: Optional[int] = None
    admission: AdmissionConfig = AdmissionConfig()
    # pin shard s to jax device s % D when more than one device exists;
    # set False to keep default placement (all shards on device 0)
    pin_devices: bool = True
    # "inprocess" | "process"; None resolves REPRO_SHARD_BACKEND, then
    # "inprocess"
    backend: Optional[str] = None
    # "ring" (consistent hash, elastic) | "modulo" (pure hash % N,
    # cannot reshard)
    partitioner: str = "ring"
    vnodes: int = 64                  # ring points per shard
    migrate_batch_arcs: int = 8       # arcs copied per migration step
    # max time _reshard keeps retrying one arc batch across worker
    # deaths before giving up (a respawn + WAL replay fits many times)
    reshard_retry_s: float = 60.0
    # --- durability / chaos tier (DESIGN.md §12) -------------------------
    # base directory for per-shard write-ahead ingest logs; None disables.
    # Partitioned stream-attached tables get a WAL at
    # ``<wal_dir>/shard-<s>/<table>/`` injected into their PipelineConfig;
    # on worker death (process backend) the dead shard's log is archived,
    # then replayed through the live route table after respawn
    wal_dir: Optional[str] = None
    # pre-forked workers kept past jax import for sub-second adoption on
    # respawn (process backend; 0 disables the pool)
    standby_workers: int = 0
    # persistent jax compilation cache shared by worker incarnations, so
    # a respawned worker loads serialized executables instead of
    # recompiling (compile dominates recovery MTTR once the standby pool
    # has amortized interpreter startup). None defaults to
    # ``<wal_dir>/.jax-cache`` when a WAL dir is configured
    compile_cache_dir: Optional[str] = None
    # stale-tier cache: last served feature row per key, used to answer
    # STATUS_DEGRADED while a shard is down/replaying (0 disables)
    degraded_cache_keys: int = 4096
    # chaos: fault plan for the worker transport (process backend); None
    # falls back to the REPRO_FAULT_PLAN env var, then no faults
    fault_plan: Optional[FaultPlan] = None


@dataclass
class ShardedHandleMetrics:
    requests: int = 0
    batches: int = 0
    shed_requests: int = 0
    shed_batches: int = 0
    degraded_requests: int = 0     # rows answered from the stale tier
    degraded_batches: int = 0      # batches with >= 1 DEGRADED row
    serve_s: float = 0.0
    canary_batches: int = 0
    canary_max_abs_diff: float = 0.0
    # end-to-end (scatter->gather) per-batch latency, in the same
    # rolling sketch HandleMetrics uses — the control plane's replan
    # p99 health check works identically when sharded, and the sketch
    # merges exactly with per-shard serve sketches (DESIGN.md §14)
    latency_s: RollingSketch = dataclasses.field(
        default_factory=lambda: RollingSketch(
            window_s=HandleMetrics.LATENCY_WINDOW_S))

    def observe_latency(self, seconds: float) -> None:
        self.latency_s.observe(float(seconds))

    def latency_percentile(self, pct: float) -> float:
        return self.latency_s.percentile(pct)

    def snapshot(self) -> Dict[str, float]:
        """JSON-serializable copy (sketch rides along, mergeable)."""
        sk = self.latency_s.sketch()
        return {
            "requests": self.requests, "batches": self.batches,
            "shed_requests": self.shed_requests,
            "shed_batches": self.shed_batches,
            "degraded_requests": self.degraded_requests,
            "degraded_batches": self.degraded_batches,
            "serve_s": self.serve_s,
            "canary_batches": self.canary_batches,
            "canary_max_abs_diff": self.canary_max_abs_diff,
            "latency_samples": len(self.latency_s),
            "latency_p50_s": sk.percentile(50),
            "latency_p99_s": sk.percentile(99),
            "latency_sketch": sk.to_dict(),
        }


@dataclass
class _TableSpec:
    schema: TableSchema
    replicated: bool
    # resolved per-shard creation kwargs, replayed when a shard is added
    # (elastic reshard) or a dead worker is respawned
    create_kw: Dict[str, object] = dataclasses.field(default_factory=dict)


class ShardedDeploymentHandle:
    """One version of a deployment across every shard — the sharded
    serving endpoint. Owns the per-shard :class:`DeploymentHandle`s; the
    router dispatches against THESE handles directly, so a mid-redeploy
    inner-engine state is invisible to in-flight batches (same
    handle-owned-executable argument as the single-engine swap).

    ``handles[s]`` may be ``None`` for shard slots retired before this
    version was deployed — routing never selects a retired slot."""

    def __init__(self, engine: "ShardedEngine", name: str, version: int,
                 handles: Sequence[Optional[DeploymentHandle]]):
        self.engine = engine
        self.name = name
        self.version = version
        self.handles: Tuple[Optional[DeploymentHandle], ...] = \
            tuple(handles)
        self.state = DeploymentHandle.WARMING
        self.metrics = ShardedHandleMetrics()
        # the deploy-time inputs, kept so a respawned worker (or a newly
        # added shard) can rebuild this exact version
        self.query: Optional[Query] = None
        self.warm_buckets: Optional[Tuple[int, ...]] = None
        self._canary: Optional[Tuple["ShardedDeploymentHandle", float]] = \
            None
        self._canary_counter = 0
        self._lock = threading.Lock()
        # stale tier (degradation ladder OK→DEGRADED→SHED): the last
        # feature row served per key, LRU-bounded. While a shard is down
        # its keys answer from here with STATUS_DEGRADED instead of
        # shedding the whole batch — possibly stale, never wrong-key
        self._stale: "collections.OrderedDict" = collections.OrderedDict()
        self._stale_cap = int(engine.cfg.degraded_cache_keys)

    # ------------------------------------------------------------ identity
    @property
    def tag(self) -> str:
        return f"{self.name}@v{self.version}x{len(self.handles)}"

    @property
    def live(self) -> bool:
        return self.state == DeploymentHandle.LIVE

    def _first(self) -> DeploymentHandle:
        return next(h for h in self.handles if h is not None)

    @property
    def plan(self):
        return self._first().plan

    @property
    def phys(self):
        return self._first().phys

    @property
    def table(self):
        """A live shard's table — schema/introspection only; mutation
        must go through the sharded engine (routing)."""
        return self._first().table

    def __repr__(self) -> str:
        return (f"ShardedDeploymentHandle({self.name!r} v{self.version} "
                f"[{self.state}] x{len(self.handles)} shards)")

    # ------------------------------------------------------------ warm etc
    def warm(self, buckets: Sequence[int]) -> int:
        return sum(h.warm(buckets) for h in self.handles
                   if h is not None)

    def version_vector(self) -> Tuple[int, ...]:
        """Per-shard table versions (shard order, active slots) now."""
        return tuple(h.table.version for h in self.handles
                     if h is not None)

    def join_staleness(self) -> Dict[str, Dict[str, float]]:
        """Cross-shard rollup of the per-shard staleness metrics. Age
        percentiles come from the EXACT merge of per-shard sketches —
        the merged p99 is what one engine observing the union would
        report, not a worst-shard max (DESIGN.md §14)."""
        out: Dict[str, Dict[str, float]] = {}
        sketches: Dict[str, list] = {}
        for h in self.handles:
            if h is None:
                continue
            for t, st in h.join_staleness().items():
                agg = out.setdefault(t, {"probes": 0, "matches": 0,
                                         "age_samples": 0})
                agg["probes"] += st["probes"]
                agg["matches"] += st["matches"]
                agg["age_samples"] += st["age_samples"]
                sk = st.get("age_sketch")
                if sk is not None:
                    sketches.setdefault(t, []).append(sk)
        for t, agg in out.items():
            agg["match_rate"] = (agg["matches"] / agg["probes"]
                                 if agg["probes"] else 0.0)
            merged = QuantileSketch.merged(sketches.get(t, ()))
            agg["age_p50"] = merged.percentile(50)
            agg["age_p99"] = merged.percentile(99)
            agg["age_sketch"] = merged.to_dict()
        return out

    # --------------------------------------------------------------- serve
    def request(self, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None,
                ctx: Optional[RequestContext] = None) -> FeatureFrame:
        """Serve one batch: admit -> (canary pick) -> scatter -> gather.

        Shedding is all-or-nothing: an expired deadline (at admission or
        while queued on any shard) returns a frame whose EVERY row is
        ``STATUS_SHED`` — never a mix of shed and computed rows."""
        eng = self.engine
        B = len(keys)
        trace = ctx.trace_id if ctx is not None else None
        if B == 0:
            return FeatureFrame(
                {n: np.zeros((0,), np.float32)
                 for n in self.phys.feature_names},
                status=np.zeros((0,), np.int8), deployment=self.name,
                version=self.version, trace_id=trace,
                version_vector=self.version_vector())
        if rows is None and self.plan.joins:
            raise ValueError(
                f"deployment {self.name!r} has {len(self.plan.joins)} "
                f"LAST JOIN(s); online requests must pass rows= — the "
                f"join probes read the request row's join-key column(s)")
        aspan = eng.tracer.start(
            "admission", trace,
            parent_id=ctx.parent_span if ctx is not None else None,
            tags={"deployment": self.name, "rows": B})
        adm = eng.resources.admit(self.name, ctx,
                                  queue_depths=eng.router.queue_depths)
        if aspan is not None:
            eng.tracer.finish(aspan, tags={"shed": adm.shed})
        if adm.shed:
            return self._shed_frame(B, trace, kind="admission")
        try:
            cand = None
            pinned = ctx is not None and ctx.version_pin is not None
            canary = None if pinned else self._canary
            if canary is not None:
                cand_handle, frac = canary
                with self._lock:
                    self._canary_counter += 1
                    n = self._canary_counter
                if int(n * frac) > int((n - 1) * frac):
                    cand = cand_handle
            if cand is None:
                return self._scatter_gather(keys, ts, rows, ctx, trace)
            # canary slice: candidate serves; incumbent recomputes as the
            # reference and the divergence lands on the candidate
            base = self._scatter_gather(keys, ts, rows, ctx, trace)
            new = cand._scatter_gather(keys, ts, rows, ctx, trace)
            diff = 0.0
            for nme, v in new.columns.items():
                ref = base.columns.get(nme)
                if ref is not None and np.size(v):
                    diff = max(diff, float(np.max(np.abs(
                        np.asarray(v, np.float64)
                        - np.asarray(ref, np.float64)))))
            with cand._lock:
                cand.metrics.canary_batches += 1
                cand.metrics.canary_max_abs_diff = max(
                    cand.metrics.canary_max_abs_diff, diff)
            return new
        finally:
            adm.release()

    def _scatter_gather(self, keys, ts, rows, ctx, trace) -> FeatureFrame:
        eng = self.engine
        t0 = time.perf_counter()
        karr = np.asarray(keys)
        ts_arr = np.asarray(ts, np.float32)
        row_arr = (np.asarray(rows, np.float32) if rows is not None
                   else None)
        B = len(karr)
        span = eng.tracer.start(
            "router.scatter_gather", trace,
            parent_id=ctx.parent_span if ctx is not None else None,
            tags={"deployment": self.name, "rows": B})
        if span is not None:
            # re-parent downstream spans (lane.execute, worker serve)
            # under this one
            ctx = (dataclasses.replace(ctx, parent_span=span.span_id)
                   if ctx is not None else
                   RequestContext(trace_id=trace,
                                  parent_span=span.span_id))
        try:
            parts = eng.router.scatter(self.handles, karr, ts_arr,
                                       row_arr, ctx=ctx,
                                       owners=eng.owners_of(karr))
            columns, status, _tvers, any_shed = \
                eng.router.gather(parts, B)
        except BaseException as e:
            if span is not None:
                eng.tracer.finish(span,
                                  tags={"error": type(e).__name__})
            raise
        if span is not None:
            eng.tracer.finish(
                span, tags={"n_sub_batches": len(parts),
                            "shed": bool(any_shed)})
        if any_shed:
            reasons = {it.shed_reason for _, it in parts if it.shed}
            if reasons == {"worker_down"} and self._stale_cap > 0:
                # degradation ladder: ONLY the dead shard's rows went
                # missing — try the stale tier before giving up on the
                # whole batch
                deg = self._degraded_frame(parts, B, trace)
                if deg is not None:
                    eng.resources.record_degraded(int(deg.n_degraded))
                    return deg
            shed_kind = ("worker_down" if "worker_down" in reasons
                         else "deadline")
            eng.resources.record_shed(kind=shed_kind)
            return self._shed_frame(B, trace, kind=shed_kind)
        self._remember(karr, columns, status)
        wall = time.perf_counter() - t0
        with self._lock:
            m = self.metrics
            m.requests += B
            m.batches += 1
            m.serve_s += wall
            m.observe_latency(wall)
        # freshness stamp across touched shards: MIN watermark (the
        # slowest shard bounds the batch) / MAX feature age
        wm = age = None
        for _, it in parts:
            if it.watermark is not None:
                wm = it.watermark if wm is None \
                    else min(wm, it.watermark)
            if it.feature_age is not None:
                age = it.feature_age if age is None \
                    else max(age, it.feature_age)
        vv = self.version_vector()
        eng.flight.record(
            "serve", trace=trace, deployment=self.tag, rows=B,
            version_vector=list(vv), watermark=wm, feature_age=age,
            serve_ms=wall * 1e3)
        return FeatureFrame(
            columns, status=status, deployment=self.name,
            version=self.version, trace_id=trace,
            table_version=max((h.table.version for h in self.handles
                               if h is not None), default=-1),
            latency={"serve_s": wall},
            version_vector=vv, watermark=wm, feature_age=age)

    # ------------------------------------------------------ stale tier
    @staticmethod
    def _ckey(key):
        return key.item() if isinstance(key, np.generic) else key

    def _remember(self, karr, columns, status) -> None:
        """Refresh the stale tier from a fully-computed batch: every
        STATUS_OK row's features, keyed by request key, LRU-evicted."""
        if self._stale_cap <= 0:
            return
        names = self.phys.feature_names
        mat = np.stack([np.asarray(columns[n], np.float32)
                        for n in names], axis=1)
        st = np.asarray(status)
        with self._lock:
            cache = self._stale
            for i in np.flatnonzero(st == STATUS_OK):
                k = self._ckey(karr[int(i)])
                cache[k] = mat[int(i)]
                cache.move_to_end(k)
            while len(cache) > self._stale_cap:
                cache.popitem(last=False)

    def _degraded_frame(self, parts, B: int, trace
                        ) -> Optional[FeatureFrame]:
        """Assemble a mixed frame: completed sub-batches keep their
        fresh rows/statuses; worker_down sub-batches answer from the
        stale tier with STATUS_DEGRADED. Returns ``None`` — meaning
        fall back to a whole-batch shed — if ANY dead-shard key has no
        cached row (a partially-degradable batch would otherwise need
        per-row shed statuses, which the shed contract forbids)."""
        names = self.phys.feature_names
        columns = {n: np.zeros((B,), np.float32) for n in names}
        status = np.zeros(B, np.int8)
        n_deg = 0
        with self._lock:
            for idx, it in parts:
                if not it.shed:
                    for kname, v in it.columns.items():
                        if kname in columns:
                            columns[kname][idx] = np.asarray(v, np.float32)
                    status[idx] = it.status
                    continue
                for j, key in zip(idx, it.keys):
                    row = self._stale.get(self._ckey(key))
                    if row is None:
                        return None
                    for fi, n in enumerate(names):
                        columns[n][int(j)] = row[fi]
                    status[int(j)] = STATUS_DEGRADED
                    n_deg += 1
            self.metrics.degraded_requests += n_deg
            self.metrics.degraded_batches += 1
        return FeatureFrame(
            columns, status=status, deployment=self.name,
            version=self.version, trace_id=trace,
            table_version=max((h.table.version for h in self.handles
                               if h is not None), default=-1),
            version_vector=self.version_vector())

    def _shed_frame(self, B: int, trace,
                    kind: str = "shed") -> FeatureFrame:
        with self._lock:
            self.metrics.shed_requests += B
            self.metrics.shed_batches += 1
        self.engine.flight.record("shed", trace=trace,
                                  deployment=self.tag, rows=B,
                                  shed_kind=kind)
        return FeatureFrame(
            {n: np.zeros((B,), np.float32)
             for n in self.phys.feature_names},
            status=np.full(B, STATUS_SHED, np.int8),
            deployment=self.name, version=self.version, trace_id=trace,
            version_vector=self.version_vector())

    def rollback(self) -> "ShardedDeploymentHandle":
        return self.engine.rollback(self.name)


class ShardedPipeline:
    """Streaming facade: one IngestPipeline per shard, each with its own
    watermarks/frontiers — routing by the engine's ring, so an event's
    reorder repair happens on the shard that stores it. Ownership is
    read UNDER the engine's route lock: an event must not land in a
    source shard's buffer after that shard's key range was extracted by
    an in-flight migration step."""

    def __init__(self, engine: "ShardedEngine", table: str,
                 pipes: Sequence, replicated: bool):
        self.engine = engine
        self.table = table
        self.pipes: List = list(pipes)   # grows under add_shard
        self.replicated = replicated

    def _active(self) -> List[Tuple[int, object]]:
        retired = self.engine._retired
        return [(s, p) for s, p in enumerate(self.pipes)
                if s not in retired]

    def _gate(self, s: int) -> None:
        """Refuse ingest into a shard whose worker is respawning/replaying
        its WAL. A fresh write landing in the rebuilt buffer BEFORE replay
        finishes would make ``migrate_in``'s prefix-skip drop the older
        replayed events — recovery would no longer be bit-identical. The
        producer sees :class:`ShardDownError` and retries after recovery."""
        client = getattr(self.pipes[s], "client", None)
        if client is not None and not getattr(client, "ready", True):
            raise ShardDownError(
                f"shard {s} is recovering (WAL replay in progress)")

    def push(self, key, ts: float, row: np.ndarray) -> bool:
        eng = self.engine
        if self.replicated:
            ok = True
            for _s, p in self._active():
                ok = p.push(key, ts, row) and ok
            return ok
        with eng._route_lock:
            s = eng._routing.owner(key)
            self._gate(s)
            return self.pipes[s].push(key, ts, row)

    def push_batch(self, keys: Sequence, ts: Sequence[float],
                   rows: np.ndarray, *, all_or_nothing: bool = False
                   ) -> int:
        keys = np.asarray(keys)
        ts = np.asarray(ts, np.float32)
        rows = np.asarray(rows, np.float32)
        eng = self.engine
        if self.replicated:
            return min(p.push_batch(keys, ts, rows,
                                    all_or_nothing=all_or_nothing)
                       for _s, p in self._active())
        with eng._route_lock:
            sid = eng._routing.owners_of(keys)
            n = 0
            for s in np.unique(sid):
                self._gate(int(s))
                idx = np.flatnonzero(sid == s)
                n += self.pipes[s].push_batch(
                    keys[idx], ts[idx], rows[idx],
                    all_or_nothing=all_or_nothing)
            return n

    def flush(self, *, flush_all: bool = True) -> None:
        for _s, p in self._active():
            p.flush(flush_all=flush_all)

    def wait_idle(self, timeout: float = 30.0) -> bool:
        return all(p.wait_idle(timeout) for _s, p in self._active())

    def warm(self) -> int:
        return sum(p.warm() for _s, p in self._active())

    def version_vector(self) -> Tuple[int, ...]:
        return tuple(p.table.version for _s, p in self._active())

    def metrics(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for _s, p in self._active():
            for k, v in p.metrics().items():
                out[k] = out.get(k, 0) + v
        out["n_shards"] = len(self._active())
        return out

    def close(self, *, drain: bool = True) -> None:
        for _s, p in self._active():
            p.close(drain=drain)


class ShardedEngine:
    """N hash-partitioned shard engines behind the Engine API."""

    def __init__(self, cfg: ShardConfig = ShardConfig(), *,
                 backend: Optional[str] = None,
                 flags: OptFlags = OptFlags(), **engine_kw):
        self.cfg = cfg
        self.flags = flags
        self._engine_kw = dict(engine_kw)
        S = cfg.n_shards
        kind = (backend or cfg.backend
                or os.environ.get("REPRO_SHARD_BACKEND") or "inprocess")
        if kind not in ("inprocess", "process"):
            raise ValueError(f"unknown shard backend {kind!r}; expected "
                             f"'inprocess' or 'process'")
        self.backend_kind = kind
        if kind == "process":
            from repro.shard.proc.backend import ProcShardBackend
            plan = cfg.fault_plan if cfg.fault_plan is not None \
                else FaultPlan.from_env()
            cache = cfg.compile_cache_dir or (
                os.path.join(cfg.wal_dir, ".jax-cache")
                if cfg.wal_dir else None)
            self.backend = ProcShardBackend(
                S, flags=flags, engine_kw=engine_kw,
                standby_workers=cfg.standby_workers, fault_plan=plan,
                compile_cache=cache)
            self.backend.reseed_hook = self._reseed_replicas
            self.backend.respawn_hook = self._replay_shard
            self.backend.prespawn_hook = self._archive_wal
            self.backend.replay_hook = self._replay_wal
            self.shards: List = list(self.backend.clients)
            self.devices: Tuple = tuple(None for _ in range(S))
            default_lanes = S
        else:
            import jax
            self.backend = None
            devices = jax.devices()
            self.devices = tuple(
                devices[s % len(devices)] if (cfg.pin_devices
                                              and len(devices) > 1)
                else None for s in range(S))
            self.shards = [Engine(flags, **engine_kw) for _ in range(S)]
            default_lanes = len({d for d in self.devices
                                 if d is not None}) or 1
        n_lanes = cfg.n_lanes if cfg.n_lanes is not None else default_lanes
        self.router = ShardRouter(S, dispatch_rows=cfg.dispatch_rows,
                                  coalesce_delay_s=cfg.coalesce_delay_s,
                                  n_lanes=n_lanes)
        self.resources = ResourceManager(cfg.admission)
        # shared observability (DESIGN.md §13): ONE tracer/profiler for
        # the parent tier; in-process shard engines record into the SAME
        # tracer (their own constructor-made one is replaced), so the
        # trace tree assembles in place. Process-backend workers keep
        # their own tracer and export spans per-RPC; the client adopts
        # them (re-based) into this tracer.
        from repro.obs.profile import OperatorProfiler
        from repro.obs.trace import Tracer
        self.tracer = Tracer(sample_rate=float(
            os.environ.get("REPRO_TRACE_SAMPLE", "0") or 0))
        self.profiler = OperatorProfiler()
        # parent-tier flight recorder (DESIGN.md §14): per-batch serve /
        # shed breadcrumbs, dumped on SLO breach (control plane) or
        # worker death (_archive_wal prespawn hook)
        self.flight = FlightRecorder()
        if self.backend is None:
            for sub in self.shards:
                sub.tracer = self.tracer
        else:
            for c in self.backend.clients:
                c.tracer = self.tracer
        self.router.tracer = self.tracer
        # ring routing state: readers (scatter, query_offline) read the
        # route table lock-free — a reader racing a range flip sees either
        # the old owner (which retains a stale copy: correct) or the new
        # owner (which already finished copying: correct). WRITERS must
        # hold _route_lock across owner-compute + staging so no event
        # lands in a source buffer after its range was extracted.
        self._route_lock = threading.RLock()
        if cfg.partitioner == "modulo":
            self._ring: Optional[HashRing] = None
            self._routing = ModuloRouting(S)
        elif cfg.partitioner == "ring":
            self._ring = HashRing(range(S), vnodes=cfg.vnodes)
            self._routing = RouteTable(self._ring)
        else:
            raise ValueError(f"unknown partitioner {cfg.partitioner!r}")
        self._retired: Set[int] = set()
        self.specs: Dict[str, _TableSpec] = {}
        self.streams: Dict[str, ShardedPipeline] = {}
        self._stream_cfgs: Dict[str, object] = {}
        self._models: Dict[str, Tuple[Callable, object]] = {}
        self.deployments: Dict[str, ShardedDeploymentHandle] = {}
        self._versions: Dict[str, Dict[int, ShardedDeploymentHandle]] = {}
        self._history: Dict[str, List[ShardedDeploymentHandle]] = {}
        self._deploy_lock = threading.RLock()
        # serializes reshard operations; taken OUTSIDE _deploy_lock so a
        # migration can wait out a worker respawn (whose hooks need the
        # deploy lock) without deadlocking
        self._reshard_lock = threading.Lock()
        # WAL recovery counters (latency_decomposition / telemetry)
        self.recovery_stats: Dict[str, float] = {
            "wal_replays": 0, "wal_replayed_events": 0,
            "wal_replay_lag_s": 0.0}
        self._closed = False

    # ------------------------------------------------------------ identity
    @property
    def n_shards(self) -> int:
        """ACTIVE shard count (grows/shrinks with add/remove_shard)."""
        return len(self.shards) - len(self._retired)

    def _active_ids(self) -> List[int]:
        return [s for s in range(len(self.shards))
                if s not in self._retired]

    def _primary(self):
        return self.shards[self._active_ids()[0]]

    @property
    def cache(self):
        """A live shard's plan cache (FeatureServer warm-gating compat)."""
        return self._primary().cache

    def shard_of(self, key) -> int:
        """Current owning shard of ``key`` under the ring (or modulo)."""
        return self._routing.owner(key)

    def owners_of(self, keys: np.ndarray) -> np.ndarray:
        return self._routing.owners_of(np.asarray(keys))

    @property
    def worker_restarts(self) -> int:
        """Total worker respawns (process backend; 0 in-process)."""
        if self.backend is None:
            return 0
        return sum(c.restarts for c in self.backend.clients)

    # ------------------------------------------------------------------ DDL
    def create_table(self, schema: TableSchema, *, max_keys: int = 1024,
                     capacity: int = 1024, bucket_size: int = 64,
                     join_keys: Sequence[str] = (),
                     replicate: bool = False,
                     per_shard_max_keys: Optional[int] = None) -> None:
        """Create the table on every shard.

        Partitioned (default): each shard holds the keys that hash to it;
        ``max_keys`` is the TOTAL key budget and each shard provisions
        ``max_keys/S`` plus 30% hash-skew headroom (override with
        ``per_shard_max_keys``). Replicated: every shard holds a full
        copy — required for LAST JOIN right tables, whose probes must
        resolve on the probing shard.
        """
        S = self.n_shards
        if replicate or per_shard_max_keys is None:
            per_shard = max_keys if replicate else max(
                16, int(1.3 * max_keys / S) + 8)
        else:
            per_shard = per_shard_max_keys
        create_kw = dict(max_keys=per_shard, capacity=capacity,
                         bucket_size=bucket_size,
                         join_keys=tuple(join_keys))
        for s in self._active_ids():
            self.shards[s].create_table(schema, device=self.devices[s],
                                        **create_kw)
        self.specs[schema.name] = _TableSpec(schema=schema,
                                             replicated=replicate,
                                             create_kw=create_kw)
        if self.backend is not None:
            self.backend.log_ddl("create_table", schema=schema,
                                 **create_kw)

    def tables_of(self, name: str) -> Tuple:
        """The per-shard Table objects for ``name`` (shard order;
        in-process backend only — a subprocess's tables are not
        reachable as objects, which is rather the point)."""
        if self.backend is not None:
            raise NotImplementedError(
                "tables_of() reaches into shard-engine objects; the "
                "process backend keeps those in worker subprocesses — "
                "use query_offline / telemetry snapshots instead")
        return tuple(self.shards[s].tables[name]
                     for s in self._active_ids())

    def insert(self, table: str, keys: Sequence, ts: Sequence[float],
               rows: np.ndarray) -> None:
        """Bulk insert, routed to owning shards (replicated tables fan
        out to all — one serialized payload broadcast under the process
        backend). For stream-attached partitioned tables the multi-shard
        write is TRANSACTIONAL: every involved shard prepares its slice,
        then all commit — or any reject aborts them all with nothing
        staged (matching ``Engine.insert``'s atomic contract, but across
        shards)."""
        spec = self._spec(table)
        keys = np.asarray(keys)
        ts = np.asarray(ts, np.float32)
        rows = np.asarray(rows, np.float32)
        if spec.replicated:
            if self.backend is not None:
                self.backend.broadcast("insert", only=self._active_ids(),
                                       table=table, keys=keys.tolist(),
                                       ts=ts.tolist(), rows=rows)
            else:
                # donate=False: the shard engines are live — their lane
                # threads serve off table snapshots concurrently with
                # this write, so donating ingest would delete buffers
                # under an in-flight request
                for s in self._active_ids():
                    self.shards[s].insert(table, keys.tolist(),
                                          ts.tolist(), rows, donate=False)
            return
        facade = self.streams.get(table)
        if facade is not None:
            self._insert_txn(table, facade, keys, ts, rows)
            return
        with self._route_lock:
            sid = self._routing.owners_of(keys)
            for s in np.unique(sid):
                idx = np.flatnonzero(sid == s)
                self.shards[s].insert(table, keys[idx].tolist(),
                                      ts[idx].tolist(), rows[idx],
                                      donate=False)

    def _insert_txn(self, table: str, facade: ShardedPipeline,
                    keys: np.ndarray, ts: np.ndarray, rows: np.ndarray
                    ) -> None:
        """Cross-shard 2-phase ingest over the per-shard stream buffers.
        ``prepare`` validates each slice against its shard's released
        frontier and parks it; the buffers then HOLD their watermarks at
        the prepared timestamps, so phase 2 ``commit`` cannot fail. Any
        reject (or a dead worker mid-prepare) aborts every parked slice
        — the pre-2PC behavior of shard 0 applying while shard 1
        rejected can no longer happen."""
        with self._route_lock:
            sid = self._routing.owners_of(keys)
            txns: List[Tuple[int, int]] = []
            try:
                for s in np.unique(sid):
                    facade._gate(int(s))
                    idx = np.flatnonzero(sid == s)
                    txn = facade.pipes[s].prepare(
                        keys[idx].tolist(), ts[idx].tolist(), rows[idx])
                    if txn is None:
                        raise ValueError(
                            f"insert on table {table!r} rejected "
                            f"atomically: the batch contains event(s) "
                            f"beyond a shard's released frontier "
                            f"(unrepairably late) or with non-finite "
                            f"timestamps; nothing was staged on any "
                            f"shard")
                    txns.append((int(s), txn))
            except BaseException:
                for s, txn in txns:
                    try:
                        facade.pipes[s].abort_txn(txn)
                    except Exception:
                        pass          # abort is advisory on a dead shard
                raise
            for s, txn in txns:
                facade.pipes[s].commit_txn(txn)
        # barrier (outside the route lock — flushing does device work):
        # everything committed becomes queryable, surfacing flush errors
        # exactly like Engine.insert's single-shard barrier
        for s, _txn in txns:
            pipe = facade.pipes[s]
            if hasattr(pipe, "client"):          # process backend proxy
                pipe.flush(flush_all=True, check=True)
            else:
                errs_before = pipe.stats["errors"]
                pipe.flush(flush_all=True)
                if (pipe.stats["errors"] > errs_before
                        and pipe.buffer.n_staged > 0):
                    raise pipe.last_error

    def _spec(self, table: str) -> _TableSpec:
        spec = self.specs.get(table)
        if spec is None:
            raise KeyError(f"unknown table {table!r}; create_table first; "
                           f"known: {sorted(self.specs)}")
        return spec

    # ------------------------------------------------------------ streaming
    def attach_stream(self, table: str, cfg=None, **cfg_kw
                      ) -> ShardedPipeline:
        """One ingest pipeline per shard (per-shard watermarks); events
        route to the owning shard's pipeline."""
        from repro.streaming.pipeline import PipelineConfig
        spec = self._spec(table)
        if table in self.streams:
            raise ValueError(f"table {table!r} already has a stream")
        if cfg is None and cfg_kw:
            cfg = PipelineConfig(**cfg_kw)
        elif cfg is not None and cfg_kw:
            raise ValueError("pass cfg or keywords, not both")
        if (self.cfg.wal_dir is not None and not spec.replicated
                and getattr(cfg, "wal", None) is None):
            # durability: every partitioned stream shard gets its own WAL
            # under <wal_dir>/shard-{shard}/<table>; the template keeps the
            # `{shard}` placeholder — each side (in-process loop below,
            # worker clients in attach) resolves its own shard id, and DDL
            # replay after a respawn resolves to the NEW incarnation's dir
            cfg = dataclasses.replace(
                cfg if cfg is not None else PipelineConfig(),
                wal=WalConfig(dir=os.path.join(
                    self.cfg.wal_dir, "shard-{shard}", table)))
        if self.backend is not None:
            pipes = [self.shards[s].attach_stream(table, cfg)
                     for s in self._active_ids()]
        else:
            pipes = [self.shards[s].attach_stream(
                         table, wal_resolve_shard(cfg, s))
                     for s in self._active_ids()]
        facade = ShardedPipeline(self, table, pipes, spec.replicated)
        self.streams[table] = facade
        self._stream_cfgs[table] = cfg
        if self.backend is not None:
            self.backend.log_ddl("attach_stream", table=table, cfg=cfg)
        return facade

    def create_stream(self, schema: TableSchema, *, max_keys: int = 1024,
                      capacity: int = 1024, bucket_size: int = 64,
                      replicate: bool = False, **cfg_kw):
        self.create_table(schema, max_keys=max_keys, capacity=capacity,
                          bucket_size=bucket_size, replicate=replicate)
        facade = self.attach_stream(schema.name, **cfg_kw)
        tables = (None if self.backend is not None
                  else self.tables_of(schema.name))
        return tables, facade

    def register_model(self, name: str, fn: Callable,
                       params: object = None) -> None:
        """NOTE: under the process backend ``fn``/``params`` cross a
        pickle boundary — module-level functions work, closures don't."""
        for s in self._active_ids():
            self.shards[s].register_model(name, fn, params)
        self._models[name] = (fn, params)
        if self.backend is not None:
            self.backend.log_ddl("register_model", name=name, fn=fn,
                                 params=params)

    def set_cost_model(self, model: CostModel) -> CostModel:
        """Install calibrated optimizer constants on EVERY shard (all
        shards must compile the same plan — a per-shard cost model would
        break the one-plan-per-version invariant ``deploy`` relies on).
        Takes effect on the next ``deploy``; returns the previous model."""
        with self._deploy_lock:
            prev = self._primary().cost_model
            for s in self._active_ids():
                self.shards[s].set_cost_model(model)
            if self.backend is not None:
                self.backend.log_ddl("set_cost_model", model=model)
            return prev

    @property
    def cost_model(self) -> CostModel:
        return self._primary().cost_model

    # --------------------------------------------------------------- deploy
    def deploy(self, name: str,
               query: Union[str, Query, dsl.QueryBuilder], *,
               warm_buckets: Optional[Sequence[int]] = None,
               canary: float = 0.0) -> ShardedDeploymentHandle:
        """Compile one executable set per shard, then publish the whole
        set atomically under one handle. Joined right tables must be
        replicated (probes resolve through the probing shard's local
        replica)."""
        if canary and not (0.0 < canary <= 1.0):
            raise ValueError(
                f"canary fraction must be in (0, 1], got {canary}")
        if isinstance(query, str):
            query = dsl.parse_sql(query)
        elif isinstance(query, dsl.QueryBuilder):
            query = query.build()
        with self._deploy_lock:
            prev = self.deployments.get(name)
            if canary > 0.0 and prev is None:
                raise ValueError(
                    f"canary deploy of {name!r} requires an existing live "
                    f"deployment; deploy without canary= first")
            # build EVERY shard's version before any publish: a failed
            # shard build must leave the live set untouched AND not leak
            # the versions already built on earlier shards
            handles: List[Optional[DeploymentHandle]] = \
                [None] * len(self.shards)
            built: List[Tuple[int, DeploymentHandle]] = []
            try:
                for s in self._active_ids():
                    h = self.shards[s].build_version(
                        name, query, warm_buckets=warm_buckets)
                    handles[s] = h
                    built.append((s, h))
            except BaseException:
                self._discard_built(built)
                raise
            first = next(h for h in handles if h is not None)
            for j in first.plan.joins:
                if not self._spec(j.table).replicated:
                    self._discard_built(built)
                    raise ValueError(
                        f"LAST JOIN right table {j.table!r} is hash-"
                        f"partitioned; a probing shard could not resolve "
                        f"keys owned by other shards — create it with "
                        f"replicate=True (broadcast dimension table)")
            version = first.version
            sh = ShardedDeploymentHandle(self, name, version, handles)
            sh.query = query
            sh.warm_buckets = (tuple(warm_buckets) if warm_buckets
                               else None)
            self._versions.setdefault(name, {})[version] = sh
            if canary > 0.0:
                displaced = prev._canary[0] if prev._canary else None
                sh.state = DeploymentHandle.CANARY
                prev._canary = (sh, float(canary))
                if displaced is not None:
                    self._discard(displaced)
            else:
                self._swap(name, sh, prev)
            return sh

    def _discard_built(self, built: List[Tuple[int, DeploymentHandle]]
                       ) -> None:
        for s, h in built:
            try:
                self.shards[s].discard_version(h)
            except Exception:
                pass       # a shard dying mid-rollback is already down

    def _swap(self, name: str,
              new: ShardedDeploymentHandle,
              prev: Optional[ShardedDeploymentHandle]) -> None:
        for s in self._active_ids():
            if new.handles[s] is not None:
                self.shards[s].publish_version(new.handles[s])
        new._canary = None
        new.state = DeploymentHandle.LIVE
        self.deployments[name] = new       # the atomic publish
        if prev is not None:
            if prev._canary is not None and prev._canary[0] is not new:
                self._discard(prev._canary[0])
            prev._canary = None
            prev.state = DeploymentHandle.RETIRED
            hist = self._history.setdefault(name, [])
            hist.append(prev)
            # mirror the inner engines' retention bound: beyond it the
            # inner handles released their executables anyway, so the
            # sharded wrapper is unpinnable too
            while len(hist) > self._primary().max_retained_versions:
                dropped = hist.pop(0)
                self._versions.get(name, {}).pop(dropped.version, None)

    def _discard(self, cand: ShardedDeploymentHandle) -> None:
        cand.state = DeploymentHandle.RETIRED
        self._discard_built([(s, cand.handles[s])
                             for s in self._active_ids()
                             if cand.handles[s] is not None])
        self._versions.get(cand.name, {}).pop(cand.version, None)

    def handle(self, name: str, version: Optional[int] = None
               ) -> ShardedDeploymentHandle:
        if version is None:
            dep = self.deployments.get(name)
            if dep is None:
                raise KeyError(f"unknown deployment {name!r}; deployed: "
                               f"{sorted(self.deployments)}")
            return dep
        try:
            return self._versions[name][version]
        except KeyError:
            raise KeyError(
                f"deployment {name!r} has no version {version}; known: "
                f"{sorted(self._versions.get(name, {}))}") from None

    def promote(self, name: str) -> ShardedDeploymentHandle:
        with self._deploy_lock:
            live = self.handle(name)
            if live._canary is None:
                raise ValueError(
                    f"deployment {name!r} has no active canary")
            cand, _ = live._canary
            live._canary = None
            self._swap(name, cand, live)
            return cand

    def rollback(self, name: str) -> ShardedDeploymentHandle:
        with self._deploy_lock:
            live = self.deployments.get(name)
            if live is not None and live._canary is not None:
                self._discard(live._canary[0])
                live._canary = None
                return live
            hist = self._history.get(name)
            if not hist:
                raise ValueError(
                    f"no prior version of {name!r} to roll back to")
            prev = hist.pop()
            self._swap(name, prev, live)
            return prev

    # -------------------------------------------------------------- elastic
    def add_shard(self) -> int:
        """Grow the shard set by one under live traffic: bring up the
        runtime (a fresh subprocess under the process backend), replay
        the catalog (tables, streams, models, cost model), seed
        replicated tables, build + publish every retained deployment
        version, add a router queue — and only THEN flip ring ownership,
        interval by interval, migrating each key range before its flip.
        Requests keep flowing the whole time (routing always answers
        with a shard that holds the data). Returns the new shard id."""
        if self._ring is None:
            raise RuntimeError(
                "partitioner='modulo' cannot reshard; use the default "
                "consistent-hash ring")
        with self._deploy_lock:
            s = len(self.shards)
            # 1) runtime + catalog
            if self.backend is not None:
                client = self.backend.add_client()   # replays DDL itself
                client.tracer = self.tracer
                self.shards.append(client)
                self.devices = self.devices + (None,)
            else:
                eng = Engine(self.flags, **self._engine_kw)
                eng.tracer = self.tracer
                dev = None
                if self.cfg.pin_devices:
                    import jax
                    devs = jax.devices()
                    if len(devs) > 1:
                        dev = devs[s % len(devs)]
                for tname, spec in self.specs.items():
                    eng.create_table(spec.schema, device=dev,
                                     **spec.create_kw)
                for name, (fn, params) in self._models.items():
                    eng.register_model(name, fn, params)
                eng.set_cost_model(self.cost_model)
                for tname in self._stream_cfgs:
                    eng.attach_stream(
                        tname, wal_resolve_shard(self._stream_cfgs[tname],
                                                 s))
                self.shards.append(eng)
                self.devices = self.devices + (dev,)
            # 2) streaming facades gain the new shard's pipe
            for tname, facade in self.streams.items():
                if self.backend is not None:
                    facade.pipes.append(client._streams[tname])
                else:
                    facade.pipes.append(eng.streams[tname])
            # 3) replicated dimension tables: full copy from a donor
            self._seed_replicas(s)
            # 4) every retained deployment version exists on the new
            #    shard BEFORE any traffic can route there
            for name, versions in self._versions.items():
                live = self.deployments.get(name)
                for v in sorted(versions):
                    sh = versions[v]
                    h = self.shards[s].build_version(
                        name, sh.query, warm_buckets=sh.warm_buckets)
                    sh.handles = sh.handles + (h,)
                    if live is sh:
                        self.shards[s].publish_version(h)
            # 5) routing: new queue now; the range migration itself runs
            #    OUTSIDE the deploy lock — a worker respawn mid-migration
            #    needs that lock for its catalog/deployment/WAL replay
            #    hooks, and _reshard waits out exactly such respawns
            self.router.add_queue()
        with self._reshard_lock:
            self._reshard(self._ring.with_shard(s))
        return s

    def remove_shard(self, s: int) -> int:
        """Shrink the shard set: migrate every key range owned by ``s``
        to the surviving shards (interval by interval, under live
        traffic), then retire and close the runtime. The slot id is
        never reused. Returns the number of events migrated."""
        if self._ring is None:
            raise RuntimeError(
                "partitioner='modulo' cannot reshard; use the default "
                "consistent-hash ring")
        with self._deploy_lock:
            if s in self._retired or not 0 <= s < len(self.shards):
                raise ValueError(f"shard {s} is not active")
            if self.n_shards <= 1:
                raise ValueError("cannot remove the last active shard")
        # migrate outside the deploy lock (see add_shard): a respawn of
        # some OTHER worker mid-migration must be able to run its replay
        # hooks while _reshard retries the interrupted batch
        with self._reshard_lock:
            moved = self._reshard(self._ring.without_shard(s))
        with self._deploy_lock:
            self._retired.add(s)
            # no NEW traffic routes to s now (ring + _retired), but a
            # scatter that read the pre-reshard route table can still
            # target it: retire the queue (late submits shed), then wait
            # out everything already queued/executing — closing the
            # runtime under a live sub-batch deletes its jax buffers
            # mid-execution
            self.router.retire_queue(s)
            self.router.drain_shard(s)
            if self.backend is not None:
                client = self.shards[s]
                client.retired = True      # supervisor must not respawn
                client.close()
            else:
                self.shards[s].close()
            return moved

    def _seed_replicas(self, s: int) -> None:
        """Copy every replicated table's full contents onto shard ``s``
        from the first healthy donor (new shard / respawned worker)."""
        donor = next((d for d in self._active_ids() if d != s), None)
        if donor is None:
            return
        for tname, spec in self.specs.items():
            if not spec.replicated:
                continue
            facade = self.streams.get(tname)
            if facade is not None and donor < len(facade.pipes):
                facade.pipes[donor].flush(flush_all=True)
            lk, ex, _mi = self._mig_ops(donor)
            keys = lk(tname)
            if not keys:
                continue
            ks, tsv, rws = ex(tname, keys)
            if len(ks):
                _lk, _ex, mi = self._mig_ops(s)
                mi(tname, ks, tsv, rws)

    def _mig_ops(self, s: int):
        """(list_keys, extract_events, migrate_in) for shard ``s`` —
        local calls in-process, worker RPCs under the process backend."""
        eng = self.shards[s]
        if self.backend is not None:
            return eng.list_keys, eng.extract_events, eng.migrate_in
        from repro.shard import migrate as _m
        return ((lambda t: _m.list_keys(eng, t)),
                (lambda t, ks: _m.extract_events(eng, t, ks)),
                (lambda t, ks, tsv, rws: _m.migrate_in(eng, t, ks, tsv,
                                                       rws)))

    def _reshard(self, new_ring: HashRing, *,
                 batch_arcs: Optional[int] = None) -> int:
        """Migrate routing from the current ring to ``new_ring``: serve
        from a merged route table, copy each differing key range
        (source flush -> enumerate keys in range -> extract -> insert
        into target, skipping any already-present prefix) and flip its
        owner — one batch of ranges at a time under the route lock, so
        ingest interleaves with migration at batch granularity. The
        source keeps its (now stale) copy: readers are never routed
        there for the moved keys, ``query_offline`` filters by current
        ownership, and the skip logic makes a later move-back safe."""
        step = batch_arcs or self.cfg.migrate_batch_arcs
        with self._route_lock:
            rt = RouteTable.merged(self._ring, new_ring)
            self._routing = rt
        plan = rt.plan_against(new_ring)
        tgt = {a: new_ring.owner_of_hash(int(rt.points[a]))
               for a in plan}
        partitioned = [t for t, sp in self.specs.items()
                       if not sp.replicated]
        moved = 0
        for i in range(0, len(plan), step):
            batch = plan[i:i + step]
            # one batch is retried as a unit when a worker dies (or an
            # RPC times out) mid-migration: migrate_in's prefix-skip
            # makes a re-run idempotent, arcs already flipped regroup as
            # src == dst no-ops, and the respawned worker's WAL replay
            # (which needs the route lock we release between attempts)
            # restores the source data the retry re-extracts
            deadline = time.monotonic() + self.cfg.reshard_retry_s
            while True:
                try:
                    with self._route_lock:
                        groups: Dict[Tuple[int, int], List[int]] = {}
                        for a in batch:
                            groups.setdefault((rt.arc_owner(a), tgt[a]),
                                              []).append(a)
                        for (src, dst), arcs in groups.items():
                            if src == dst:
                                rt.set_owner(arcs, dst)
                                continue
                            arcset = np.asarray(arcs)
                            for tname in partitioned:
                                facade = self.streams.get(tname)
                                if facade is not None:
                                    # staged events must be IN the table
                                    # before extract reads its snapshot
                                    facade.pipes[src].flush(
                                        flush_all=True)
                                lk, ex, _mi = self._mig_ops(src)
                                all_keys = lk(tname)
                                if not all_keys:
                                    continue
                                in_arc = rt.arc_of_hashes(
                                    key_hashes(np.asarray(all_keys)))
                                sel = [all_keys[int(j)] for j in
                                       np.flatnonzero(
                                           np.isin(in_arc, arcset))]
                                if not sel:
                                    continue
                                ks, tsv, rws = ex(tname, sel)
                                if len(ks):
                                    _lk, _ex, mi = self._mig_ops(dst)
                                    moved += mi(tname, ks, tsv, rws)
                            rt.set_owner(arcs, dst)
                    break
                except (ShardDownError, TimeoutError):
                    if time.monotonic() >= deadline:
                        raise
                    # route lock released: the supervisor's respawn +
                    # replay hooks can run; wait for every worker to
                    # come back before re-running the batch
                    self._await_ready()
        self._ring = new_ring
        with self._route_lock:
            self._routing = RouteTable(new_ring)
        return moved

    # ----------------------------------------------- worker respawn hooks
    def _reseed_replicas(self, s: int, client) -> None:
        """(process backend) After a worker respawn + catalog replay,
        re-seed its replicated dimension tables from a healthy donor —
        joins on the respawned shard must not silently miss every
        dimension row. Partitioned table data re-enters through the
        stream like any other restart."""
        del client
        with self._deploy_lock:
            self._seed_replicas(s)

    def _replay_shard(self, s: int, client) -> None:
        """(process backend) Rebuild every retained deployment version
        on a respawned worker, in version order, aliasing the parent's
        stable version ids to the fresh worker's numbering; publish the
        live one. Runs under the deploy lock so a concurrent deploy
        cannot interleave with the rebuild."""
        with self._deploy_lock:
            for name, versions in self._versions.items():
                live = self.deployments.get(name)
                for v in sorted(versions):
                    sh = versions[v]
                    ph = sh.handles[s] if s < len(sh.handles) else None
                    if ph is None:
                        continue
                    summary = client.proc.call(
                        "build_version", name=name, query=sh.query,
                        warm_buckets=sh.warm_buckets)
                    client._alias[(name, ph.version)] = \
                        summary["version"]
                    ph.table.version = summary["table_version"]
                    ph.phys.feature_names = \
                        list(summary["feature_names"])
                    if live is not None and live.handles[s] is ph:
                        ph.table.version = client.proc.call(
                            "publish_version", name=name,
                            version=summary["version"])

    def _await_ready(self, timeout: float = 30.0) -> bool:
        """Block until every non-retired process worker is serving again
        (in-process backend: trivially true). MUST be called without
        ``_route_lock`` held — the supervisor's WAL replay needs it."""
        if self.backend is None:
            return True
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if all(c.ready or getattr(c, "retired", False)
                   for c in self.backend.clients):
                return True
            time.sleep(0.05)
        return False

    # ------------------------------------------------- WAL recovery hooks
    def _archive_wal(self, s: int) -> None:
        """(process backend, pre-spawn) Move the dead shard's WAL tree
        aside so the respawned incarnation starts a FRESH log — replay
        re-ingests through the pipeline and re-logs into the new one.
        Archives stack (``.recover-0``, ``.recover-1`` ...) if a worker
        dies again before the previous replay finished; prefix-skip
        makes replaying both idempotent."""
        # postmortem evidence first: the ring holds the batches that led
        # into the crash (rate-limited, so a crash loop can't disk-fill)
        self.flight.record("worker_down", shard=s)
        self.flight.dump(f"worker-down-shard-{s}")
        if self.cfg.wal_dir is None:
            return
        src = os.path.join(self.cfg.wal_dir, f"shard-{s}")
        if not os.path.isdir(src):
            return
        k = 0
        while os.path.exists(f"{src}.recover-{k}"):
            k += 1
        os.rename(src, f"{src}.recover-{k}")

    def _replay_wal(self, s: int, client) -> None:
        """(process backend, post-respawn) Replay the archived WAL of
        shard ``s`` through the LIVE route table: events are re-scattered
        to their current owners (usually ``s`` itself, but a reshard may
        have moved keys while the worker was down) via ``migrate_in``,
        whose prefix-skip keeps duplicates out. Runs after the catalog +
        deployment replay, while the client is still ``ready=False`` so
        no fresh ingest can race ahead of the replayed history."""
        del client
        if self.cfg.wal_dir is None:
            return
        t0 = time.monotonic()
        dirs = sorted(d for d in os.listdir(self.cfg.wal_dir)
                      if d.startswith(f"shard-{s}.recover-")) \
            if os.path.isdir(self.cfg.wal_dir) else []
        total = 0
        for d in dirs:
            rdir = os.path.join(self.cfg.wal_dir, d)
            for tname in sorted(os.listdir(rdir)):
                spec = self.specs.get(tname)
                if (spec is None or spec.replicated
                        or tname not in self.streams):
                    continue
                events: List[Tuple[object, float, np.ndarray]] = []
                for keys, tsv, rows in wal_read_dir(
                        os.path.join(rdir, tname)):
                    for j in range(len(keys)):
                        events.append((keys[j], float(tsv[j]), rows[j]))
                if not events:
                    continue
                # global (ts, append-seq) order: stable sort reproduces
                # exactly the order the buffer accepted them in
                events.sort(key=lambda e: e[1])
                ks = np.asarray([e[0] for e in events])
                tsv = np.asarray([e[1] for e in events], np.float32)
                rws = np.asarray([e[2] for e in events], np.float32)
                with self._route_lock:
                    owners = self._routing.owners_of(ks)
                for o in np.unique(owners):
                    idx = np.flatnonzero(owners == o)
                    _lk, _ex, mi = self._mig_ops(int(o))
                    for c in range(0, len(idx), 2048):
                        sl = idx[c:c + 2048]
                        total += mi(tname, ks[sl], tsv[sl], rws[sl])
            shutil.rmtree(rdir)          # replayed in full: drop archive
        self.recovery_stats["wal_replays"] += 1
        self.recovery_stats["wal_replayed_events"] += total
        self.recovery_stats["wal_replay_lag_s"] = \
            time.monotonic() - t0

    # --------------------------------------------------------------- online
    def request(self, name: str, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None,
                ctx: Optional[RequestContext] = None) -> FeatureFrame:
        pin = ctx.version_pin if ctx is not None else None
        return self.handle(name, pin).request(keys, ts, rows, ctx=ctx)

    # -------------------------------------------------------------- offline
    def query_offline(self, name: str, *, batch_size: int = 1024,
                      point_in_time: bool = True) -> Dict[str, np.ndarray]:
        """Per-shard offline materialisation under pinned snapshots,
        concatenated. ``__key`` holds the ACTUAL key values (not dense
        indices — those are shard-local), plus a ``__shard`` column and
        the ``version_vector`` the run was pinned to. Rows whose key is
        no longer owned by the shard that produced them (stale copies
        left by a range migration) are filtered out, so the output
        matches the unsharded engine before/during/after a reshard."""
        dep = self.handle(name)
        base_spec = self.specs.get(dep.table.schema.name)
        replicated = base_spec is not None and base_spec.replicated
        outs: List[Dict[str, np.ndarray]] = []
        vvec = []
        shard_ids_ = ([self._active_ids()[0]] if replicated
                      else self._active_ids())
        for s in shard_ids_:
            eng = self.shards[s]
            res = eng.query_offline(name, batch_size=batch_size,
                                    point_in_time=point_in_time)
            h = dep.handles[s]
            vvec.append(h.table.version if h is not None else -1)
            if "__key" not in res or len(res["__key"]) == 0:
                # hash skew (or n_shards > distinct keys) can leave a
                # shard with no retained events; skip it rather than
                # concatenating dtype-less empties into the key column
                continue
            res = {k: np.asarray(v) for k, v in res.items()}
            if self.backend is None:
                # in-process: map dense indices -> real keys here (the
                # process backend's workers already did, where the
                # key_to_idx map lives)
                table = h.table
                inv = {i: k for k, i in table.key_to_idx.items()}
                res["__key"] = np.asarray(
                    [inv[int(i)] for i in res["__key"]])
            if not replicated:
                own = self._routing.owners_of(res["__key"]) == s
                if not own.all():
                    res = {k: v[own] for k, v in res.items()}
                if len(res["__key"]) == 0:
                    continue
            res["__shard"] = np.full(len(res["__key"]), s, np.int32)
            outs.append(res)
        if not outs:
            merged = {n: np.zeros((0,), np.float32)
                      for n in dep.phys.feature_names}
            merged["__key"] = np.zeros((0,), np.int64)
            merged["__ts"] = np.zeros((0,), np.float32)
            merged["__shard"] = np.zeros((0,), np.int32)
        else:
            merged = {k: np.concatenate([o[k] for o in outs])
                      for k in outs[0]}
        merged["__version_vector"] = np.asarray(vvec, np.int64)
        return merged

    # ---------------------------------------------------------------- intro
    def explain(self, name: str) -> str:
        dep = self.handle(name)
        rs = self.router.stats()
        part = ("modulo" if self._ring is None else
                f"consistent-hash ring ({self._ring.vnodes} vnodes/"
                f"shard)")
        lines = [
            f"sharded deployment {name!r} v{dep.version} [{dep.state}] "
            f"across {self.n_shards} shard(s) "
            f"[{self.backend_kind} backend]",
            f"  router: {part}, "
            f"dispatch_rows={self.cfg.dispatch_rows}, "
            f"rows/dispatch={rs['rows_per_dispatch']:.1f}",
            f"  admission: max_inflight="
            f"{self.cfg.admission.max_inflight}, max_queue_depth="
            f"{self.cfg.admission.max_queue_depth} "
            f"({self.resources.metrics()})",
            f"  devices: " + ", ".join(
                str(self.devices[s]) if self.devices[s] is not None
                else ("worker-subprocess" if self.backend is not None
                      else "default")
                for s in self._active_ids()),
            f"  version vector: {dep.version_vector()}",
        ]
        lines.append(f"  per-shard plan (shard {self._active_ids()[0]} "
                     f"of {self.n_shards}; all shards compile the same "
                     f"plan):")
        lines += ["  " + l for l in
                  self._primary().explain(name).splitlines()]
        return "\n".join(lines)

    def explain_analyze(self, target: str) -> str:
        """Measured-runtime EXPLAIN, merged across shards. ``target`` is
        a deployment name or an ``EXPLAIN ANALYZE SELECT ...`` statement
        (matched against deployed queries, like the single engine)."""
        from repro.obs.profile import OperatorProfiler
        name = target
        sql = dsl.strip_explain_analyze(target)
        if sql is not None:
            q = dsl.parse_sql(sql)
            name = next((nm for nm, dep in self.deployments.items()
                         if dep.query == q), None)
            if name is None:
                raise KeyError(
                    f"EXPLAIN ANALYZE: no live deployment serves this "
                    f"query (deploy it first); deployed: "
                    f"{sorted(self.deployments)}")
        dep = self.handle(name)
        snaps = []
        for s in self._active_ids():
            sub = self.shards[s]
            if hasattr(sub, "profiler"):             # in-process Engine
                snaps.append(sub.profiler.snapshot(name))
            else:                                    # proc client (RPC)
                snaps.append(sub.profile_snapshot(name))
        return OperatorProfiler.render(
            name, dep.version, OperatorProfiler.merge(snaps),
            n_shards=len(snaps))

    def drain_profile_observations(self, name: str) -> List[Dict]:
        """Measured-per-operator calibrator feed (control plane): drain
        every in-process shard profiler's interval accumulator. Process
        workers keep their profiles worker-side (the plane falls back to
        its EM attribution there)."""
        obs: List[Dict] = []
        if self.backend is None:
            for s in self._active_ids():
                obs.extend(
                    self.shards[s].profiler.drain_observations(name))
        return obs

    # ----------------------------------------------------------- freshness
    def freshness_snapshot(self) -> Dict[str, Dict[str, object]]:
        """Cross-shard freshness: per-worker snapshots (RPC under the
        process backend, mirroring ``profile_snapshot``) merged exactly —
        sketches add bucket-wise, counters sum, watermarks take the MIN
        (the slowest shard bounds global freshness)."""
        # the proc client exposes the same method (one RPC per worker)
        snaps = [self.shards[s].freshness_snapshot()
                 for s in self._active_ids()]
        return FreshnessTracker.merge(snaps)

    def freshness_export(self) -> Dict[str, object]:
        """Flat ``freshness`` metrics group (merged across shards)."""
        return FreshnessTracker.export(self.freshness_snapshot())

    def _drift_monitor(self) -> DriftMonitor:
        snaps = []
        for s in self._active_ids():
            sub = self.shards[s]
            if hasattr(sub, "drift"):                # in-process Engine
                snaps.append(sub.drift.snapshot())
            else:                                    # proc client (RPC)
                snaps.append(sub.drift_snapshot())
        return DriftMonitor.merge(snaps)

    def drift_report(self) -> Dict[str, Dict[str, float]]:
        """Per-column live-vs-reference PSI, merged across shards."""
        return self._drift_monitor().report()

    def drift_export(self) -> Dict[str, float]:
        return self._drift_monitor().export()

    def pin_drift_reference(self) -> List[str]:
        """Pin every shard's current live distribution as its drift
        reference (each shard pins locally; the merged report then
        compares merged-live vs merged-reference)."""
        cols: Set[str] = set()
        for s in self._active_ids():
            cols.update(self.shards[s].pin_drift_reference())
        return sorted(cols)

    def latency_decomposition(self) -> Dict[str, float]:
        # counters sum across shards; rates are recomputed from the
        # summed counters and percentiles take the worst shard — summing
        # a ratio or a p99 across shards would be nonsense
        agg: Dict[str, float] = {}
        join_matches = 0.0
        join_p99: List[float] = []
        hit: List[float] = []
        for s in self._active_ids():
            eng = self.shards[s]
            d = eng.latency_decomposition()
            for k, v in d.items():
                if k in ("cache_hit_rate", "join_match_rate",
                         "join_age_p99"):
                    continue
                agg[k] = agg.get(k, 0.0) + v
            if d.get("join_probes"):
                join_matches += d["join_match_rate"] * d["join_probes"]
                p99 = d.get("join_age_p99", float("nan"))
                if not np.isnan(p99):
                    join_p99.append(p99)
            hit.append(eng.cache.stats.hit_rate)
        if agg.get("join_probes"):
            agg["join_match_rate"] = join_matches / agg["join_probes"]
            agg["join_age_p99"] = (max(join_p99) if join_p99
                                   else float("nan"))
        agg["cache_hit_rate"] = float(np.mean(hit)) if hit else 0.0
        agg["n_shards"] = self.n_shards
        agg["worker_restarts"] = self.worker_restarts
        agg.update({f"router_{k}": v
                    for k, v in self.router.stats().items()})
        agg.update({f"admission_{k}": v
                    for k, v in self.resources.metrics().items()})
        agg.update({f"recovery_{k}": v
                    for k, v in self.recovery_stats.items()})
        if self.backend is not None:
            agg.update({f"recovery_{k}": v
                        for k, v in self.backend.recovery_stats.items()})
            tstats: Dict[str, float] = {}
            for c in self.backend.clients:
                for k, v in c.transport_stats.items():
                    tstats[k] = tstats.get(k, 0) + v
            agg.update({f"transport_{k}": v for k, v in tstats.items()})
        return agg

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        # drain first: in-flight gathers complete before any lane stops
        # (a fail-fast close here could error a request that was already
        # queued — the DynamicBatcher.close() lesson, applied)
        self.router.shutdown(drain=True)
        self.streams.clear()   # shard engines own + close the pipelines
        if self.backend is not None:
            self.backend.close()
        else:
            for s in self._active_ids():
                self.shards[s].close()

    def __enter__(self) -> "ShardedEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False
