"""ShardedEngine: horizontal scale-out behind the single-engine API.

DESIGN.md §9. N key-hash-partitioned **shard engines** — each a full
:class:`repro.core.engine.Engine` with its own tables, device-resident
key directory, plan cache, and (when streams are attached) ingest
pipeline with its own watermarks — behind the familiar ``create_table /
insert / attach_stream / deploy / request / query_offline`` surface.
When the jax runtime exposes several devices (a TPU slice, or CPU with
``--xla_force_host_platform_device_count=N``), shard ``s`` is pinned to
device ``s % D`` so shard executions ride separate device streams; on a
single device everything still works, just serialized.

* **Routing** (``shard/router.py``): ingest goes to the key's owning
  shard; a request batch is scattered by key hash, executed per shard by
  coalescing workers, and gathered back in request order. The paper's
  key-partitioned tablets, in-process.
* **Deployments**: ``deploy`` compiles one executable set per shard
  (``Engine.build_version``) and then publishes the whole set under ONE
  :class:`ShardedDeploymentHandle` — hot swap, counter-based canary and
  rollback operate on the set atomically; a batch is always served by a
  single (version, shard-set).
* **Tables**: partitioned by default; ``replicate=True`` broadcasts a
  table to every shard (dimension tables — LAST JOIN probes then resolve
  through the owning shard's local replica, no cross-shard hop).
* **Offline parity**: ``query_offline`` runs per shard against pinned
  snapshots and stamps the result with the cross-shard **version
  vector**; outputs are bit-identical to the unsharded engine because
  per-key event order (and therefore every ring) is preserved by
  routing.
* **Admission control** (``shard/resource.py``): per-deployment
  in-flight and queue-depth bounds plus deadline shedding, so
  saturating one deployment or shard degrades with explicit
  backpressure/shed statuses instead of unbounded queueing.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import dsl
from repro.core.engine import DeploymentHandle, Engine, HandleMetrics
from repro.core.logical import Query
from repro.core.optimizer import CostModel, OptFlags
from repro.core.results import (STATUS_SHED, FeatureFrame, RequestContext)
from repro.featurestore.table import TableSchema
from repro.shard.resource import AdmissionConfig, ResourceManager
from repro.shard.router import ShardRouter, shard_ids, shard_of

__all__ = ["ShardConfig", "ShardedEngine", "ShardedDeploymentHandle",
           "ShardedPipeline"]


@dataclass(frozen=True)
class ShardConfig:
    n_shards: int = 2
    dispatch_rows: int = 256          # coalesced rows per shard dispatch
    # max wait for a worker to fill one dispatch chunk (batcher-style
    # deadline policy; 0 disables waiting)
    coalesce_delay_s: float = 0.002
    # execution lanes (worker threads). None = one per distinct device in
    # use: running more execution streams than devices just thrashes;
    # shards beyond that share lanes round-robin, like tablets sharing a
    # tablet-server's executor pool
    n_lanes: Optional[int] = None
    admission: AdmissionConfig = AdmissionConfig()
    # pin shard s to jax device s % D when more than one device exists;
    # set False to keep default placement (all shards on device 0)
    pin_devices: bool = True


@dataclass
class ShardedHandleMetrics:
    requests: int = 0
    batches: int = 0
    shed_requests: int = 0
    shed_batches: int = 0
    serve_s: float = 0.0
    canary_batches: int = 0
    canary_max_abs_diff: float = 0.0
    # end-to-end (scatter->gather) per-batch latency reservoir — same
    # FIFO-window semantics as HandleMetrics.latency_s, so the control
    # plane's replan p99 health check works identically when sharded
    latency_s: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(
            maxlen=HandleMetrics.LATENCY_RESERVOIR))

    def observe_latency(self, seconds: float) -> None:
        self.latency_s.append(float(seconds))

    def latency_percentile(self, pct: float) -> float:
        if not self.latency_s:
            return float("nan")
        return float(np.percentile(np.asarray(self.latency_s, np.float64),
                                   pct))

    def snapshot(self) -> Dict[str, float]:
        """JSON-serializable copy (reservoir summarised, not dumped)."""
        return {
            "requests": self.requests, "batches": self.batches,
            "shed_requests": self.shed_requests,
            "shed_batches": self.shed_batches,
            "serve_s": self.serve_s,
            "canary_batches": self.canary_batches,
            "canary_max_abs_diff": self.canary_max_abs_diff,
            "latency_samples": len(self.latency_s),
            "latency_p50_s": self.latency_percentile(50),
            "latency_p99_s": self.latency_percentile(99),
        }


@dataclass
class _TableSpec:
    schema: TableSchema
    replicated: bool


class ShardedDeploymentHandle:
    """One version of a deployment across every shard — the sharded
    serving endpoint. Owns the per-shard :class:`DeploymentHandle`s; the
    router dispatches against THESE handles directly, so a mid-redeploy
    inner-engine state is invisible to in-flight batches (same
    handle-owned-executable argument as the single-engine swap)."""

    def __init__(self, engine: "ShardedEngine", name: str, version: int,
                 handles: Sequence[DeploymentHandle]):
        self.engine = engine
        self.name = name
        self.version = version
        self.handles: Tuple[DeploymentHandle, ...] = tuple(handles)
        self.state = DeploymentHandle.WARMING
        self.metrics = ShardedHandleMetrics()
        self._canary: Optional[Tuple["ShardedDeploymentHandle", float]] = \
            None
        self._canary_counter = 0
        self._lock = threading.Lock()

    # ------------------------------------------------------------ identity
    @property
    def tag(self) -> str:
        return f"{self.name}@v{self.version}x{len(self.handles)}"

    @property
    def live(self) -> bool:
        return self.state == DeploymentHandle.LIVE

    @property
    def plan(self):
        return self.handles[0].plan

    @property
    def phys(self):
        return self.handles[0].phys

    @property
    def table(self):
        """Shard 0's table — schema/introspection only; mutation must go
        through the sharded engine (routing)."""
        return self.handles[0].table

    def __repr__(self) -> str:
        return (f"ShardedDeploymentHandle({self.name!r} v{self.version} "
                f"[{self.state}] x{len(self.handles)} shards)")

    # ------------------------------------------------------------ warm etc
    def warm(self, buckets: Sequence[int]) -> int:
        return sum(h.warm(buckets) for h in self.handles)

    def version_vector(self) -> Tuple[int, ...]:
        """Per-shard table versions (shard order) right now."""
        return tuple(h.table.version for h in self.handles)

    def join_staleness(self) -> Dict[str, Dict[str, float]]:
        """Cross-shard rollup of the per-shard staleness metrics."""
        out: Dict[str, Dict[str, float]] = {}
        for h in self.handles:
            for t, st in h.join_staleness().items():
                agg = out.setdefault(t, {"probes": 0, "matches": 0,
                                         "age_p99": float("nan"),
                                         "age_samples": 0})
                agg["probes"] += st["probes"]
                agg["matches"] += st["matches"]
                agg["age_samples"] += st["age_samples"]
                if st["age_samples"]:
                    p99 = st["age_p99"]
                    agg["age_p99"] = (p99 if np.isnan(agg["age_p99"])
                                      else max(agg["age_p99"], p99))
        for agg in out.values():
            agg["match_rate"] = (agg["matches"] / agg["probes"]
                                 if agg["probes"] else 0.0)
        return out

    # --------------------------------------------------------------- serve
    def request(self, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None,
                ctx: Optional[RequestContext] = None) -> FeatureFrame:
        """Serve one batch: admit -> (canary pick) -> scatter -> gather.

        Shedding is all-or-nothing: an expired deadline (at admission or
        while queued on any shard) returns a frame whose EVERY row is
        ``STATUS_SHED`` — never a mix of shed and computed rows."""
        eng = self.engine
        B = len(keys)
        trace = ctx.trace_id if ctx is not None else None
        if B == 0:
            return FeatureFrame(
                {n: np.zeros((0,), np.float32)
                 for n in self.phys.feature_names},
                status=np.zeros((0,), np.int8), deployment=self.name,
                version=self.version, trace_id=trace,
                version_vector=self.version_vector())
        if rows is None and self.plan.joins:
            raise ValueError(
                f"deployment {self.name!r} has {len(self.plan.joins)} "
                f"LAST JOIN(s); online requests must pass rows= — the "
                f"join probes read the request row's join-key column(s)")
        adm = eng.resources.admit(self.name, ctx,
                                  queue_depths=eng.router.queue_depths)
        if adm.shed:
            return self._shed_frame(B, trace)
        try:
            cand = None
            pinned = ctx is not None and ctx.version_pin is not None
            canary = None if pinned else self._canary
            if canary is not None:
                cand_handle, frac = canary
                with self._lock:
                    self._canary_counter += 1
                    n = self._canary_counter
                if int(n * frac) > int((n - 1) * frac):
                    cand = cand_handle
            if cand is None:
                return self._scatter_gather(keys, ts, rows, ctx, trace)
            # canary slice: candidate serves; incumbent recomputes as the
            # reference and the divergence lands on the candidate
            base = self._scatter_gather(keys, ts, rows, ctx, trace)
            new = cand._scatter_gather(keys, ts, rows, ctx, trace)
            diff = 0.0
            for nme, v in new.columns.items():
                ref = base.columns.get(nme)
                if ref is not None and np.size(v):
                    diff = max(diff, float(np.max(np.abs(
                        np.asarray(v, np.float64)
                        - np.asarray(ref, np.float64)))))
            with cand._lock:
                cand.metrics.canary_batches += 1
                cand.metrics.canary_max_abs_diff = max(
                    cand.metrics.canary_max_abs_diff, diff)
            return new
        finally:
            adm.release()

    def _scatter_gather(self, keys, ts, rows, ctx, trace) -> FeatureFrame:
        eng = self.engine
        t0 = time.perf_counter()
        karr = np.asarray(keys)
        ts_arr = np.asarray(ts, np.float32)
        row_arr = (np.asarray(rows, np.float32) if rows is not None
                   else None)
        B = len(karr)
        parts = eng.router.scatter(self.handles, karr, ts_arr, row_arr,
                                   ctx=ctx)
        columns, status, _tvers, any_shed = eng.router.gather(parts, B)
        if any_shed:
            eng.resources.record_shed()
            return self._shed_frame(B, trace)
        wall = time.perf_counter() - t0
        with self._lock:
            m = self.metrics
            m.requests += B
            m.batches += 1
            m.serve_s += wall
            m.observe_latency(wall)
        return FeatureFrame(
            columns, status=status, deployment=self.name,
            version=self.version, trace_id=trace,
            table_version=max((h.table.version for h in self.handles),
                              default=-1),
            latency={"serve_s": wall},
            version_vector=self.version_vector())

    def _shed_frame(self, B: int, trace) -> FeatureFrame:
        with self._lock:
            self.metrics.shed_requests += B
            self.metrics.shed_batches += 1
        return FeatureFrame(
            {n: np.zeros((B,), np.float32)
             for n in self.phys.feature_names},
            status=np.full(B, STATUS_SHED, np.int8),
            deployment=self.name, version=self.version, trace_id=trace,
            version_vector=self.version_vector())

    def rollback(self) -> "ShardedDeploymentHandle":
        return self.engine.rollback(self.name)


class ShardedPipeline:
    """Streaming facade: one IngestPipeline per shard, each with its own
    watermarks/frontiers — routing by the same key hash as serving, so an
    event's reorder repair happens on the shard that stores it."""

    def __init__(self, engine: "ShardedEngine", table: str,
                 pipes: Sequence, replicated: bool):
        self.engine = engine
        self.table = table
        self.pipes = tuple(pipes)
        self.replicated = replicated

    def push(self, key, ts: float, row: np.ndarray) -> bool:
        if self.replicated:
            ok = True
            for p in self.pipes:
                ok = p.push(key, ts, row) and ok
            return ok
        s = shard_of(key, len(self.pipes))
        return self.pipes[s].push(key, ts, row)

    def push_batch(self, keys: Sequence, ts: Sequence[float],
                   rows: np.ndarray, *, all_or_nothing: bool = False
                   ) -> int:
        keys = np.asarray(keys)
        ts = np.asarray(ts, np.float32)
        rows = np.asarray(rows, np.float32)
        if self.replicated:
            return min(p.push_batch(keys, ts, rows,
                                    all_or_nothing=all_or_nothing)
                       for p in self.pipes)
        sid = shard_ids(keys, len(self.pipes))
        n = 0
        for s, p in enumerate(self.pipes):
            idx = np.flatnonzero(sid == s)
            if idx.size:
                n += p.push_batch(keys[idx], ts[idx], rows[idx],
                                  all_or_nothing=all_or_nothing)
        return n

    def flush(self, *, flush_all: bool = True) -> None:
        for p in self.pipes:
            p.flush(flush_all=flush_all)

    def wait_idle(self, timeout: float = 30.0) -> bool:
        return all(p.wait_idle(timeout) for p in self.pipes)

    def warm(self) -> int:
        return sum(p.warm() for p in self.pipes)

    def version_vector(self) -> Tuple[int, ...]:
        return tuple(p.table.version for p in self.pipes)

    def metrics(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for p in self.pipes:
            for k, v in p.metrics().items():
                out[k] = out.get(k, 0) + v
        out["n_shards"] = len(self.pipes)
        return out

    def close(self, *, drain: bool = True) -> None:
        for p in self.pipes:
            p.close(drain=drain)


class ShardedEngine:
    """N hash-partitioned shard engines behind the Engine API."""

    def __init__(self, cfg: ShardConfig = ShardConfig(), *,
                 flags: OptFlags = OptFlags(), **engine_kw):
        import jax
        self.cfg = cfg
        self.flags = flags
        S = cfg.n_shards
        devices = jax.devices()
        self.devices: Tuple = tuple(
            devices[s % len(devices)] if (cfg.pin_devices
                                          and len(devices) > 1) else None
            for s in range(S))
        self.shards: List[Engine] = [Engine(flags, **engine_kw)
                                     for _ in range(S)]
        n_lanes = cfg.n_lanes
        if n_lanes is None:
            n_lanes = len({d for d in self.devices if d is not None}) or 1
        self.router = ShardRouter(S, dispatch_rows=cfg.dispatch_rows,
                                  coalesce_delay_s=cfg.coalesce_delay_s,
                                  n_lanes=n_lanes)
        self.resources = ResourceManager(cfg.admission)
        self.specs: Dict[str, _TableSpec] = {}
        self.streams: Dict[str, ShardedPipeline] = {}
        self.deployments: Dict[str, ShardedDeploymentHandle] = {}
        self._versions: Dict[str, Dict[int, ShardedDeploymentHandle]] = {}
        self._history: Dict[str, List[ShardedDeploymentHandle]] = {}
        self._deploy_lock = threading.RLock()
        self._closed = False

    # ------------------------------------------------------------ identity
    @property
    def n_shards(self) -> int:
        return self.cfg.n_shards

    @property
    def cache(self):
        """Shard 0's plan cache (FeatureServer warm-gating compat)."""
        return self.shards[0].cache

    def shard_of(self, key) -> int:
        return shard_of(key, self.n_shards)

    # ------------------------------------------------------------------ DDL
    def create_table(self, schema: TableSchema, *, max_keys: int = 1024,
                     capacity: int = 1024, bucket_size: int = 64,
                     join_keys: Sequence[str] = (),
                     replicate: bool = False,
                     per_shard_max_keys: Optional[int] = None) -> None:
        """Create the table on every shard.

        Partitioned (default): each shard holds the keys that hash to it;
        ``max_keys`` is the TOTAL key budget and each shard provisions
        ``max_keys/S`` plus 30% hash-skew headroom (override with
        ``per_shard_max_keys``). Replicated: every shard holds a full
        copy — required for LAST JOIN right tables, whose probes must
        resolve on the probing shard.
        """
        S = self.n_shards
        if replicate or per_shard_max_keys is None:
            per_shard = max_keys if replicate else max(
                16, int(1.3 * max_keys / S) + 8)
        else:
            per_shard = per_shard_max_keys
        for s, eng in enumerate(self.shards):
            eng.create_table(schema, max_keys=per_shard, capacity=capacity,
                             bucket_size=bucket_size, join_keys=join_keys,
                             device=self.devices[s])
        self.specs[schema.name] = _TableSpec(schema=schema,
                                             replicated=replicate)

    def tables_of(self, name: str) -> Tuple:
        """The per-shard Table objects for ``name`` (shard order)."""
        return tuple(e.tables[name] for e in self.shards)

    def insert(self, table: str, keys: Sequence, ts: Sequence[float],
               rows: np.ndarray) -> None:
        """Bulk insert, routed to owning shards (replicated tables fan
        out to all). Per-shard semantics match ``Engine.insert``
        (including the stream barrier when a pipeline is attached);
        atomic validation is per shard — a cross-shard transactional
        reject is future work (DESIGN.md §9)."""
        spec = self._spec(table)
        keys = np.asarray(keys)
        ts = np.asarray(ts, np.float32)
        rows = np.asarray(rows, np.float32)
        if spec.replicated:
            for eng in self.shards:
                eng.insert(table, keys.tolist(), ts.tolist(), rows)
            return
        sid = shard_ids(keys, self.n_shards)
        for s, eng in enumerate(self.shards):
            idx = np.flatnonzero(sid == s)
            if idx.size:
                eng.insert(table, keys[idx].tolist(), ts[idx].tolist(),
                           rows[idx])

    def _spec(self, table: str) -> _TableSpec:
        spec = self.specs.get(table)
        if spec is None:
            raise KeyError(f"unknown table {table!r}; create_table first; "
                           f"known: {sorted(self.specs)}")
        return spec

    # ------------------------------------------------------------ streaming
    def attach_stream(self, table: str, cfg=None, **cfg_kw
                      ) -> ShardedPipeline:
        """One ingest pipeline per shard (per-shard watermarks); events
        route to the owning shard's pipeline."""
        spec = self._spec(table)
        if table in self.streams:
            raise ValueError(f"table {table!r} already has a stream")
        pipes = [eng.attach_stream(table, cfg, **cfg_kw)
                 for eng in self.shards]
        facade = ShardedPipeline(self, table, pipes, spec.replicated)
        self.streams[table] = facade
        return facade

    def create_stream(self, schema: TableSchema, *, max_keys: int = 1024,
                      capacity: int = 1024, bucket_size: int = 64,
                      replicate: bool = False, **cfg_kw):
        self.create_table(schema, max_keys=max_keys, capacity=capacity,
                          bucket_size=bucket_size, replicate=replicate)
        return (self.tables_of(schema.name),
                self.attach_stream(schema.name, **cfg_kw))

    def register_model(self, name: str, fn: Callable,
                       params: object = None) -> None:
        for eng in self.shards:
            eng.register_model(name, fn, params)

    def set_cost_model(self, model: CostModel) -> CostModel:
        """Install calibrated optimizer constants on EVERY shard (all
        shards must compile the same plan — a per-shard cost model would
        break the one-plan-per-version invariant ``deploy`` relies on).
        Takes effect on the next ``deploy``; returns the previous model."""
        with self._deploy_lock:
            prev = self.shards[0].cost_model
            for eng in self.shards:
                eng.set_cost_model(model)
            return prev

    @property
    def cost_model(self) -> CostModel:
        return self.shards[0].cost_model

    # --------------------------------------------------------------- deploy
    def deploy(self, name: str,
               query: Union[str, Query, dsl.QueryBuilder], *,
               warm_buckets: Optional[Sequence[int]] = None,
               canary: float = 0.0) -> ShardedDeploymentHandle:
        """Compile one executable set per shard, then publish the whole
        set atomically under one handle. Joined right tables must be
        replicated (probes resolve through the probing shard's local
        replica)."""
        if canary and not (0.0 < canary <= 1.0):
            raise ValueError(
                f"canary fraction must be in (0, 1], got {canary}")
        if isinstance(query, str):
            query = dsl.parse_sql(query)
        elif isinstance(query, dsl.QueryBuilder):
            query = query.build()
        with self._deploy_lock:
            prev = self.deployments.get(name)
            if canary > 0.0 and prev is None:
                raise ValueError(
                    f"canary deploy of {name!r} requires an existing live "
                    f"deployment; deploy without canary= first")
            # build EVERY shard's version before any publish: a failed
            # shard build must leave the live set untouched AND not leak
            # the versions already built on earlier shards
            handles: List[DeploymentHandle] = []
            try:
                for eng in self.shards:
                    handles.append(eng.build_version(
                        name, query, warm_buckets=warm_buckets))
            except BaseException:
                for eng, h in zip(self.shards, handles):
                    eng.discard_version(h)
                raise
            for j in handles[0].plan.joins:
                if not self._spec(j.table).replicated:
                    for eng, h in zip(self.shards, handles):
                        eng.discard_version(h)
                    raise ValueError(
                        f"LAST JOIN right table {j.table!r} is hash-"
                        f"partitioned; a probing shard could not resolve "
                        f"keys owned by other shards — create it with "
                        f"replicate=True (broadcast dimension table)")
            version = handles[0].version
            sh = ShardedDeploymentHandle(self, name, version, handles)
            self._versions.setdefault(name, {})[version] = sh
            if canary > 0.0:
                displaced = prev._canary[0] if prev._canary else None
                sh.state = DeploymentHandle.CANARY
                prev._canary = (sh, float(canary))
                if displaced is not None:
                    self._discard(displaced)
            else:
                self._swap(name, sh, prev)
            return sh

    def _swap(self, name: str,
              new: ShardedDeploymentHandle,
              prev: Optional[ShardedDeploymentHandle]) -> None:
        for eng, h in zip(self.shards, new.handles):
            eng.publish_version(h)
        new._canary = None
        new.state = DeploymentHandle.LIVE
        self.deployments[name] = new       # the atomic publish
        if prev is not None:
            if prev._canary is not None and prev._canary[0] is not new:
                self._discard(prev._canary[0])
            prev._canary = None
            prev.state = DeploymentHandle.RETIRED
            hist = self._history.setdefault(name, [])
            hist.append(prev)
            # mirror the inner engines' retention bound: beyond it the
            # inner handles released their executables anyway, so the
            # sharded wrapper is unpinnable too
            while len(hist) > self.shards[0].max_retained_versions:
                dropped = hist.pop(0)
                self._versions.get(name, {}).pop(dropped.version, None)

    def _discard(self, cand: ShardedDeploymentHandle) -> None:
        cand.state = DeploymentHandle.RETIRED
        for eng, h in zip(self.shards, cand.handles):
            eng.discard_version(h)
        self._versions.get(cand.name, {}).pop(cand.version, None)

    def handle(self, name: str, version: Optional[int] = None
               ) -> ShardedDeploymentHandle:
        if version is None:
            dep = self.deployments.get(name)
            if dep is None:
                raise KeyError(f"unknown deployment {name!r}; deployed: "
                               f"{sorted(self.deployments)}")
            return dep
        try:
            return self._versions[name][version]
        except KeyError:
            raise KeyError(
                f"deployment {name!r} has no version {version}; known: "
                f"{sorted(self._versions.get(name, {}))}") from None

    def promote(self, name: str) -> ShardedDeploymentHandle:
        with self._deploy_lock:
            live = self.handle(name)
            if live._canary is None:
                raise ValueError(
                    f"deployment {name!r} has no active canary")
            cand, _ = live._canary
            live._canary = None
            self._swap(name, cand, live)
            return cand

    def rollback(self, name: str) -> ShardedDeploymentHandle:
        with self._deploy_lock:
            live = self.deployments.get(name)
            if live is not None and live._canary is not None:
                self._discard(live._canary[0])
                live._canary = None
                return live
            hist = self._history.get(name)
            if not hist:
                raise ValueError(
                    f"no prior version of {name!r} to roll back to")
            prev = hist.pop()
            self._swap(name, prev, live)
            return prev

    # --------------------------------------------------------------- online
    def request(self, name: str, keys: Sequence, ts: Sequence[float],
                rows: Optional[np.ndarray] = None,
                ctx: Optional[RequestContext] = None) -> FeatureFrame:
        pin = ctx.version_pin if ctx is not None else None
        return self.handle(name, pin).request(keys, ts, rows, ctx=ctx)

    # -------------------------------------------------------------- offline
    def query_offline(self, name: str, *, batch_size: int = 1024,
                      point_in_time: bool = True) -> Dict[str, np.ndarray]:
        """Per-shard offline materialisation under pinned snapshots,
        concatenated. ``__key`` holds the ACTUAL key values (not dense
        indices — those are shard-local), plus a ``__shard`` column and
        the ``version_vector`` the run was pinned to."""
        dep = self.handle(name)
        outs: List[Dict[str, np.ndarray]] = []
        vvec = []
        for s, eng in enumerate(self.shards):
            res = eng.query_offline(name, batch_size=batch_size,
                                    point_in_time=point_in_time)
            table = dep.handles[s].table
            vvec.append(table.version)
            if "__key" not in res or len(res["__key"]) == 0:
                # hash skew (or n_shards > distinct keys) can leave a
                # shard with no retained events; skip it rather than
                # concatenating dtype-less empties into the key column
                continue
            inv = {i: k for k, i in table.key_to_idx.items()}
            res["__key"] = np.asarray(
                [inv[int(i)] for i in res["__key"]])
            res["__shard"] = np.full(len(res["__key"]), s, np.int32)
            outs.append(res)
        if not outs:
            merged = {n: np.zeros((0,), np.float32)
                      for n in dep.phys.feature_names}
            merged["__key"] = np.zeros((0,), np.int64)
            merged["__ts"] = np.zeros((0,), np.float32)
            merged["__shard"] = np.zeros((0,), np.int32)
        else:
            merged = {k: np.concatenate([o[k] for o in outs])
                      for k in outs[0]}
        merged["__version_vector"] = np.asarray(vvec, np.int64)
        return merged

    # ---------------------------------------------------------------- intro
    def explain(self, name: str) -> str:
        dep = self.handle(name)
        rs = self.router.stats()
        lines = [
            f"sharded deployment {name!r} v{dep.version} [{dep.state}] "
            f"across {self.n_shards} shard(s)",
            f"  router: hash-partitioned (Knuth multiplicative), "
            f"dispatch_rows={self.cfg.dispatch_rows}, "
            f"rows/dispatch={rs['rows_per_dispatch']:.1f}",
            f"  admission: max_inflight="
            f"{self.cfg.admission.max_inflight}, max_queue_depth="
            f"{self.cfg.admission.max_queue_depth} "
            f"({self.resources.metrics()})",
            f"  devices: " + ", ".join(
                str(d) if d is not None else "default"
                for d in self.devices),
            f"  version vector: {dep.version_vector()}",
        ]
        lines.append("  per-shard plan (shard 0 of "
                     f"{self.n_shards}; all shards compile the same "
                     f"plan):")
        lines += ["  " + l for l in
                  self.shards[0].explain(name).splitlines()]
        return "\n".join(lines)

    def latency_decomposition(self) -> Dict[str, float]:
        # counters sum across shards; rates are recomputed from the
        # summed counters and percentiles take the worst shard — summing
        # a ratio or a p99 across shards would be nonsense
        agg: Dict[str, float] = {}
        join_matches = 0.0
        join_p99: List[float] = []
        for eng in self.shards:
            d = eng.latency_decomposition()
            for k, v in d.items():
                if k in ("cache_hit_rate", "join_match_rate",
                         "join_age_p99"):
                    continue
                agg[k] = agg.get(k, 0.0) + v
            if d.get("join_probes"):
                join_matches += d["join_match_rate"] * d["join_probes"]
                p99 = d.get("join_age_p99", float("nan"))
                if not np.isnan(p99):
                    join_p99.append(p99)
        if agg.get("join_probes"):
            agg["join_match_rate"] = join_matches / agg["join_probes"]
            agg["join_age_p99"] = (max(join_p99) if join_p99
                                   else float("nan"))
        hit = [eng.cache.stats.hit_rate for eng in self.shards]
        agg["cache_hit_rate"] = float(np.mean(hit)) if hit else 0.0
        agg["n_shards"] = self.n_shards
        agg.update({f"router_{k}": v
                    for k, v in self.router.stats().items()})
        agg.update({f"admission_{k}": v
                    for k, v in self.resources.metrics().items()})
        return agg

    # ------------------------------------------------------------ lifecycle
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.router.close()
        self.streams.clear()   # inner engines own + close the pipelines
        for eng in self.shards:
            eng.close()

    def __enter__(self) -> "ShardedEngine":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False
