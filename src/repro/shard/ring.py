"""Consistent-hash ring: elastic key -> shard ownership (DESIGN.md §11).

The modulo partitioner (``shard_of``) is pure in ``(key, n_shards)`` —
perfect while the shard count never changes, but growing N remaps
(N-1)/N of the key space at once. The ring maps keys to the **successor
virtual node** on a 32-bit hash circle instead: adding or removing one
shard only moves the key ranges adjacent to its virtual nodes (~1/N of
the space), so resharding is a bounded background migration instead of
a full rebuild.

Two layers:

* :class:`HashRing` — immutable ownership function. ``vnodes`` points
  per shard (crc32 of ``"shard:<s>:vnode:<v>"``), sorted once;
  ``owners_of`` is one vectorised ``np.searchsorted`` over the batch.
* :class:`RouteTable` — the *mutable* routing state the engine serves
  from **during** a migration. Built over the merged point set of the
  old and new rings, it starts extensionally equal to the old ring and
  is flipped interval-by-interval as each key range finishes copying —
  readers always see a consistent owner for any key, and a range's flip
  is a single int store.

Hashes intentionally reuse the router's Knuth/crc32 family so a ring
with the hash ranges of exactly one shard degenerates gracefully and
scalar/vectorised paths route identically.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

__all__ = ["key_hash", "key_hashes", "HashRing", "RouteTable",
           "ModuloRouting"]

# same multiplicative constant as shard/router.py and featurestore.keydir
_MULT = 2654435761
_MASK32 = 0xFFFFFFFF


def key_hash(key) -> int:
    """32-bit routing hash of one key — pure, stable forever, identical
    to the hash family ``shard_of`` reduces modulo N."""
    if isinstance(key, np.generic):
        key = key.item()      # repr(np.str_) differs across numpy majors
    if isinstance(key, int) and not isinstance(key, bool):
        return (key & _MASK32) * _MULT & _MASK32
    return zlib.crc32(repr(key).encode()) & _MASK32


def key_hashes(keys: np.ndarray) -> np.ndarray:
    """Vectorised :func:`key_hash` -> (B,) uint64 (values < 2**32)."""
    keys = np.asarray(keys)
    if keys.dtype.kind in "iu":
        return ((keys.astype(np.uint64) & _MASK32) * _MULT) & _MASK32
    return np.asarray([key_hash(k) for k in keys.tolist()], np.uint64)


class HashRing:
    """Immutable consistent-hash ring over a set of shard slot ids."""

    def __init__(self, shards: Iterable[int], vnodes: int = 64):
        self.shard_set: Tuple[int, ...] = tuple(sorted(set(shards)))
        if not self.shard_set:
            raise ValueError("a hash ring needs at least one shard")
        self.vnodes = int(vnodes)
        pts: List[int] = []
        owner: List[int] = []
        for s in self.shard_set:
            for v in range(self.vnodes):
                pts.append(zlib.crc32(f"shard:{s}:vnode:{v}".encode())
                           & _MASK32)
                owner.append(s)
        p = np.asarray(pts, np.uint64)
        o = np.asarray(owner, np.int32)
        # stable order: by point, ties by owner id — both rings sharing a
        # collided point value resolve it the same way
        order = np.lexsort((o, p))
        self.points: np.ndarray = p[order]
        self.owners: np.ndarray = o[order]

    # ------------------------------------------------------------ ownership
    def owner_of_hash(self, h: int) -> int:
        i = int(np.searchsorted(self.points, np.uint64(h), side="left"))
        return int(self.owners[i % len(self.points)])

    def owner(self, key) -> int:
        return self.owner_of_hash(key_hash(key))

    def owners_of(self, keys: np.ndarray) -> np.ndarray:
        h = key_hashes(keys)
        idx = np.searchsorted(self.points, h, side="left")
        return self.owners[idx % len(self.points)].astype(np.int32)

    # ------------------------------------------------------------ evolution
    def with_shard(self, shard: int) -> "HashRing":
        return HashRing(self.shard_set + (shard,), self.vnodes)

    def without_shard(self, shard: int) -> "HashRing":
        rest = tuple(s for s in self.shard_set if s != shard)
        return HashRing(rest, self.vnodes)

    def __repr__(self) -> str:
        return (f"HashRing(shards={self.shard_set}, "
                f"vnodes={self.vnodes})")


class RouteTable:
    """Mutable interval -> owner map serving reads during a migration.

    Intervals are the elementary arcs of the merged point set of the old
    and the new ring: within one arc both rings are constant, so a
    migration step ("this arc now belongs to shard t") is one element
    store into ``cur``. ``owners_of`` stays a single ``searchsorted``.
    Arc ``i`` covers hashes ``(points[i-1], points[i]]`` with the usual
    wraparound for ``i == 0``.
    """

    def __init__(self, ring: HashRing):
        self.points = ring.points.copy()
        self.cur = ring.owners.astype(np.int32).copy()

    @classmethod
    def merged(cls, old: HashRing, new: HashRing) -> "RouteTable":
        """Route table over the union point set, initially routing
        exactly like ``old``."""
        rt = cls.__new__(cls)
        pts = np.union1d(old.points, new.points)
        rt.points = pts.astype(np.uint64)
        rt.cur = np.asarray([old.owner_of_hash(int(p)) for p in pts],
                            np.int32)
        return rt

    def plan_against(self, new: HashRing) -> List[int]:
        """Arc indices whose owner must change to make this table route
        like ``new`` — the migration work list."""
        tgt = np.asarray([new.owner_of_hash(int(p)) for p in self.points],
                         np.int32)
        return [int(i) for i in np.flatnonzero(tgt != self.cur)]

    # ------------------------------------------------------------ ownership
    def arc_of_hashes(self, h: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.points, h, side="left") \
            % len(self.points)

    def owner(self, key) -> int:
        i = int(np.searchsorted(self.points, np.uint64(key_hash(key)),
                                side="left"))
        return int(self.cur[i % len(self.points)])

    def owners_of(self, keys: np.ndarray) -> np.ndarray:
        return self.cur[self.arc_of_hashes(key_hashes(keys))]

    def set_owner(self, arcs: Sequence[int], owner: int) -> None:
        for i in arcs:
            self.cur[i] = owner

    def arc_owner(self, arc: int) -> int:
        return int(self.cur[arc])

    def shard_counts(self) -> Dict[int, int]:
        u, c = np.unique(self.cur, return_counts=True)
        return {int(s): int(n) for s, n in zip(u, c)}


class ModuloRouting:
    """The original ``hash % N`` partitioner behind the same owner API —
    kept as an explicit escape hatch (``ShardConfig(partitioner=
    "modulo")``); it cannot reshard."""

    def __init__(self, n_shards: int):
        self.n_shards = int(n_shards)

    def owner(self, key) -> int:
        if self.n_shards <= 1:
            return 0
        return key_hash(key) % self.n_shards

    def owners_of(self, keys: np.ndarray) -> np.ndarray:
        if self.n_shards <= 1:
            return np.zeros(len(keys), np.int32)
        return (key_hashes(keys) % self.n_shards).astype(np.int32)
