"""Length-prefixed, CRC-framed pickle transport over a socketpair
(DESIGN.md §11, §12).

One AF_UNIX ``socketpair`` per worker, created by the parent and passed
to the subprocess by fd inheritance (``REPRO_SHARD_WORKER_FD``). Frames
are ``8-byte big-endian length || 4-byte crc32 || pickle payload``; a
frame is a 3-tuple:

    request:  (req_id, method, args_blob)     args_blob = pickle(dict)
    response: (req_id, ok, payload)           payload = result | exc

``args_blob`` is pre-pickled *bytes inside the frame* so a broadcast
(replicated dimension-table ingest) serializes the — potentially large —
array payload ONCE and fans the same blob to every worker; the outer
frame per worker differs only by its req_id.

Integrity: the CRC covers the pickle payload. On mismatch ``recv``
raises :class:`FrameCorrupt` — crucially AFTER consuming the full
declared length, so the stream stays frame-aligned and the reader can
skip the bad frame and keep going (the sender's retry/backoff layer
re-sends; see ``proc/backend.py``). Without the CRC a flipped bit
becomes a pickle crash or, worse, silently wrong data.

Fault injection: a :class:`~repro.shard.proc.faults.FaultInjector`
assigned to ``Channel.fault_injector`` intercepts every outbound frame
(drop / delay / duplicate / corrupt / kill-on-nth) — the chaos suite's
only hook into the wire, so production paths carry zero fault branches.

Sends are locked (many lanes share one worker channel); receives are
single-reader (the parent's per-worker reader thread / the worker's
serve loop). Numpy arrays ride pickle protocol 5 buffer support where
available — on one host this is a memcpy, not an encode.
"""
from __future__ import annotations

import pickle
import socket
import struct
import threading
import zlib
from typing import Any, Optional, Tuple

__all__ = ["Channel", "FrameCorrupt", "encode_args", "decode_args"]

_HDR = struct.Struct(">QI")          # payload length, crc32(payload)
_PROTO = pickle.HIGHEST_PROTOCOL


class FrameCorrupt(RuntimeError):
    """A received frame failed its CRC (or would not unpickle). The
    stream is still aligned — the full frame was consumed — so this is
    RETRYABLE: drop the frame, count it, read the next one."""


def encode_args(args: dict) -> bytes:
    """Pickle an RPC's kwargs once — shareable across a broadcast."""
    return pickle.dumps(args, protocol=_PROTO)


def decode_args(blob: bytes) -> dict:
    return pickle.loads(blob)


class Channel:
    """One framed, thread-safe-send / single-reader pickle channel."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._closed = False
        # chaos hook — installed only AFTER the hello/ready handshake so
        # bootstrap frames are never dropped (proc/faults.py)
        self.fault_injector = None  # type: Optional[Any]

    # -------------------------------------------------------------- send
    def send(self, obj: Tuple) -> None:
        payload = pickle.dumps(obj, protocol=_PROTO)
        inj = self.fault_injector
        if inj is None:
            frames = [(payload, zlib.crc32(payload))]
        else:
            # the injector decides what actually hits the wire: [] drops
            # the frame, two entries duplicate it, a mutated payload
            # under the ORIGINAL crc models on-wire corruption (length
            # unchanged, so the receiver stays frame-aligned)
            frames = inj.frames(payload)
        with self._send_lock:
            for p, crc in frames:
                self._sock.sendall(_HDR.pack(len(p), crc) + p)

    # -------------------------------------------------------------- recv
    def _recv_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("channel peer closed")
            buf.extend(chunk)
        return bytes(buf)

    def recv(self) -> Any:
        """Blocking read of one frame. Raises ``EOFError`` when the peer
        is gone (worker death / parent exit) and ``FrameCorrupt`` on a
        CRC/unpickle failure — after consuming the whole frame, so the
        caller may simply read the next one."""
        length, crc = _HDR.unpack(self._recv_exact(_HDR.size))
        payload = self._recv_exact(length)      # always consume: stay aligned
        if zlib.crc32(payload) != crc:
            raise FrameCorrupt(
                f"frame of {length} bytes failed crc32 check")
        try:
            return pickle.loads(payload)
        except Exception as e:                  # garbage that passed CRC
            raise FrameCorrupt(f"frame would not unpickle: {e!r}") from e

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()

    @property
    def closed(self) -> bool:
        return self._closed
