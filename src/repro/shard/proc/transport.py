"""Length-prefixed pickle framing over a socketpair (DESIGN.md §11).

One AF_UNIX ``socketpair`` per worker, created by the parent and passed
to the subprocess by fd inheritance (``REPRO_SHARD_WORKER_FD``). Frames
are ``8-byte big-endian length || pickle payload``; a frame is a
3-tuple:

    request:  (req_id, method, args_blob)     args_blob = pickle(dict)
    response: (req_id, ok, payload)           payload = result | exc

``args_blob`` is pre-pickled *bytes inside the frame* so a broadcast
(replicated dimension-table ingest) serializes the — potentially large —
array payload ONCE and fans the same blob to every worker; the outer
frame per worker differs only by its req_id.

Sends are locked (many lanes share one worker channel); receives are
single-reader (the parent's per-worker reader thread / the worker's
serve loop). Numpy arrays ride pickle protocol 5 buffer support where
available — on one host this is a memcpy, not an encode.
"""
from __future__ import annotations

import pickle
import socket
import struct
import threading
from typing import Any, Optional, Tuple

__all__ = ["Channel", "encode_args", "decode_args"]

_LEN = struct.Struct(">Q")
_PROTO = pickle.HIGHEST_PROTOCOL


def encode_args(args: dict) -> bytes:
    """Pickle an RPC's kwargs once — shareable across a broadcast."""
    return pickle.dumps(args, protocol=_PROTO)


def decode_args(blob: bytes) -> dict:
    return pickle.loads(blob)


class Channel:
    """One framed, thread-safe-send / single-reader pickle channel."""

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._send_lock = threading.Lock()
        self._closed = False

    # -------------------------------------------------------------- send
    def send(self, obj: Tuple) -> None:
        payload = pickle.dumps(obj, protocol=_PROTO)
        with self._send_lock:
            self._sock.sendall(_LEN.pack(len(payload)) + payload)

    # -------------------------------------------------------------- recv
    def _recv_exact(self, n: int) -> bytes:
        buf = bytearray()
        while len(buf) < n:
            chunk = self._sock.recv(n - len(buf))
            if not chunk:
                raise EOFError("channel peer closed")
            buf.extend(chunk)
        return bytes(buf)

    def recv(self) -> Any:
        """Blocking read of one frame. Raises ``EOFError`` when the peer
        is gone (worker death / parent exit)."""
        (length,) = _LEN.unpack(self._recv_exact(_LEN.size))
        return pickle.loads(self._recv_exact(length))

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()

    @property
    def closed(self) -> bool:
        return self._closed
