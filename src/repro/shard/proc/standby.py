"""Warm-standby worker pool: pre-forked, jax-imported, ready to adopt.

A shard worker's spawn cost is dominated by jax import + backend init
(~5 s on CPU) — dead weight on the recovery critical path, since the
replacement process runs the exact same bootstrap every time. The pool
keeps ``n`` workers parked PAST that bootstrap: each is spawned with
``REPRO_SHARD_PREWARM=1``, eagerly imports the Engine stack, sends a
``("warm", {pid})`` frame, and then blocks on ``recv`` waiting for a
hello that may come much later.

Adoption (DESIGN.md §12): when a shard dies, ``_WorkerProc`` asks the
pool for a warmed entry and — instead of spawning — sends its normal
``hello`` (shard identity, flags, engine kwargs) down the standby's
existing channel. The standby wakes, constructs the Engine (cheap: jax
is already resident), replies ``ready``, and IS the replacement worker;
kill→serving MTTR drops from seconds to the catalog replay alone. Every
``take`` triggers a background refill, so the pool self-heals back to
``n`` after absorbing a failure burst.

Standbys are shard-agnostic on purpose: the jax env pins
(`worker_env`) are identical for every shard, and everything
shard-specific arrives in the hello — one pool serves the whole fleet.
"""
from __future__ import annotations

import socket
import subprocess
import sys
import threading
from types import SimpleNamespace
from typing import List, Optional, Tuple

from repro.shard.proc.transport import Channel

__all__ = ["StandbyPool"]

_WARM_TIMEOUT_S = 180.0


class StandbyPool:
    """``n`` pre-warmed shard workers awaiting adoption."""

    def __init__(self, n: int, compile_cache: Optional[str] = None):
        self.n = int(n)
        self.compile_cache = compile_cache
        self._lock = threading.Lock()
        self._entries: List[SimpleNamespace] = []
        self._closing = False
        self.stats = {"spawned": 0, "adopted": 0, "misses": 0}
        for _ in range(self.n):
            self._spawn_one()

    # ------------------------------------------------------------- spawn
    def _spawn_one(self) -> None:
        # lazy import: backend.py owns worker_env and imports this module
        from repro.shard.proc.backend import worker_env
        with self._lock:
            if self._closing:
                return
        parent_sock, child_sock = socket.socketpair()
        env = worker_env(-1, compile_cache=self.compile_cache)
        # shard identity comes in hello
        env["REPRO_SHARD_WORKER_FD"] = str(child_sock.fileno())
        env["REPRO_SHARD_PREWARM"] = "1"
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.shard.proc.worker"],
            env=env, pass_fds=[child_sock.fileno()])
        child_sock.close()
        entry = SimpleNamespace(proc=proc, sock=parent_sock,
                                ch=Channel(parent_sock),
                                warmed=threading.Event(), dead=False)
        with self._lock:
            if self._closing:
                self._kill(entry)
                return
            self._entries.append(entry)
            self.stats["spawned"] += 1
        threading.Thread(target=self._watch, args=(entry,), daemon=True,
                         name="standby-watch").start()

    def _watch(self, entry) -> None:
        """Consume the standby's single ``warm`` frame, then get out of
        the way — after ``warmed`` is set nothing reads this channel
        until an adopter runs its handshake on it."""
        try:
            entry.sock.settimeout(_WARM_TIMEOUT_S)
            tag, info = entry.ch.recv()
            entry.sock.settimeout(None)
            if tag == "warm":
                entry.pid = info["pid"]
                entry.warmed.set()
                return
        except Exception:
            pass
        entry.dead = True
        try:
            entry.ch.close()
        except OSError:
            pass

    # -------------------------------------------------------------- take
    def take(self) -> Optional[Tuple[subprocess.Popen, socket.socket,
                                     Channel]]:
        """Pop one warmed standby as ``(proc, sock, channel)`` — or
        ``None`` when nothing is warm yet (the caller cold-spawns).
        Always kicks off a background refill on a hit."""
        with self._lock:
            if self._closing:
                return None
            hit = None
            for i, e in enumerate(self._entries):
                if e.warmed.is_set() and not e.dead \
                        and e.proc.poll() is None:
                    hit = self._entries.pop(i)
                    break
            if hit is None:
                self.stats["misses"] += 1
                return None
            self.stats["adopted"] += 1
        threading.Thread(target=self._spawn_one, daemon=True,
                         name="standby-refill").start()
        return hit.proc, hit.sock, hit.ch

    @property
    def n_warm(self) -> int:
        with self._lock:
            return sum(1 for e in self._entries
                       if e.warmed.is_set() and not e.dead)

    # --------------------------------------------------------- lifecycle
    @staticmethod
    def _kill(entry) -> None:
        try:
            entry.ch.close()               # EOF wakes the parked worker
        except OSError:
            pass
        try:
            entry.proc.wait(timeout=2.0)
        except subprocess.TimeoutExpired:
            entry.proc.kill()
            entry.proc.wait(timeout=5.0)

    def close(self) -> None:
        with self._lock:
            self._closing = True
            entries = list(self._entries)
            self._entries.clear()
        for e in entries:
            self._kill(e)
