"""Process-backed shard runtime: one subprocess per shard engine.

See DESIGN.md §11. Public surface: :class:`ProcShardBackend` (selected
via ``ShardedEngine(backend="process")`` or ``REPRO_SHARD_BACKEND=
process``); ``worker.py`` is the subprocess entry point
(``python -m repro.shard.proc.worker``)."""
from repro.shard.proc.backend import (ProcDeploymentHandle,
                                      ProcEngineClient,
                                      ProcPipelineClient,
                                      ProcShardBackend, worker_env)
from repro.shard.proc.transport import Channel, decode_args, encode_args

__all__ = ["ProcShardBackend", "ProcEngineClient", "ProcDeploymentHandle",
           "ProcPipelineClient", "worker_env", "Channel", "encode_args",
           "decode_args"]
