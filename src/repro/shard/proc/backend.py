"""Parent-side process backend: worker supervision + Engine-shaped proxies.

``ProcShardBackend`` owns N :class:`_WorkerProc` subprocesses (one per
shard, spawned with the olmax-style per-process jax env pins) and wraps
each in a :class:`ProcEngineClient` that duck-types the slice of the
``Engine`` API the sharded runtime uses — so ``ShardedEngine`` and the
``ShardRouter`` lanes run UNCHANGED against subprocess shards: a lane
calls ``handle.request(keys, ts, rows)`` exactly as before; here that
is one ``serve`` RPC over the worker's channel instead of a local call.

Liveness: a monitor thread polls worker processes. Death fails every
pending RPC with :class:`ShardDownError` (lanes translate it into a
whole-batch ``STATUS_SHED`` — no hung futures, no raw exceptions on the
serving path), then the worker is respawned, its catalog (DDL, streams,
models, cost model) replayed, replicated dimension tables re-seeded
from a healthy shard, and the engine's ``_replay_shard`` hook rebuilds
and republishes every retained deployment version. Partitioned table
data is NOT recovered — it re-enters through the stream like any other
restart (documented in DESIGN.md §11).

Version alias map: a respawned worker restarts version numbering at 1,
while the parent's handles keep their original version ids; per-client
``(name, parent_version) -> worker_version`` aliases keep every parent
handle addressable across respawns without rewriting the router/engine
bookkeeping.
"""
from __future__ import annotations

import os
import signal
import socket
import subprocess
import sys
import threading
import time
from types import SimpleNamespace
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.shard.proc.faults import FaultInjector, FaultPlan
from repro.shard.proc.transport import Channel, FrameCorrupt, encode_args
from repro.shard.router import ShardDownError

__all__ = ["ProcShardBackend", "ProcEngineClient", "ProcDeploymentHandle",
           "ProcPipelineClient", "worker_env"]

_SPAWN_TIMEOUT_S = 120.0
_RPC_TIMEOUT_S = 120.0
# retry/backoff for unanswered RPC attempts: the frame (same req_id —
# worker-side dedup keeps execution exactly-once) is re-sent after
# base·2^attempt seconds, capped; the OVERALL call deadline still rules
_RETRY_BASE_S = 0.25
_RETRY_CAP_S = 5.0
_TCMALLOC_PATHS = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def worker_env(shard_id: int,
               compile_cache: Optional[str] = None) -> Dict[str, str]:
    """Per-worker env pins (the SNIPPETS.md olmax ``run.sh`` recipe):
    exactly one XLA host device per worker, CPU platform + dtype pins,
    quiet logs, tcmalloc preload when available. These must be in the
    environment BEFORE the worker imports jax — the whole reason shards
    are subprocesses."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "xla_force_host_platform_device_count" not in f]
    flags.append("--xla_force_host_platform_device_count=1")
    env["XLA_FLAGS"] = " ".join(flags)
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.setdefault("JAX_ENABLE_X64", "0")
    env.setdefault("JAX_DEFAULT_DTYPE_BITS", "32")
    env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    if "LD_PRELOAD" not in env:
        for p in _TCMALLOC_PATHS:
            if os.path.exists(p):
                env["LD_PRELOAD"] = p
                break
    src = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH", "")) if p)
    env["REPRO_SHARD_WORKER_ID"] = str(shard_id)
    # persistent jax compilation cache (REPRO_SHARD_COMPILE_CACHE or the
    # engine's compile_cache_dir config): a RESPAWNED worker replays its
    # WAL and rebuilds deployments against already-serialized XLA
    # executables instead of recompiling them — compile time dominates
    # cold-recovery MTTR once the interpreter import is amortized by the
    # standby pool
    cache = compile_cache or env.get("REPRO_SHARD_COMPILE_CACHE")
    if cache:
        env.setdefault("JAX_COMPILATION_CACHE_DIR", cache)
        env.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
        env.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "-1")
    # the worker must not itself default to the process backend
    env.pop("REPRO_SHARD_BACKEND", None)
    return env


class _WorkerProc:
    """One worker subprocess + its channel + pending-RPC bookkeeping.

    May *adopt* a pre-warmed standby process instead of cold-spawning
    (``standby``), carries a shared ``stats`` dict so transport counters
    survive respawns, and arms a parent-side fault injector (with a
    SIGKILL trigger on this worker) when a ``fault_plan`` is given."""

    def __init__(self, shard_id: int, flags, engine_kw: dict, *,
                 fault_plan: Optional[FaultPlan] = None,
                 standby=None, stats: Optional[Dict[str, int]] = None,
                 compile_cache: Optional[str] = None):
        self.shard_id = shard_id
        self.alive = False
        self.adopted = False
        self._lock = threading.Lock()
        self._pending: Dict[int, "threading.Event"] = {}
        self._results: Dict[int, Tuple[bool, object]] = {}
        self._req_seq = 0
        self.stats = stats if stats is not None else {}
        entry = standby.take() if standby is not None else None
        if entry is not None:
            # warm adoption: the standby already paid jax import and is
            # parked on recv — our hello turns it into this shard
            self.proc, parent_sock, self.ch = entry
            self.adopted = True
        else:
            parent_sock, child_sock = socket.socketpair()
            env = worker_env(shard_id, compile_cache=compile_cache)
            env["REPRO_SHARD_WORKER_FD"] = str(child_sock.fileno())
            self.proc = subprocess.Popen(
                [sys.executable, "-m", "repro.shard.proc.worker"],
                env=env, pass_fds=[child_sock.fileno()])
            child_sock.close()
            self.ch = Channel(parent_sock)
        # handshake: engine construction args out, ready frame back
        parent_sock.settimeout(_SPAWN_TIMEOUT_S)
        self.ch.send(("hello", {"shard_id": shard_id, "flags": flags,
                                "engine_kw": engine_kw,
                                "fault_plan": fault_plan}))
        tag, info = self.ch.recv()
        assert tag == "ready", f"worker {shard_id} bad handshake: {tag!r}"
        parent_sock.settimeout(None)
        self.pid = info["pid"]
        self.alive = True
        # chaos: only after the handshake — bootstrap frames are sacred.
        # The kill trigger lives HERE (not in the worker): SIGKILL on
        # the Nth outbound frame models a worker dying mid-RPC.
        if fault_plan is not None and fault_plan.active:
            pid = self.pid
            self.ch.fault_injector = FaultInjector(
                fault_plan, role=f"client-{shard_id}",
                kill_cb=lambda: os.kill(pid, signal.SIGKILL))
        self._reader = threading.Thread(
            target=self._read_loop, daemon=True,
            name=f"shard{shard_id}-reader")
        self._reader.start()

    # ---------------------------------------------------------------- rpc
    def _read_loop(self) -> None:
        try:
            while True:
                try:
                    req_id, ok, payload = self.ch.recv()
                except FrameCorrupt:
                    # frame consumed, stream aligned: the retry layer
                    # re-sends the request, so just count and read on
                    self.stats["frame_corrupt"] = \
                        self.stats.get("frame_corrupt", 0) + 1
                    continue
                with self._lock:
                    ev = self._pending.pop(req_id, None)
                    if ev is not None:
                        self._results[req_id] = (ok, payload)
                        ev.set()
        except (EOFError, OSError):
            self.mark_down()

    def mark_down(self) -> None:
        """Worker is gone: fail every pending RPC immediately."""
        with self._lock:
            self.alive = False
            pending = list(self._pending.items())
            self._pending.clear()
            for req_id, ev in pending:
                self._results[req_id] = (False, ShardDownError(
                    f"shard {self.shard_id} worker (pid {self.pid}) died"))
                ev.set()

    def submit_blob(self, method: str, blob: bytes) -> int:
        with self._lock:
            if not self.alive:
                raise ShardDownError(
                    f"shard {self.shard_id} worker is down")
            self._req_seq += 1
            req_id = self._req_seq
            self._pending[req_id] = threading.Event()
        try:
            self.ch.send((req_id, method, blob))
        except OSError:
            self.mark_down()
        return req_id

    def wait(self, req_id: int, timeout: float = _RPC_TIMEOUT_S):
        with self._lock:
            ev = self._pending.get(req_id)
            done = req_id in self._results
        if not done and ev is not None and not ev.wait(timeout):
            with self._lock:
                self._pending.pop(req_id, None)
            raise TimeoutError(
                f"shard {self.shard_id} RPC timed out after {timeout}s")
        with self._lock:
            ok, payload = self._results.pop(req_id)
        if not ok:
            raise payload
        return payload

    def call(self, method: str, _timeout: float = _RPC_TIMEOUT_S,
             **args):
        """RPC with bounded-exponential-backoff retry. An unanswered
        attempt re-sends the SAME req_id/frame (drop/corrupt faults eat
        frames; the worker's dedup keeps a merely-slow original from
        double-executing), until the overall ``_timeout`` deadline.
        ``ShardDownError`` is never retried — the supervisor owns
        respawn, and the lane sheds/degrades meanwhile."""
        blob = encode_args(args)
        deadline = time.monotonic() + _timeout
        with self._lock:
            if not self.alive:
                raise ShardDownError(
                    f"shard {self.shard_id} worker is down")
            self._req_seq += 1
            req_id = self._req_seq
            ev = self._pending[req_id] = threading.Event()
        attempt = 0
        while True:
            try:
                self.ch.send((req_id, method, blob))
            except OSError:
                self.mark_down()     # sets ev with ShardDownError below
            attempt_s = min(_RETRY_BASE_S * (2.0 ** attempt),
                            _RETRY_CAP_S)
            remaining = deadline - time.monotonic()
            if ev.wait(min(attempt_s, max(remaining, 0.001))):
                with self._lock:
                    ok, payload = self._results.pop(req_id)
                if not ok:
                    raise payload
                return payload
            if time.monotonic() >= deadline:
                with self._lock:
                    self._pending.pop(req_id, None)
                    self._results.pop(req_id, None)
                self.stats["rpc_timeouts"] = \
                    self.stats.get("rpc_timeouts", 0) + 1
                raise TimeoutError(
                    f"shard {self.shard_id} RPC {method!r} timed out "
                    f"after {_timeout}s ({attempt + 1} attempts)")
            attempt += 1
            self.stats["retries"] = self.stats.get("retries", 0) + 1

    # --------------------------------------------------------- lifecycle
    def dead(self) -> bool:
        return self.proc.poll() is not None

    def close(self, timeout: float = 5.0) -> None:
        if self.alive:
            try:
                self.submit_blob("shutdown", encode_args({}))
            except (ShardDownError, OSError):
                pass
        self.ch.close()           # EOF unblocks the worker's serve loop
        try:
            self.proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait(timeout=5.0)
        self.mark_down()


class _ProxyMetrics:
    """Parent-side per-shard-handle counters (tests and introspection
    read ``handle.metrics.requests``; the authoritative worker-side
    HandleMetrics stays available via the ``handle_metrics`` RPC)."""

    def __init__(self):
        self.requests = 0
        self.batches = 0
        self.serve_s = 0.0

    def snapshot(self) -> Dict[str, float]:
        return {"requests": self.requests, "batches": self.batches,
                "serve_s": self.serve_s}


class _TableMirror:
    """Schema + last-seen version of one worker-side table. ``version``
    refreshes on every publish/flush/serve response, so version vectors
    are cheap reads, not RPCs."""

    def __init__(self, schema, version: int = 0):
        self.schema = schema
        self.version = version

    def __repr__(self) -> str:
        return (f"_TableMirror({self.schema.name!r} "
                f"v{self.version})")


class ProcDeploymentHandle:
    """Per-shard deployment proxy satisfying the lane/handle contract:
    ``request(keys, ts, rows)``, ``.table.schema``, ``.plan.joins``,
    ``.phys.feature_names``, ``.metrics``, ``.warm``, ``.live``."""

    # lanes may pass ``timeout_s`` (derived from the RequestContext
    # deadline) so a serve RPC cannot outlive its request's budget
    supports_rpc_deadline = True

    def __init__(self, client: "ProcEngineClient", name: str,
                 version: int, summary: dict):
        from repro.core.engine import DeploymentHandle
        self.client = client
        self.name = name
        self.version = version           # parent version id (stable)
        self.table = client._table_mirror(summary["table"],
                                          summary["schema"])
        self.table.version = summary["table_version"]
        self.plan = SimpleNamespace(joins=tuple(summary["joins"]))
        self.phys = SimpleNamespace(
            feature_names=list(summary["feature_names"]))
        self.state = DeploymentHandle.WARMING
        self.metrics = _ProxyMetrics()

    @property
    def live(self) -> bool:
        from repro.core.engine import DeploymentHandle
        return self.state == DeploymentHandle.LIVE

    def _wv(self) -> int:
        return self.client._alias.get((self.name, self.version),
                                      self.version)

    def request(self, keys, ts, rows=None, *,
                timeout_s: Optional[float] = None, ctx=None, n_live=None):
        from repro.core.results import FeatureFrame
        if not self.client.ready:
            raise ShardDownError(
                f"shard {self.client.shard_id} is respawning")
        tracer = getattr(self.client, "tracer", None)
        trace = None
        if (ctx is not None and ctx.trace_id is not None
                and tracer is not None and tracer.sampled(ctx.trace_id)):
            trace = {"trace_id": ctx.trace_id, "parent": ctx.parent_span}
        t0 = time.perf_counter()
        columns, status, tver, spans, wm, age = self.client.proc.call(
            "serve",
            _timeout=_RPC_TIMEOUT_S if timeout_s is None else timeout_s,
            name=self.name, version=self._wv(),
            keys=np.asarray(keys), ts=np.asarray(ts, np.float32),
            rows=None if rows is None else np.asarray(rows, np.float32),
            trace=trace, n_live=n_live)
        t1 = time.perf_counter()
        if spans and tracer is not None:
            self._adopt_spans(tracer, spans, t0, t1)
        self.table.version = max(self.table.version, tver)
        self.metrics.requests += len(keys)
        self.metrics.batches += 1
        self.metrics.serve_s += t1 - t0
        return FeatureFrame(columns, status=status, deployment=self.name,
                            version=self.version, table_version=tver,
                            watermark=wm, feature_age=age)

    @staticmethod
    def _adopt_spans(tracer, spans, rpc_start: float,
                     rpc_end: float) -> None:
        """Re-base worker-clock spans onto this process's clock: the
        worker span window is centered inside the RPC window (transport
        overhead split evenly before/after — the classic symmetric-
        offset estimate), then adopted idempotently (retried/duplicated
        RPCs re-deliver the same span ids; ``Tracer.adopt`` dedups)."""
        w0 = min(s["start"] for s in spans)
        w1 = max(s["end"] for s in spans)
        slack = max((rpc_end - rpc_start) - (w1 - w0), 0.0) / 2.0
        tracer.adopt(spans, rebase=rpc_start + slack - w0)

    def warm(self, buckets: Sequence[int]) -> int:
        return self.client.proc.call("warm", name=self.name,
                                     version=self._wv(),
                                     buckets=tuple(buckets))

    def join_staleness(self) -> Dict[str, Dict[str, float]]:
        return self.client.proc.call("join_staleness", name=self.name,
                                     version=self._wv())

    def __repr__(self) -> str:
        return (f"ProcDeploymentHandle({self.name!r} v{self.version} "
                f"[{self.state}] shard {self.client.shard_id})")


class ProcPipelineClient:
    """IngestPipeline proxy for one shard's stream (RPC per call; the
    worker-side flusher thread does the actual table mutation)."""

    def __init__(self, client: "ProcEngineClient", table: str):
        self.client = client
        self.table_name = table

    @property
    def table(self) -> _TableMirror:
        return self.client._tables[self.table_name]

    def push(self, key, ts: float, row) -> bool:
        return self.client.proc.call("pipe_push", table=self.table_name,
                                     key=key, ts=float(ts),
                                     row=np.asarray(row, np.float32))

    def push_batch(self, keys, ts, rows, *, all_or_nothing: bool = False
                   ) -> int:
        return self.client.proc.call(
            "pipe_push_batch", table=self.table_name,
            keys=np.asarray(keys), ts=np.asarray(ts, np.float32),
            rows=np.asarray(rows, np.float32),
            all_or_nothing=all_or_nothing)

    def prepare(self, keys, ts, rows) -> Optional[int]:
        return self.client.proc.call(
            "pipe_prepare", table=self.table_name,
            keys=np.asarray(keys), ts=np.asarray(ts, np.float32),
            rows=np.asarray(rows, np.float32))

    def commit_txn(self, txn: int) -> int:
        return self.client.proc.call("pipe_commit",
                                     table=self.table_name, txn=txn)

    def abort_txn(self, txn: int) -> None:
        self.client.proc.call("pipe_abort", table=self.table_name,
                              txn=txn)

    def flush(self, *, flush_all: bool = True, check: bool = False
              ) -> None:
        ver = self.client.proc.call("pipe_flush", table=self.table_name,
                                    flush_all=flush_all, check=check)
        self.table.version = max(self.table.version, ver)

    def wait_idle(self, timeout: float = 30.0) -> bool:
        return self.client.proc.call("pipe_wait_idle",
                                     table=self.table_name,
                                     timeout=timeout)

    def warm(self) -> int:
        return self.client.proc.call("pipe_warm", table=self.table_name)

    def metrics(self) -> Dict[str, float]:
        return self.client.proc.call("pipe_metrics",
                                     table=self.table_name)

    def close(self, *, drain: bool = True) -> None:
        # the worker owns its pipelines and closes them with its engine;
        # a parent-side close is just a best-effort final drain
        if drain and self.client.proc.alive:
            try:
                self.flush(flush_all=True)
            except (ShardDownError, TimeoutError):
                pass


class _StatsProxy:
    """``engine.stats`` stand-in — the control plane reads worker-side
    counter snapshots over the transport (ISSUE 7 requirement)."""

    def __init__(self, client: "ProcEngineClient", method: str):
        self._client = client
        self._method = method

    def snapshot(self) -> Dict[str, float]:
        return self._client.proc.call(self._method)


class _CacheStatsProxy(_StatsProxy):
    @property
    def hit_rate(self) -> float:
        return self._client.proc.call("cache_hit_rate")


class _CacheProxy:
    def __init__(self, client: "ProcEngineClient", enabled: bool):
        self.stats = _CacheStatsProxy(client, "cache_stats")
        self.enabled = enabled


class ProcEngineClient:
    """Engine-shaped facade over one worker subprocess. Implements the
    subset of the Engine surface ``ShardedEngine`` + telemetry touch;
    anything else raises ``AttributeError`` naturally (in-process-only
    introspection like ``.tables`` is deliberately absent — reaching
    into another process's objects is the bug this backend removes)."""

    def __init__(self, backend: "ProcShardBackend", shard_id: int):
        from repro.core.optimizer import CostModel
        self.backend = backend
        self.shard_id = shard_id
        # client-level so counters survive worker respawns (each
        # _WorkerProc writes into this same dict)
        self.transport_stats: Dict[str, int] = {
            "retries": 0, "frame_corrupt": 0, "rpc_timeouts": 0}
        self.proc = _WorkerProc(shard_id, backend.flags,
                                backend.engine_kw,
                                fault_plan=backend.fault_plan,
                                standby=backend.standby,
                                stats=self.transport_stats,
                                compile_cache=backend.compile_cache)
        self._tables: Dict[str, _TableMirror] = {}
        self._streams: Dict[str, ProcPipelineClient] = {}
        self._alias: Dict[Tuple[str, int], int] = {}
        self._live: Dict[str, ProcDeploymentHandle] = {}
        self.stats = _StatsProxy(self, "engine_stats")
        self.cache = _CacheProxy(
            self, enabled=backend.engine_kw.get("max_cache_entries",
                                                128) > 0)
        self.max_retained_versions = backend.engine_kw.get(
            "max_retained_versions", 2)
        self.cost_model = backend.engine_kw.get("cost_model") \
            or CostModel()
        self.restarts = 0
        # set by ShardedEngine.remove_shard: an intentionally-closed
        # worker must not be respawned by the supervisor
        self.retired = False
        # False while a respawn is replaying the catalog/deployments on
        # a fresh worker: the process is alive but cannot serve yet, so
        # the serving path sheds (worker_down) instead of surfacing the
        # worker's raw missing-handle errors
        self.ready = True

    # ----------------------------------------------------------- mirrors
    def _table_mirror(self, name: str, schema) -> _TableMirror:
        m = self._tables.get(name)
        if m is None:
            m = self._tables[name] = _TableMirror(schema)
        return m

    # --------------------------------------------------------------- DDL
    def create_table(self, schema, *, max_keys: int = 1024,
                     capacity: int = 1024, bucket_size: int = 64,
                     join_keys: Sequence[str] = (), device=None) -> None:
        del device  # each worker owns its whole (single-device) runtime
        self.proc.call("create_table", schema=schema, max_keys=max_keys,
                       capacity=capacity, bucket_size=bucket_size,
                       join_keys=tuple(join_keys))
        self._table_mirror(schema.name, schema)

    def insert(self, table: str, keys, ts, rows, *,
               donate: bool = True) -> None:
        # donate is accepted for call-site parity with Engine.insert but
        # not forwarded: the worker handles RPCs serially, so no reader
        # can hold a snapshot across its own insert
        self.proc.call("insert", table=table, keys=keys, ts=ts,
                       rows=np.asarray(rows, np.float32))

    def attach_stream(self, table: str, cfg=None, **cfg_kw
                      ) -> ProcPipelineClient:
        from repro.streaming.pipeline import PipelineConfig
        from repro.streaming.wal import resolve_shard
        if cfg is None and cfg_kw:
            cfg = PipelineConfig(**cfg_kw)
        # WAL dirs are per shard: substitute a ``{shard}`` placeholder
        # HERE — this path also runs during catalog replay onto a
        # respawned worker and on elastic add_client, so the new log
        # lands in this shard's own directory
        cfg = resolve_shard(cfg, self.shard_id)
        self.proc.call("attach_stream", table=table, cfg=cfg)
        pipe = ProcPipelineClient(self, table)
        self._streams[table] = pipe
        return pipe

    def register_model(self, name: str, fn, params=None) -> None:
        self.proc.call("register_model", name=name, fn=fn, params=params)

    def set_cost_model(self, model):
        prev = self.cost_model
        self.proc.call("set_cost_model", model=model)
        self.cost_model = model
        return prev

    # ------------------------------------------------------------ deploy
    def build_version(self, name: str, query, *,
                      warm_buckets=None) -> ProcDeploymentHandle:
        summary = self.proc.call("build_version", name=name, query=query,
                                 warm_buckets=warm_buckets)
        return ProcDeploymentHandle(self, name, summary["version"],
                                    summary)

    def publish_version(self, handle: ProcDeploymentHandle) -> None:
        from repro.core.engine import DeploymentHandle
        tver = self.proc.call("publish_version", name=handle.name,
                              version=handle._wv())
        handle.table.version = max(handle.table.version, tver)
        old = self._live.get(handle.name)
        if old is not None and old is not handle:
            old.state = DeploymentHandle.RETIRED
        handle.state = DeploymentHandle.LIVE
        self._live[handle.name] = handle

    def discard_version(self, handle: ProcDeploymentHandle) -> None:
        from repro.core.engine import DeploymentHandle
        self.proc.call("discard_version", name=handle.name,
                       version=handle._wv())
        handle.state = DeploymentHandle.RETIRED
        self._alias.pop((handle.name, handle.version), None)

    # ----------------------------------------------------------- offline
    def query_offline(self, name: str, *, batch_size: int = 1024,
                      point_in_time: bool = True) -> Dict[str, np.ndarray]:
        """Worker-side materialisation; ``__key`` already holds REAL key
        values (mapped where ``key_to_idx`` lives, inside the worker)."""
        return self.proc.call("query_offline", name=name,
                              batch_size=batch_size,
                              point_in_time=point_in_time)

    # --------------------------------------------------------- migration
    def list_keys(self, table: str) -> List:
        return self.proc.call("list_keys", table=table)

    def extract_events(self, table: str, keys: Sequence):
        return self.proc.call("extract_events", table=table,
                              keys=list(keys))

    def migrate_in(self, table: str, keys: Sequence, ts, rows) -> int:
        return self.proc.call("migrate_in", table=table, keys=list(keys),
                              ts=np.asarray(ts, np.float32),
                              rows=np.asarray(rows, np.float32))

    # ------------------------------------------------------------- intro
    def latency_decomposition(self) -> Dict[str, float]:
        return self.proc.call("latency_decomposition")

    def explain(self, name: str) -> str:
        return self.proc.call("explain", name=name)

    def explain_analyze(self, target: str) -> str:
        return self.proc.call("explain_analyze", target=target)

    def profile_snapshot(self, name: str) -> Optional[Dict]:
        """Worker-side OperatorProfiler totals (picklable dict) — merged
        parent-side across shards for sharded EXPLAIN ANALYZE."""
        return self.proc.call("profile_snapshot", name=name)

    def freshness_snapshot(self) -> Dict:
        """Worker-side FreshnessTracker snapshot (sketch dicts + live
        watermarks) — merged exactly parent-side across shards."""
        return self.proc.call("freshness_snapshot")

    def drift_snapshot(self) -> Dict:
        return self.proc.call("drift_snapshot")

    def pin_drift_reference(self) -> List[str]:
        return self.proc.call("pin_drift")

    def table_version(self, table: str) -> int:
        v = self.proc.call("table_version", table=table)
        m = self._tables.get(table)
        if m is not None:
            m.version = max(m.version, v)
        return v

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:
        self.proc.close()


class ProcShardBackend:
    """Spawns, supervises, and (on death) respawns the worker fleet."""

    MONITOR_INTERVAL_S = 0.2

    def __init__(self, n_shards: int, *, flags, engine_kw: dict,
                 standby_workers: int = 0,
                 fault_plan: Optional[FaultPlan] = None,
                 compile_cache: Optional[str] = None):
        self.flags = flags
        self.engine_kw = dict(engine_kw)
        self.fault_plan = fault_plan
        self.compile_cache = compile_cache
        self.clients: List[ProcEngineClient] = []
        # (method, kwargs) log replayed onto respawned workers, in order
        self._ddl_log: List[Tuple[str, dict]] = []
        # set by ShardedEngine: called with (shard_id, client) after the
        # catalog replay, to rebuild + republish deployment versions
        self.respawn_hook: Optional[Callable[[int, "ProcEngineClient"],
                                             None]] = None
        # set by ShardedEngine: shard_id -> replicated table names, for
        # replica re-seeding from a healthy shard
        self.reseed_hook: Optional[Callable[[int, "ProcEngineClient"],
                                            None]] = None
        # WAL hooks (ShardedEngine): prespawn archives the dead shard's
        # log dir BEFORE the replacement opens a fresh one; replay —
        # after the catalog + deployments are back — re-scatters the
        # archived events through the live RouteTable
        self.prespawn_hook: Optional[Callable[[int], None]] = None
        self.replay_hook: Optional[Callable[[int, "ProcEngineClient"],
                                            None]] = None
        # last-recovery timing, read by telemetry/bench_recovery
        self.recovery_stats: Dict[str, float] = {}
        self._closing = False
        # pool first: standbys warm in the background while the initial
        # fleet cold-spawns (nothing is warm yet for the first spawns)
        self.standby = None
        if standby_workers > 0:
            from repro.shard.proc.standby import StandbyPool
            self.standby = StandbyPool(standby_workers,
                                       compile_cache=compile_cache)
        for s in range(n_shards):
            self.clients.append(ProcEngineClient(self, s))
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         daemon=True,
                                         name="shard-proc-monitor")
        self._monitor.start()

    # ------------------------------------------------------------ catalog
    def log_ddl(self, method: str, **kwargs) -> None:
        self._ddl_log.append((method, kwargs))

    def add_client(self) -> ProcEngineClient:
        """Spawn one more worker (elastic add_shard) and bring it up to
        the current catalog."""
        client = ProcEngineClient(self, len(self.clients))
        self._replay_catalog(client)
        self.clients.append(client)
        return client

    def _replay_catalog(self, client: ProcEngineClient) -> None:
        for method, kwargs in self._ddl_log:
            getattr(client, method)(**kwargs)

    # ---------------------------------------------------------- broadcast
    def broadcast(self, method: str, only: Optional[Sequence[int]] = None,
                  **args) -> List:
        """One serialized payload fanned to every (or ``only``) worker —
        the replicated-dimension-table ingest path: the args blob is
        pickled ONCE, each worker gets the same bytes."""
        blob = encode_args(args)
        targets = [self.clients[i] for i in only] if only is not None \
            else list(self.clients)
        reqs = [(c, c.proc.submit_blob(method, blob)) for c in targets]
        return [c.proc.wait(r) for c, r in reqs]

    # ---------------------------------------------------------- liveness
    def _monitor_loop(self) -> None:
        while not self._closing:
            time.sleep(self.MONITOR_INTERVAL_S)
            for client in list(self.clients):
                if self._closing or client.retired:
                    continue
                proc = client.proc
                if not proc.dead():
                    continue
                proc.mark_down()       # idempotent; poll may beat EOF
                try:
                    self._respawn(client)
                except BaseException as e:     # keep supervising
                    sys.stderr.write(
                        f"# shard {client.shard_id} respawn failed: "
                        f"{e!r}\n")

    def _respawn(self, client: ProcEngineClient) -> None:
        t0 = time.perf_counter()
        client.ready = False
        client.proc.mark_down()
        try:
            client.proc.close(timeout=1.0)
        except Exception:
            pass
        if self.prespawn_hook is not None:
            # archive the dead shard's WAL dir before the replacement
            # worker opens a fresh log at the same path
            try:
                self.prespawn_hook(client.shard_id)
            except Exception as e:
                sys.stderr.write(f"# shard {client.shard_id} WAL "
                                 f"archive failed: {e!r}\n")
        client.proc = _WorkerProc(
            client.shard_id, self.flags, self.engine_kw,
            # a respawned worker must not inherit a live kill trigger —
            # that would be a crash loop, not a chaos experiment
            fault_plan=(self.fault_plan.disarmed()
                        if self.fault_plan is not None else None),
            standby=self.standby, stats=client.transport_stats,
            compile_cache=self.compile_cache)
        t_spawn = time.perf_counter()
        client.restarts += 1
        client._alias.clear()
        client._live.clear()
        # mirrors refresh by max(); the fresh worker restarts version
        # numbering near 0, so stale high values must be dropped first
        for m in client._tables.values():
            m.version = 0
        try:
            self._replay_catalog(client)
            if self.reseed_hook is not None:
                self.reseed_hook(client.shard_id, client)
            if self.respawn_hook is not None:
                self.respawn_hook(client.shard_id, client)
            if self.replay_hook is not None:
                self.replay_hook(client.shard_id, client)
        except BaseException:
            # a failed replay leaves the client not-ready; kill the
            # worker so the monitor's next pass retries the respawn
            client.proc.close(timeout=1.0)
            raise
        client.ready = True
        now = time.perf_counter()
        self.recovery_stats = {
            "last_mttr_s": now - t0,
            "last_spawn_s": t_spawn - t0,
            "last_replay_s": now - t_spawn,
            "last_adopted": float(client.proc.adopted),
            "recoveries": self.recovery_stats.get("recoveries", 0) + 1}

    # --------------------------------------------------------- lifecycle
    def close(self) -> None:
        self._closing = True
        for client in self.clients:
            client.close()
        if self.standby is not None:
            self.standby.close()
