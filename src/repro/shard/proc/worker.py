"""Shard worker subprocess: one full Engine behind an RPC loop.

Spawned by :class:`repro.shard.proc.backend.ProcShardBackend` with the
channel fd in ``REPRO_SHARD_WORKER_FD`` and the jax env pins
(``--xla_force_host_platform_device_count=1``, ``JAX_PLATFORMS``, dtype
pins) already in the environment — they must land BEFORE jax import,
which is exactly why shard engines live in subprocesses at all: jax
reads them once at init, and one process cannot host N independent
runtimes.

The first frame the parent sends is ``hello`` carrying the engine
constructor arguments; after that every frame is ``(req_id, method,
args_blob)`` dispatched on a small thread pool (Engine internals are
thread-safe; serving dispatches must not queue behind a multi-second
``build_version``). Responses are ``(req_id, True, result)`` or
``(req_id, False, exception)``.
"""
from __future__ import annotations

import collections
import os
import socket
import sys
import threading
import traceback
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.shard.proc.transport import Channel, FrameCorrupt, decode_args

# completed responses kept for duplicate-request resend (retry/backoff
# makes delivery at-least-once; this cache keeps execution exactly-once)
_DONE_CACHE = 256


def _np_columns(columns) -> dict:
    """Materialise device arrays to host numpy before pickling."""
    return {k: np.asarray(v) for k, v in columns.items()}


class WorkerServer:
    """RPC dispatch around one Engine (one shard's whole runtime)."""

    def __init__(self, ch: Channel, shard_id: int, flags, engine_kw):
        from repro.core.engine import Engine
        self.ch = ch
        self.shard_id = shard_id
        self.engine = Engine(flags, **engine_kw)
        # the CLIENT makes the sampling decision (it only attaches a
        # trace dict to a serve RPC for sampled traces); worker-side the
        # tracer accepts whatever arrives, so both samplers never have
        # to agree on a rate across the process boundary
        self.engine.tracer.set_sample_rate(1.0)
        # (name, version) -> DeploymentHandle; the parent addresses serve
        # and control RPCs by this pair, never by object reference
        self.handles = {}
        self.pool = ThreadPoolExecutor(
            max_workers=4, thread_name_prefix=f"shard{shard_id}-rpc")
        self._stopping = False
        # at-least-once delivery (client retries resend the SAME req_id)
        # must stay exactly-once execution: duplicates of an in-flight
        # request are dropped (the original will answer), duplicates of
        # a finished one get its cached response re-sent
        self._dedup_lock = threading.Lock()
        self._inflight: set = set()
        self._done: "collections.OrderedDict" = collections.OrderedDict()
        self.frames_corrupt = 0
        self.dups_dropped = 0

    # --------------------------------------------------------------- loop
    def serve_forever(self) -> None:
        while not self._stopping:
            try:
                req_id, method, blob = self.ch.recv()
            except FrameCorrupt:
                # frame consumed, stream still aligned: the client's
                # retry layer re-sends — just keep reading
                self.frames_corrupt += 1
                continue
            except EOFError:
                break            # parent gone: exit quietly
            with self._dedup_lock:
                if req_id in self._inflight:
                    self.dups_dropped += 1
                    continue
                cached = self._done.get(req_id)
                if cached is None:
                    self._inflight.add(req_id)
            if cached is not None:
                self.dups_dropped += 1
                self.pool.submit(self.ch.send, cached)
                continue
            self.pool.submit(self._handle, req_id, method, blob)
        self.pool.shutdown(wait=True)
        self.engine.close()

    def _finish(self, req_id, resp) -> None:
        with self._dedup_lock:
            self._inflight.discard(req_id)
            if resp is not None:
                self._done[req_id] = resp
                while len(self._done) > _DONE_CACHE:
                    self._done.popitem(last=False)

    def _handle(self, req_id, method, blob) -> None:
        resp = None
        try:
            try:
                args = decode_args(blob) if blob else {}
                result = getattr(self, "rpc_" + method)(**args)
                resp = (req_id, True, result)
                self.ch.send(resp)
            except BaseException as e:
                # exceptions cross the boundary as values; strip
                # unpicklable baggage rather than killing the worker
                try:
                    resp = (req_id, False, e)
                    self.ch.send(resp)
                except Exception:
                    resp = (req_id, False, RuntimeError(
                        f"{type(e).__name__}: {e}\n"
                        + traceback.format_exc(limit=8)))
                    self.ch.send(resp)
        finally:
            self._finish(req_id, resp)

    def _pipe(self, table: str):
        pipe = self.engine.streams.get(table)
        if pipe is None:
            raise KeyError(f"table {table!r} has no attached stream on "
                           f"shard {self.shard_id}")
        return pipe

    def _handle_of(self, name: str, version: int):
        h = self.handles.get((name, version))
        if h is None:
            raise KeyError(f"shard {self.shard_id} has no handle "
                           f"{name!r} v{version}")
        return h

    # ---------------------------------------------------------------- DDL
    def rpc_create_table(self, schema=None, max_keys=1024, capacity=1024,
                         bucket_size=64, join_keys=()):
        self.engine.create_table(schema, max_keys=max_keys,
                                 capacity=capacity,
                                 bucket_size=bucket_size,
                                 join_keys=join_keys)

    def rpc_insert(self, table=None, keys=None, ts=None, rows=None):
        self.engine.insert(table, keys, ts, rows)

    def rpc_register_model(self, name=None, fn=None, params=None):
        self.engine.register_model(name, fn, params)

    def rpc_set_cost_model(self, model=None):
        self.engine.set_cost_model(model)

    # ---------------------------------------------------------- streaming
    def rpc_attach_stream(self, table=None, cfg=None):
        self.engine.attach_stream(table, cfg)

    def rpc_pipe_push(self, table=None, key=None, ts=None, row=None):
        return self._pipe(table).push(key, ts, row)

    def rpc_pipe_push_batch(self, table=None, keys=None, ts=None,
                            rows=None, all_or_nothing=False):
        return self._pipe(table).push_batch(
            keys, ts, rows, all_or_nothing=all_or_nothing)

    def rpc_pipe_prepare(self, table=None, keys=None, ts=None, rows=None):
        return self._pipe(table).prepare(keys, ts, rows)

    def rpc_pipe_commit(self, table=None, txn=None):
        return self._pipe(table).commit_txn(txn)

    def rpc_pipe_abort(self, table=None, txn=None):
        self._pipe(table).abort_txn(txn)

    def rpc_pipe_flush(self, table=None, flush_all=True, check=False):
        pipe = self._pipe(table)
        pipe.flush(flush_all=flush_all)
        if check and pipe.last_error is not None \
                and pipe.buffer.n_staged > 0:
            # mirror Engine.insert's barrier semantics: staged remainder
            # plus an error means the write did not fully land
            raise RuntimeError(
                f"ingest into {table!r} failed on shard "
                f"{self.shard_id}: {pipe.last_error}") from pipe.last_error
        return pipe.table.version

    def rpc_pipe_wait_idle(self, table=None, timeout=30.0):
        return self._pipe(table).wait_idle(timeout)

    def rpc_pipe_warm(self, table=None):
        return self._pipe(table).warm()

    def rpc_pipe_metrics(self, table=None):
        return dict(self._pipe(table).metrics())

    # ------------------------------------------------------------- deploy
    def rpc_build_version(self, name=None, query=None, warm_buckets=None):
        h = self.engine.build_version(name, query,
                                      warm_buckets=warm_buckets)
        self.handles[(name, h.version)] = h
        return {"version": h.version,
                "feature_names": list(h.phys.feature_names),
                "joins": tuple(h.plan.joins),
                "table": h.table.schema.name,
                "schema": h.table.schema,
                "table_version": h.table.version}

    def rpc_publish_version(self, name=None, version=None):
        h = self._handle_of(name, version)
        self.engine.publish_version(h)
        return h.table.version

    def rpc_discard_version(self, name=None, version=None):
        h = self.handles.pop((name, version), None)
        if h is not None:
            self.engine.discard_version(h)

    def rpc_warm(self, name=None, version=None, buckets=()):
        return self._handle_of(name, version).warm(buckets)

    # -------------------------------------------------------------- serve
    def rpc_serve(self, name=None, version=None, keys=None, ts=None,
                  rows=None, trace=None, n_live=None):
        ctx = None
        if trace is not None:
            from repro.core.results import RequestContext
            ctx = RequestContext(trace_id=trace["trace_id"],
                                 parent_span=trace.get("parent"))
        frame = self._handle_of(name, version).request(keys, ts, rows,
                                                       ctx=ctx,
                                                       n_live=n_live)
        # worker-clock span export rides the response; the client
        # re-bases onto its own clock and adopts (dedup by span id keeps
        # transport retries/dups idempotent)
        spans = (self.engine.tracer.export_trace(trace["trace_id"])
                 if trace is not None else ())
        return (_np_columns(frame.columns), np.asarray(frame.status),
                int(frame.table_version), spans,
                frame.watermark, frame.feature_age)

    def rpc_handle_metrics(self, name=None, version=None):
        return self._handle_of(name, version).metrics.snapshot()

    def rpc_join_staleness(self, name=None, version=None):
        return self._handle_of(name, version).join_staleness()

    # ------------------------------------------------------------ offline
    def rpc_query_offline(self, name=None, batch_size=1024,
                          point_in_time=True):
        res = self.engine.query_offline(name, batch_size=batch_size,
                                        point_in_time=point_in_time)
        out = {k: np.asarray(v) for k, v in res.items()}
        if "__key" in out and len(out["__key"]):
            # map shard-local dense indices back to real key values here,
            # where key_to_idx lives — the parent never sees local indices
            live = self.engine.deployments.get(name)
            h = live if live is not None else next(
                (h for (n, _v), h in self.handles.items() if n == name),
                None)
            inv = {i: k for k, i in h.table.key_to_idx.items()}
            out["__key"] = np.asarray([inv[int(i)] for i in out["__key"]])
        return out

    # ---------------------------------------------------------- migration
    def rpc_list_keys(self, table=None):
        from repro.shard.migrate import list_keys
        return list_keys(self.engine, table)

    def rpc_extract_events(self, table=None, keys=None):
        from repro.shard.migrate import extract_events
        return extract_events(self.engine, table, keys)

    def rpc_migrate_in(self, table=None, keys=None, ts=None, rows=None):
        from repro.shard.migrate import migrate_in
        return migrate_in(self.engine, table, keys, ts, rows)

    # -------------------------------------------------------------- intro
    def rpc_engine_stats(self):
        return self.engine.stats.snapshot()

    def rpc_cache_stats(self):
        return self.engine.cache.stats.snapshot()

    def rpc_cache_hit_rate(self):
        return float(self.engine.cache.stats.hit_rate)

    def rpc_latency_decomposition(self):
        return self.engine.latency_decomposition()

    def rpc_explain(self, name=None):
        return self.engine.explain(name)

    def rpc_explain_analyze(self, target=None):
        return self.engine.explain_analyze(target)

    def rpc_profile_snapshot(self, name=None):
        return self.engine.profiler.snapshot(name)

    def rpc_freshness_snapshot(self):
        return self.engine.freshness_snapshot()

    def rpc_drift_snapshot(self):
        return self.engine.drift.snapshot()

    def rpc_pin_drift(self):
        return self.engine.pin_drift_reference()

    def rpc_table_version(self, table=None):
        return self.engine.tables[table].version

    def rpc_ping(self):
        return {"shard": self.shard_id, "pid": os.getpid(),
                "frames_corrupt": self.frames_corrupt,
                "dups_dropped": self.dups_dropped}

    def rpc_shutdown(self):
        self._stopping = True


def main() -> int:
    fd = int(os.environ["REPRO_SHARD_WORKER_FD"])
    sock = socket.socket(fileno=fd)
    ch = Channel(sock)
    if os.environ.get("REPRO_SHARD_PREWARM") == "1":
        # standby pool (proc/standby.py): pay the multi-second jax +
        # Engine import NOW, while parked, then tell the parent we're
        # warm. The hello — carrying the actual shard identity — may
        # arrive much later, at adoption time.
        from repro.core.engine import Engine  # noqa: F401  (import cost)
        ch.send(("warm", {"pid": os.getpid()}))
    # hello carries the engine construction args (sent before any RPC)
    try:
        tag, hello = ch.recv()
    except EOFError:
        return 0       # never adopted: the standby pool closed quietly
    assert tag == "hello", f"expected hello frame, got {tag!r}"
    server = WorkerServer(ch, shard_id=hello["shard_id"],
                          flags=hello["flags"],
                          engine_kw=hello.get("engine_kw", {}))
    ch.send(("ready", {"pid": os.getpid()}))
    # chaos: install the fault injector only AFTER the handshake, so
    # bootstrap frames are never faulted; the worker side runs the plan
    # disarmed (frame faults only) — the kill trigger belongs to the
    # parent, which can SIGKILL this process mid-RPC
    plan = hello.get("fault_plan")
    if plan is not None and plan.disarmed().active:
        from repro.shard.proc.faults import FaultInjector
        ch.fault_injector = FaultInjector(
            plan.disarmed(), role=f"worker-{hello['shard_id']}")
    server.serve_forever()
    return 0


if __name__ == "__main__":
    sys.exit(main())
