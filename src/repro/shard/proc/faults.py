"""Seeded, replayable fault injection for the process-shard transport.

A :class:`FaultPlan` is a frozen description of WHAT can go wrong —
per-frame drop / delay / duplicate / corrupt probabilities plus a
deterministic kill-on-nth-frame trigger. A :class:`FaultInjector` is the
plan armed with a seeded RNG and attached to a ``Channel`` (one injector
per channel end, derived from the plan seed xor a role string, so the
client→worker and worker→client directions draw independent but fully
reproducible streams).

The injector sits in ``Channel.send`` — the ONLY chaos hook in the
transport — and rewrites each outbound frame into zero or more wire
frames:

    drop       frame never hits the wire (receiver sees nothing; the
               sender's retry layer must re-send)
    delay      frame is held ``delay_s`` before sending (reorders
               against frames from other sender threads)
    duplicate  frame is sent twice (exercises worker-side request-id
               dedup — at-least-once delivery must stay exactly-once
               execution)
    corrupt    payload bytes are mutated under the ORIGINAL declared
               crc32; length is unchanged so the stream stays aligned
               and the receiver raises ``FrameCorrupt`` (retryable)
               rather than a pickle crash. This also covers the
               "truncate" failure mode: a short frame on a SOCK_STREAM
               socketpair is indistinguishable from a stall to the
               reader, so mid-frame damage is modeled as corruption
               at full length, which the CRC catches identically.
    kill       on the Nth frame (1-based, counted per injector) the
               kill callback fires — the parent-side injector SIGKILLs
               the worker mid-RPC, the sharpest crash the runtime can
               experience.

Plans parse from compact spec strings so CI can pin one in an env var::

    REPRO_FAULT_PLAN="seed=7,drop=0.05,delay=0.1,delay_s=0.02,dup=0.05"
    REPRO_FAULT_PLAN="seed=3,kill_after=40"

Everything here is stdlib-only: ``shard/engine.py`` imports FaultPlan
for its config surface without pulling jax or the proc backend.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["FaultPlan", "FaultInjector"]

_ENV = "REPRO_FAULT_PLAN"


@dataclass(frozen=True)
class FaultPlan:
    """What the chaos layer is allowed to do, deterministically seeded.

    Probabilities are per outbound frame and evaluated independently in
    a fixed order (kill → drop → delay → corrupt → duplicate), so one
    seed always replays the identical fault sequence for a given frame
    stream."""

    seed: int = 0
    drop: float = 0.0          # P(frame never sent)
    delay: float = 0.0         # P(frame held delay_s before sending)
    delay_s: float = 0.01
    duplicate: float = 0.0     # P(frame sent twice)
    corrupt: float = 0.0       # P(payload mutated under original crc)
    kill_after: int = 0        # SIGKILL the peer on the Nth frame (0=off)

    @property
    def active(self) -> bool:
        return (self.drop > 0 or self.delay > 0 or self.duplicate > 0
                or self.corrupt > 0 or self.kill_after > 0)

    def disarmed(self) -> "FaultPlan":
        """The same plan without the kill trigger — respawned workers
        must not inherit a live kill counter or recovery becomes a
        crash loop."""
        return dataclasses.replace(self, kill_after=0)

    # ------------------------------------------------------------- parsing
    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse ``"seed=7,drop=0.05,kill_after=40"`` (aliases:
        ``dup`` for duplicate). Unknown keys raise — a typo'd chaos run
        silently testing nothing is worse than a crash."""
        kw: Dict[str, object] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k = {"dup": "duplicate"}.get(k.strip(), k.strip())
            if k not in cls.__dataclass_fields__:
                raise ValueError(f"unknown FaultPlan field {k!r} in "
                                 f"{spec!r}")
            kw[k] = int(v) if k in ("seed", "kill_after") else float(v)
        return cls(**kw)

    @classmethod
    def from_env(cls, env: str = _ENV) -> Optional["FaultPlan"]:
        spec = os.environ.get(env, "").strip()
        return cls.parse(spec) if spec else None


class FaultInjector:
    """A :class:`FaultPlan` armed with a per-role seeded RNG.

    ``role`` keeps the two directions of one channel (and the channels
    of different shards) on independent deterministic streams:
    ``seed ^ crc32(role)`` seeds a private ``random.Random``.

    ``kill_cb`` fires ON the kill frame *instead of sending it* —
    modeling a process that died mid-RPC, which is exactly when the
    caller is left holding an unanswered future.
    """

    def __init__(self, plan: FaultPlan, *, role: str = "",
                 kill_cb: Optional[Callable[[], None]] = None):
        self.plan = plan
        self.role = role
        self._rng = random.Random(plan.seed ^ zlib.crc32(role.encode()))
        self._kill_cb = kill_cb
        self._n = 0
        self._killed = False
        self._lock = threading.Lock()
        self.stats: Dict[str, int] = {
            "frames": 0, "dropped": 0, "delayed": 0, "duplicated": 0,
            "corrupted": 0, "killed": 0}

    def _mutate(self, payload: bytes) -> bytes:
        """Flip a few bytes somewhere in the payload (length preserved)."""
        b = bytearray(payload)
        for _ in range(min(3, len(b))):
            i = self._rng.randrange(len(b))
            b[i] ^= 0xFF
        return bytes(b)

    def frames(self, payload: bytes) -> List[Tuple[bytes, int]]:
        """Map one logical outbound frame to the ``(payload, crc)`` wire
        frames that actually get sent (called under the channel's send
        lock — ordering across sender threads is already serialized)."""
        p = self.plan
        with self._lock:
            self._n += 1
            n = self._n
            self.stats["frames"] += 1
            r_kill = (p.kill_after > 0 and n >= p.kill_after
                      and not self._killed)
            if r_kill:
                self._killed = True        # fire once: a respawned peer
                                           # must not be re-killed
            r_drop = p.drop > 0 and self._rng.random() < p.drop
            r_delay = p.delay > 0 and self._rng.random() < p.delay
            r_corrupt = p.corrupt > 0 and self._rng.random() < p.corrupt
            r_dup = p.duplicate > 0 and self._rng.random() < p.duplicate
        if r_kill and self._kill_cb is not None:
            self.stats["killed"] += 1
            self._kill_cb()
            return []                      # the process died mid-send
        if r_drop:
            self.stats["dropped"] += 1
            return []
        if r_delay:
            self.stats["delayed"] += 1
            time.sleep(p.delay_s)
        crc = zlib.crc32(payload)
        if r_corrupt:
            self.stats["corrupted"] += 1
            # declared crc stays that of the ORIGINAL bytes: the
            # receiver sees a full-length frame that fails its check
            out = [(self._mutate(payload), crc)]
        else:
            out = [(payload, crc)]
        if r_dup:
            self.stats["duplicated"] += 1
            out = out + [out[0]]
        return out
