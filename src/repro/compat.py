"""Version-compatibility shims over the installed jax.

The codebase targets the modern jax API (``AxisType`` meshes,
``jax.shard_map(..., check_vma=..., axis_names=...)``). The baked-in
toolchain may carry an older jax (0.4.x) where those spell differently:

* ``jax.sharding.AxisType`` does not exist; ``jax.make_mesh`` /
  ``AbstractMesh`` take no ``axis_types``;
* ``AbstractMesh`` is constructed from ``((name, size), ...)`` pairs;
* ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
  partially-manual entry as ``auto=`` (the complement of ``axis_names``)
  and replication checking as ``check_rep``.

Every call site goes through these helpers instead of feature-detecting
inline, so the rest of the codebase reads as if only the modern API
existed.
"""
from __future__ import annotations

import functools
from typing import Callable, Iterable, Optional, Sequence

import jax
from jax.sharding import AbstractMesh, Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None

__all__ = ["AxisType", "HAS_AXIS_TYPE", "HAS_PARTIAL_MANUAL", "make_mesh",
           "make_abstract_mesh", "shard_map"]

HAS_AXIS_TYPE = AxisType is not None

# Entering shard_map over a subset of mesh axes (manual subgroups) is only
# reliably lowered by the modern stack; the 0.4.x XLA check-fails on it
# (hlo_sharding_util: "Check failed: sharding.IsManualSubgroup()").
HAS_PARTIAL_MANUAL = hasattr(jax, "shard_map")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when the API supports them."""
    if HAS_AXIS_TYPE:
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             axis_types=(AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def make_abstract_mesh(axis_shapes: Sequence[int],
                       axis_names: Sequence[str]) -> AbstractMesh:
    """Device-less mesh for shape/spec validation (both API generations)."""
    if HAS_AXIS_TYPE:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names),
                            axis_types=(AxisType.Auto,) * len(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def shard_map(f: Optional[Callable] = None, *, mesh: Mesh,
              in_specs, out_specs,
              manual_axes: Optional[Iterable[str]] = None) -> Callable:
    """``jax.shard_map`` with the Manual axis set spelled portably.

    ``manual_axes`` names the axes entered manually (the modern API's
    ``axis_names``); ``None`` means fully manual. Replication/VMA checking
    is disabled on both paths (call sites mix manual and auto axes, which
    the checkers reject).
    """
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs,
                                 manual_axes=manual_axes)
    if hasattr(jax, "shard_map"):
        kwargs = {}
        if manual_axes is not None:
            kwargs["axis_names"] = frozenset(manual_axes)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset()
    if manual_axes is not None:
        auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)
