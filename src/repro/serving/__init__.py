from repro.serving.batcher import BatcherConfig, DynamicBatcher, Request
from repro.serving.server import FeatureServer, ServerConfig, ModelServer

__all__ = ["BatcherConfig", "DynamicBatcher", "Request", "FeatureServer",
           "ServerConfig", "ModelServer"]
