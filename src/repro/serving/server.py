"""Serving runtime: feature engine + (optional) model decode behind the
dynamic batcher — the paper's online mode as a deployable server loop.

Two servers:

* ``FeatureServer`` — OpenMLDB's role: per-request real-time feature
  vectors from deployed SQL window queries (engine hot path), with the
  batcher providing deadline/size batching and admission control.
* ``ModelServer``  — features (or tokens) -> model decode steps; holds the
  jit-compiled ``serve_step`` + KV caches, demonstrates the end-to-end
  "SQL features -> ML model" pipeline of the paper's Figure 5.

Fault tolerance: a hedged-dispatch wrapper (``hedged``) re-issues a
request after a deadline — at scale, one slow replica must not set the
tail latency (straggler mitigation on the serving path).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import DeploymentHandle, Engine
from repro.core.results import FeatureFrame, RequestContext
from repro.obs.trace import new_trace_id
from repro.serving.batcher import BatcherConfig, DynamicBatcher

__all__ = ["ServerConfig", "FeatureServer", "ModelServer", "hedged"]


@dataclass(frozen=True)
class ServerConfig:
    batcher: BatcherConfig = BatcherConfig()
    hedge_after_s: Optional[float] = None     # straggler re-dispatch
    # shape buckets to pre-compile at server construction (off the
    # serving path); () = first requests pay the compile, as the paper
    # charges it. Tight SLOs should warm 1..batcher.max_batch.
    warm_buckets: tuple = ()


class FeatureServer:
    """Online feature serving session over a deployed engine query.

    Each dispatched batch resolves the deployment handle ONCE — together
    with the batcher's version-pin grouping this guarantees a batch is
    served end-to-end by a single deployment version, even while a
    hot-swap redeploy publishes a new one mid-flight. A request may pin a
    version explicitly via ``RequestContext(version_pin=...)`` (retired
    versions keep serving for pinned traffic, e.g. shadow replay).

    When the deployment's table has a streaming pipeline attached (see
    ``Engine.attach_stream``), the server also exposes the **write path**:
    ``ingest`` stages an event into the watermark buffer and returns
    immediately — it never blocks a concurrent ``request``, whose reads
    come from atomically-published table snapshots (DESIGN.md §4).

    **Shard-aware**: ``engine`` may be a ``repro.shard.ShardedEngine`` —
    handle resolution, version pinning, batching and the write path all
    go through the same surface; requests are then admission-controlled
    and scattered across shard engines by the sharded handle (DESIGN.md
    §9), and ``ingest`` routes events to the owning shard's pipeline."""

    def __init__(self, engine: Engine, deployment: str,
                 cfg: ServerConfig = ServerConfig()):
        self.engine = engine
        self.deployment = deployment
        self.cfg = cfg
        self._closed = False

        def serve_batch(keys, ts, payloads, ctx=None):
            handle = self._resolve(ctx)
            return handle.request(keys, ts, payloads, ctx=ctx)

        if cfg.warm_buckets and engine.cache.enabled:
            engine.handle(deployment).warm(cfg.warm_buckets)
        self.batcher = DynamicBatcher(
            serve_batch, cfg.batcher,
            tracer=getattr(engine, "tracer", None))

    def _resolve(self, ctx: Optional[RequestContext]) -> DeploymentHandle:
        """One handle per batch — the no-version-mixing pivot."""
        if ctx is not None and ctx.version_pin is not None:
            return self.engine.handle(self.deployment,
                                      version=ctx.version_pin)
        return self.engine.handle(self.deployment)

    @property
    def handle(self) -> DeploymentHandle:
        """The currently-live deployment handle."""
        return self.engine.handle(self.deployment)

    @property
    def pipeline(self):
        """The table's attached IngestPipeline, or None."""
        table = self.engine.handle(self.deployment).table
        return self.engine.streams.get(table.schema.name)

    def request(self, key, ts: float,
                row: Optional[np.ndarray] = None,
                timeout: float = 30.0,
                ctx: Optional[RequestContext] = None) -> FeatureFrame:
        # timeout is the client's give-up bound (generous: a cold bucket
        # compile on a loaded box can take seconds); per-request serving
        # deadlines belong in ctx, which the batcher enforces.
        if ctx is None:
            ctx = RequestContext()
        if ctx.trace_id is None:
            # every request is traceABLE: the id is generated at the
            # serving edge when the caller didn't bring one (span
            # recording still honors the tracer's sampling decision)
            ctx = dataclasses.replace(ctx, trace_id=new_trace_id())
        tracer = getattr(self.engine, "tracer", None)
        span = None
        if tracer is not None:
            span = tracer.start("server.request", ctx.trace_id,
                                tags={"deployment": self.deployment})
            if span is not None:
                ctx = dataclasses.replace(ctx,
                                          parent_span=span.span_id)
        call = lambda: self.batcher(key, ts, row, timeout=timeout, ctx=ctx)
        try:
            if self.cfg.hedge_after_s is not None:
                res = hedged(call, self.cfg.hedge_after_s)
            else:
                res = call()
        finally:
            if span is not None:
                tracer.finish(span)
        if isinstance(res, FeatureFrame):
            res.trace_id = ctx.trace_id
        return res

    def ingest(self, key, ts: float, row: np.ndarray) -> bool:
        """Non-blocking event ingestion (requires an attached stream).
        Returns False iff the event was beyond the watermark (dropped)."""
        pipe = self.pipeline
        if pipe is None:
            raise RuntimeError(
                f"no stream attached to deployment {self.deployment!r}'s "
                f"table; call Engine.attach_stream first")
        return pipe.push(key, ts, row)

    def close(self) -> None:
        """Idempotent: benchmarks/tests may close via context manager AND
        explicitly without leaking or double-joining dispatcher threads."""
        if self._closed:
            return
        self._closed = True
        self.batcher.close()

    def __enter__(self) -> "FeatureServer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False


class ModelServer:
    """Batched incremental decoding behind compiled prefill/decode steps.

    ``prefill(tokens (B,S)) -> slot ids``; ``decode() -> (B,) next tokens``.
    The KV caches live on device; requests join/leave slots (continuous
    batching at slot granularity).
    """

    def __init__(self, cfg, params, *, batch: int, cache_len: int,
                 mesh=None, greedy: bool = True):
        from repro.launch.steps import make_prefill_step, make_serve_step
        from repro.models import lm
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        self.greedy = greedy
        self._prefill = jax.jit(make_prefill_step(cfg, cache_len))
        self._decode = jax.jit(make_serve_step(cfg), donate_argnums=(1,))
        self.caches = lm.init_cache(cfg, batch, cache_len)
        self.positions = jnp.zeros((batch,), jnp.int32)
        self.tokens = jnp.zeros((batch,), jnp.int32)
        self.active = np.zeros((batch,), bool)
        self.generated: List[List[int]] = [[] for _ in range(batch)]

    def prefill(self, tokens: np.ndarray) -> List[int]:
        """Admit ``tokens (B0, S)`` sequences into free slots."""
        B0, S = tokens.shape
        free = [i for i in range(self.batch) if not self.active[i]][:B0]
        if len(free) < B0:
            raise RuntimeError("no free slots (admission control)")
        last_logits, caches = self._prefill(self.params,
                                            jnp.asarray(tokens, jnp.int32))
        nxt = jnp.argmax(last_logits, -1).astype(jnp.int32)
        # scatter the prefilled caches into the batch slots
        idx = jnp.asarray(free, jnp.int32)
        self.caches = jax.tree_util.tree_map(
            lambda full, new: full.at[:, idx].set(
                new.astype(full.dtype)) if full.ndim >= 2 else full,
            self.caches, caches)
        self.tokens = self.tokens.at[idx].set(nxt)
        self.positions = self.positions.at[idx].set(S)
        for j, slot in enumerate(free):
            self.active[slot] = True
            self.generated[slot] = [int(nxt[j])]
        return free

    def decode(self, steps: int = 1) -> np.ndarray:
        """Advance every active slot ``steps`` tokens."""
        for _ in range(steps):
            logits, self.caches = self._decode(self.params, self.caches,
                                               self.tokens, self.positions)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            self.tokens = nxt
            self.positions = self.positions + 1
            host = np.asarray(nxt)
            for i in range(self.batch):
                if self.active[i]:
                    self.generated[i].append(int(host[i]))
        return np.asarray(self.tokens)

    def release(self, slots: Sequence[int]) -> None:
        for s in slots:
            self.active[s] = False


def hedged(call: Callable[[], Any], after_s: float,
           max_hedges: int = 1) -> Any:
    """Issue ``call``; if it has not returned after ``after_s``, race a
    second attempt and take the winner (tail-at-scale mitigation)."""
    result: Dict[str, Any] = {}
    done = threading.Event()

    def attempt(tag):
        try:
            r = call()
        except Exception as e:
            r = e
        if not done.is_set():
            result.setdefault("v", r)
            done.set()

    t = threading.Thread(target=attempt, args=("p",), daemon=True)
    t.start()
    n = 0
    while not done.wait(after_s) and n < max_hedges:
        n += 1
        threading.Thread(target=attempt, args=(f"h{n}",), daemon=True).start()
    done.wait()
    v = result["v"]
    if isinstance(v, Exception):
        raise v
    return v
